"""L1 correctness: Pallas cooccur kernel vs pure-jnp oracle.

This is the CORE build-time correctness signal: the AOT artifact embeds
the kernel, so if these pass, the Rust runtime executes verified numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cooccur import cooccur
from compile.kernels.ref import cooccur_ref


def random_incidence(rng, batch, n, density=0.05):
    x = (rng.random((batch, n)) < density).astype(np.float32)
    return jnp.asarray(x)


class TestCooccurBasic:
    def test_zero_input(self):
        x = jnp.zeros((128, 128), jnp.float32)
        out = cooccur(x)
        assert out.shape == (128, 128)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_single_request_pair(self):
        # One request touching items 3 and 7 -> CRM[3,7]=CRM[7,3]=1,
        # diagonal counts 1 each.
        x = np.zeros((128, 128), np.float32)
        x[0, 3] = 1.0
        x[0, 7] = 1.0
        out = np.asarray(cooccur(jnp.asarray(x)))
        assert out[3, 7] == 1.0 and out[7, 3] == 1.0
        assert out[3, 3] == 1.0 and out[7, 7] == 1.0
        assert out.sum() == 4.0

    def test_counts_accumulate(self):
        # The same pair in k requests counts k.
        x = np.zeros((256, 64), np.float32)
        for b in range(10):
            x[b, 1] = 1.0
            x[b, 2] = 1.0
        out = np.asarray(cooccur(jnp.asarray(x), block_b=128, block_n=64))
        assert out[1, 2] == 10.0

    def test_matches_ref_dense(self):
        rng = np.random.default_rng(0)
        x = random_incidence(rng, 256, 128, density=0.3)
        got = np.asarray(cooccur(x))
        want = np.asarray(cooccur_ref(x))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_symmetry(self):
        rng = np.random.default_rng(1)
        x = random_incidence(rng, 128, 128)
        out = np.asarray(cooccur(x))
        np.testing.assert_array_equal(out, out.T)

    def test_rejects_non_divisible(self):
        with pytest.raises(ValueError, match="not divisible"):
            cooccur(jnp.zeros((100, 64), jnp.float32), block_b=128, block_n=64)


class TestCooccurBlocks:
    @pytest.mark.parametrize("block_b", [32, 64, 128])
    @pytest.mark.parametrize("block_n", [32, 64, 128])
    def test_block_invariance(self, block_b, block_n):
        # Result must not depend on tiling.
        rng = np.random.default_rng(2)
        x = random_incidence(rng, 128, 128, density=0.1)
        got = np.asarray(cooccur(x, block_b=block_b, block_n=block_n))
        want = np.asarray(cooccur_ref(x))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)

    def test_rectangular(self):
        rng = np.random.default_rng(3)
        x = random_incidence(rng, 512, 64, density=0.1)
        got = np.asarray(cooccur(x, block_b=128, block_n=64))
        want = np.asarray(cooccur_ref(x))
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    batch_blocks=st.integers(1, 4),
    n_blocks=st.integers(1, 2),
    density=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooccur_hypothesis(batch_blocks, n_blocks, density, seed):
    """Property: kernel == X^T X exactly, over random shapes/densities."""
    bb, bn = 32, 32
    batch, n = batch_blocks * bb, n_blocks * bn
    rng = np.random.default_rng(seed)
    x = (rng.random((batch, n)) < density).astype(np.float32)
    got = np.asarray(cooccur(jnp.asarray(x), block_b=bb, block_n=bn))
    want = x.T @ x
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float32, np.float64, np.int32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooccur_dtypes(dtype, seed):
    """Kernel casts any input dtype to f32 and still matches the oracle."""
    rng = np.random.default_rng(seed)
    x = (rng.random((64, 32)) < 0.2).astype(dtype)
    got = np.asarray(cooccur(jnp.asarray(x), block_b=32, block_n=32))
    want = x.astype(np.float32).T @ x.astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
