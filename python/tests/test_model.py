"""L2 correctness: the full CRM pipeline vs the pure-jnp oracle, plus
behavioural checks mirroring Algorithm 2 of the paper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import crm_pipeline_ref
from compile.model import crm_pipeline, lower_crm


def make_x(reqs, n, batch=64):
    """Build an incidence matrix from a list of item-id lists."""
    x = np.zeros((batch, n), np.float32)
    for b, items in enumerate(reqs):
        for d in items:
            x[b, d] = 1.0
    return jnp.asarray(x)


class TestPipelineMatchesRef:
    @pytest.mark.parametrize("theta", [0.0, 0.2, 0.5, 0.9])
    @pytest.mark.parametrize("top_frac", [0.1, 0.3, 1.0])
    def test_random(self, theta, top_frac):
        rng = np.random.default_rng(42)
        x = jnp.asarray((rng.random((64, 32)) < 0.15).astype(np.float32))
        got = crm_pipeline(x, jnp.float32(theta), jnp.float32(top_frac))
        want = crm_pipeline_ref(x, theta, top_frac)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


class TestAlgorithm2Semantics:
    """The paper's worked example (§IV-A-1): r1={d1,d2,d3}, r2={d2,d3}."""

    def test_paper_example(self):
        x = make_x([[1, 2, 3], [2, 3]], n=32)
        norm, bin_, freq = crm_pipeline(x, jnp.float32(0.4), jnp.float32(1.0))
        norm = np.asarray(norm)
        # (d2,d3) co-accessed twice -> the max pair -> normalizes to 1.0.
        assert norm[2, 3] == pytest.approx(1.0)
        assert norm[3, 2] == pytest.approx(1.0)
        # With theta=0.4 the (d2,d3) edge is retained.
        assert np.asarray(bin_)[2, 3] == 1.0
        # Frequencies: d2,d3 appear twice; d1 once.
        assert np.asarray(freq)[2] == 2.0 and np.asarray(freq)[1] == 1.0

    def test_diagonal_never_edges(self):
        x = make_x([[4, 5], [4, 5], [4]], n=32)
        _, bin_, _ = crm_pipeline(x, jnp.float32(0.0), jnp.float32(1.0))
        assert np.all(np.diagonal(np.asarray(bin_)) == 0.0)

    def test_symmetry(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray((rng.random((64, 64)) < 0.1).astype(np.float32))
        norm, bin_, _ = crm_pipeline(x, jnp.float32(0.3), jnp.float32(1.0))
        np.testing.assert_allclose(np.asarray(norm), np.asarray(norm).T)
        np.testing.assert_array_equal(np.asarray(bin_), np.asarray(bin_).T)

    def test_norm_in_unit_interval(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray((rng.random((64, 32)) < 0.2).astype(np.float32))
        norm, _, _ = crm_pipeline(x, jnp.float32(0.5), jnp.float32(1.0))
        norm = np.asarray(norm)
        assert norm.min() >= 0.0 and norm.max() <= 1.0 + 1e-6

    def test_top_frac_filters_rare_items(self):
        # Items 0,1 are hot (appear 10x together); items 30,31 appear once.
        reqs = [[0, 1]] * 10 + [[30, 31]]
        x = make_x(reqs, n=32)
        _, bin_, _ = crm_pipeline(x, jnp.float32(0.0), jnp.float32(0.1))
        b = np.asarray(bin_)
        # top 10% of 4 active items = 1 item -> but edges need both ends
        # kept; the hot pair survives only if both rank in top-k (ties keep
        # boundary items).  The rare pair must be filtered out.
        assert b[30, 31] == 0.0

    def test_threshold_monotone(self):
        # Raising theta can only remove edges.
        rng = np.random.default_rng(9)
        x = jnp.asarray((rng.random((64, 32)) < 0.2).astype(np.float32))
        _, b_lo, _ = crm_pipeline(x, jnp.float32(0.1), jnp.float32(1.0))
        _, b_hi, _ = crm_pipeline(x, jnp.float32(0.6), jnp.float32(1.0))
        assert np.all(np.asarray(b_hi) <= np.asarray(b_lo))

    def test_empty_window(self):
        x = jnp.zeros((64, 32), jnp.float32)
        norm, bin_, freq = crm_pipeline(x, jnp.float32(0.2), jnp.float32(0.1))
        assert np.all(np.asarray(norm) == 0.0)
        assert np.all(np.asarray(bin_) == 0.0)
        assert np.all(np.asarray(freq) == 0.0)


class TestLowering:
    def test_lower_produces_hlo_text(self):
        from compile.aot import to_hlo_text

        lowered = lower_crm(64, 32)
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        # The MXU contraction must be in the module.
        assert "dot(" in text or "dot " in text

    def test_lowered_executes(self):
        lowered = lower_crm(64, 32)
        compiled = lowered.compile()
        rng = np.random.default_rng(10)
        x = jnp.asarray((rng.random((64, 32)) < 0.2).astype(np.float32))
        out = compiled(x, jnp.float32(0.2), jnp.float32(1.0))
        want = crm_pipeline_ref(x, 0.2, 1.0)
        for g, w in zip(out, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    theta=st.floats(0.0, 0.99),
    top_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_pipeline_hypothesis(theta, top_frac, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.random((64, 32)) < 0.15).astype(np.float32))
    got = crm_pipeline(x, jnp.float32(theta), jnp.float32(top_frac))
    want = crm_pipeline_ref(x, theta, top_frac)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)
