"""Pure-jnp correctness oracles for the L1 kernel and the L2 CRM pipeline.

These are the ground truth the pytest suite checks the Pallas kernel and
the exported model against.  Written in the most obvious way possible —
no tiling, no tricks.
"""

from __future__ import annotations

import jax.numpy as jnp


def cooccur_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Raw co-occurrence matrix: CRM = X^T X (f32)."""
    x = x.astype(jnp.float32)
    return x.T @ x


def crm_pipeline_ref(
    x: jnp.ndarray,
    theta: float,
    top_frac: float,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference for the full L2 pipeline (Algorithm 2 + top-p% filter).

    Steps (mirrors python/compile/model.py, which the AOT artifact runs):
      1. raw = X^T X, diagonal zeroed (self co-access is meaningless),
      2. freq = per-item request counts = diag(X^T X),
      3. keep only rows/cols of the top ``ceil(top_frac * n_active)`` most
         frequent *active* items (paper §V-A: "top 10%"),
      4. min-max normalize the kept off-diagonal entries globally,
      5. binarize at theta.

    Returns (crm_norm, crm_bin, freq), each (n, n) / (n, n) / (n,).
    """
    x = x.astype(jnp.float32)
    n = x.shape[1]
    raw = x.T @ x
    freq = jnp.diagonal(raw)
    eye = jnp.eye(n, dtype=jnp.float32)
    off = raw * (1.0 - eye)

    # Top-p% filter over items with nonzero frequency.  To keep the graph
    # shape-static we implement "top k by frequency" with a rank threshold:
    # item kept iff its frequency is >= the k-th largest nonzero frequency
    # (ties keep everybody at the boundary — documented in DESIGN.md).
    n_active = jnp.sum(freq > 0)
    k = jnp.maximum(1.0, jnp.ceil(top_frac * n_active))
    # Rank of each item's freq among nonzero freqs (descending).
    sorted_freq = jnp.sort(jnp.where(freq > 0, freq, -jnp.inf))[::-1]
    idx = jnp.clip(k.astype(jnp.int32) - 1, 0, n - 1)
    kth = sorted_freq[idx]
    keep = (freq >= kth) & (freq > 0)
    mask = jnp.outer(keep, keep).astype(jnp.float32)
    off = off * mask

    # Global min-max over the *kept off-diagonal* support, minimum
    # anchored at 0 (see model.py for rationale).  Entries outside the
    # support normalize to 0.
    support = mask * (1.0 - eye)
    lo = jnp.float32(0.0)
    hi = jnp.max(jnp.where(support > 0, off, -jnp.float32(3.4e38)))
    hi = jnp.maximum(hi, 0.0)
    span = jnp.maximum(hi - lo, 1e-9)
    crm_norm = jnp.where(support > 0, (off - lo) / span, 0.0)

    crm_bin = (crm_norm > theta).astype(jnp.float32)
    return crm_norm, crm_bin, freq
