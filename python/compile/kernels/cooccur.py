"""L1 — Pallas co-occurrence kernel: CRM = X^T @ X over request incidence.

The Clique Generation Module's numeric hot-spot (Algorithm 2 of the AKPC
paper) is the accumulation of pairwise co-access counts over a window of
requests.  With the window encoded as an incidence matrix
``X in {0,1}^{B x n}`` (row b = multi-hot vector of the items in request b),
the raw correlation matrix is exactly ``CRM = X^T X`` — including the
diagonal, which holds per-item frequencies and is masked out downstream.

This is the canonical MXU workload.  The kernel tiles the contraction the
way a CUDA version would tile threadblocks over shared memory, but for TPU:

  * grid = (n/bn, n/bn, B/bB); each (i, j) output tile of shape (bn, bn)
    accumulates over the k-axis (batch) in VMEM,
  * BlockSpec streams (bB, bn) slabs of X from HBM into VMEM twice per
    step (once as the "row" operand, once as the "column" operand),
  * the inner product runs on the MXU via jnp.dot with an f32 accumulator.

VMEM footprint per grid step (bB = bn = 128, f32):
  2 * 128*128*4 B (inputs) + 128*128*4 B (accumulator) = 192 KiB << 16 MiB.

On this image Pallas must run ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls.  Real-TPU efficiency is estimated in
DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes: 128 is the native MXU tile edge.
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_N = 128


def _cooccur_kernel(x_rows_ref, x_cols_ref, o_ref):
    """One grid step: o[i, j] += x[k, i]^T @ x[k, j].

    x_rows_ref: (bB, bn) slab of X for the output-row items.
    x_cols_ref: (bB, bn) slab of X for the output-column items.
    o_ref:      (bn, bn) output tile, accumulated across the k grid axis.
    """
    k = pl.program_id(2)

    # Zero the accumulator tile on the first k-step.
    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction: (bn, bB) @ (bB, bn) with f32 accumulation.
    o_ref[...] += jnp.dot(
        x_rows_ref[...].T,
        x_cols_ref[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def cooccur(
    x: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
) -> jax.Array:
    """Compute the raw co-occurrence matrix ``X^T X`` with a Pallas kernel.

    Args:
      x: (B, n) incidence matrix, any float dtype (counts are small enough
         for exact f32).  B and n must be multiples of the block sizes; the
         L2 wrapper pads.

    Returns:
      (n, n) f32 co-occurrence matrix (diagonal = item frequencies).
    """
    b, n = x.shape
    if b % block_b != 0 or n % block_n != 0:
        raise ValueError(
            f"cooccur: shape {(b, n)} not divisible by blocks "
            f"{(block_b, block_n)}; pad in the caller"
        )
    x = x.astype(jnp.float32)

    grid = (n // block_n, n // block_n, b // block_b)
    return pl.pallas_call(
        _cooccur_kernel,
        grid=grid,
        in_specs=[
            # Row-operand slab: k-th batch block, i-th item block.
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (k, i)),
            # Column-operand slab: k-th batch block, j-th item block.
            pl.BlockSpec((block_b, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, x)
