"""AOT export: lower the L2 CRM pipeline to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's runtime
(xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`).  The text parser
reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py.

One artifact per (batch, n) shape; the Rust runtime's artifact registry
picks the smallest n >= the configured item-universe size and pads the
incidence batch.  `make artifacts` is incremental via mtime (Makefile).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from compile.model import lower_crm

# (batch, n) shapes exported by default.  batch=1024 holds a sliding
# Table-II correlation window (10 batches x 200 requests, sessionized to
# <1024 transactions); n covers the paper's n=60 base up to the
# Fig. 8(b)/9(b) scalability sweeps.  A small (256, 64) shape is kept for
# tests and single-batch windows.
DEFAULT_SHAPES = [
    (256, 64),
    (1024, 64),
    (1024, 128),
    (1024, 256),
    (1024, 512),
    (1024, 1024),
]

# Pallas block sizes per artifact: interpret=True unrolls every grid step
# into the HLO, so CPU execution pays per-step overhead. §Perf iteration 2
# (EXPERIMENTS.md): raising blocks from fixed 128x128 to 512-capped blocks
# cut grid steps up to 8x and sped the compiled artifact ~3-10x on CPU,
# while 512x512 f32 tiles (3 MiB VMEM) still fit the 16 MiB TPU budget.
BLOCK_CAP = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default=None,
        help="comma list like 256x64,512x512 (batchxN); default = built-ins",
    )
    args = ap.parse_args()

    shapes = DEFAULT_SHAPES
    if args.shapes:
        shapes = [
            tuple(int(v) for v in s.split("x")) for s in args.shapes.split(",")
        ]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for batch, n in shapes:
        lowered = lower_crm(batch, n)
        text = to_hlo_text(lowered)
        name = f"crm_b{batch}_n{n}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest.append({"file": name, "batch": batch, "n": n})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(
            {
                "artifacts": manifest,
                "inputs": ["x (batch, n) f32", "theta () f32", "top_frac () f32"],
                "outputs": ["crm_norm (n, n) f32", "crm_bin (n, n) f32", "freq (n,) f32"],
            },
            f,
            indent=2,
        )
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
