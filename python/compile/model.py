"""L2 — the Clique Generation Module's numeric pipeline as one JAX graph.

This is the computation the Rust coordinator executes on every ``T^CG``
tick (Algorithm 1, Event 1 / Algorithm 2 of the AKPC paper):

    incidence X (B, n)  --cooccur (L1 Pallas)-->  raw CRM (n, n)
        --> zero diagonal
        --> top-p% frequency filter         (paper §V-A, "top 10%")
        --> global min-max normalization    (Algorithm 2 line 5)
        --> threshold at theta              (Algorithm 2 lines 6-9)

``theta`` and ``top_frac`` are *runtime inputs* (rank-0 arrays), not baked
constants, so a single AOT artifact serves the full Fig. 7(a) theta sweep.

The whole pipeline lowers into a single HLO module; XLA fuses everything
after the matmul into a handful of elementwise/reduce kernels.  Python is
build-time only — the Rust runtime executes the exported artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.cooccur import cooccur


def _pick_block(dim: int, preferred: int = 512) -> int:
    """Largest power-of-two block <= preferred that divides dim.

    interpret=True unrolls each grid step into the lowered HLO, so larger
    blocks mean fewer steps and less per-step overhead on CPU; 512x512 f32
    tiles (3 MiB) still fit a real TPU's VMEM budget with double buffering
    (DESIGN.md §7, EXPERIMENTS.md §Perf iteration 2).
    """
    b = preferred
    while b > 1 and dim % b != 0:
        b //= 2
    return b


def crm_pipeline(
    x: jax.Array,
    theta: jax.Array,
    top_frac: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full CRM pipeline.  Returns (crm_norm, crm_bin, freq).

    Args:
      x:        (B, n) f32 incidence matrix (rows = requests in the window,
                multi-hot over items).  Padded rows/cols must be zero.
      theta:    rank-0 f32, CRM binarization threshold.
      top_frac: rank-0 f32, fraction of active items kept (0 < f <= 1).
    """
    b, n = x.shape
    raw = cooccur(
        x,
        block_b=_pick_block(b),
        block_n=_pick_block(n),
    )

    freq = jnp.diagonal(raw)
    eye = jnp.eye(n, dtype=jnp.float32)
    off = raw * (1.0 - eye)

    # Top-p% most-frequent active items (shape-static rank threshold).
    n_active = jnp.sum(freq > 0)
    k = jnp.maximum(1.0, jnp.ceil(top_frac * n_active))
    sorted_freq = jnp.sort(jnp.where(freq > 0, freq, -jnp.inf))[::-1]
    idx = jnp.clip(k.astype(jnp.int32) - 1, 0, n - 1)
    kth = sorted_freq[idx]
    keep = (freq >= kth) & (freq > 0)
    mask = jnp.outer(keep, keep).astype(jnp.float32)
    off = off * mask

    # Global min-max over the kept off-diagonal support (Alg. 2 line 5).
    # The minimum is anchored at 0: the raw CRM of any realistic window is
    # dominated by never-co-accessed (zero) pairs, so min = 0 in practice;
    # anchoring avoids degenerate all-equal-counts windows collapsing to
    # zero edges (mirrored by the Rust native engine, crm/native.rs).
    support = mask * (1.0 - eye)
    lo = jnp.float32(0.0)
    hi = jnp.max(jnp.where(support > 0, off, -jnp.float32(3.4e38)))
    hi = jnp.maximum(hi, 0.0)
    span = jnp.maximum(hi - lo, 1e-9)
    crm_norm = jnp.where(support > 0, (off - lo) / span, 0.0)

    crm_bin = (crm_norm > theta).astype(jnp.float32)
    return crm_norm, crm_bin, freq


def lower_crm(batch: int, n_items: int):
    """jit + lower the pipeline for a concrete (batch, n_items) shape."""
    x_spec = jax.ShapeDtypeStruct((batch, n_items), jnp.float32)
    s_spec = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(crm_pipeline).lower(x_spec, s_spec, s_spec)
