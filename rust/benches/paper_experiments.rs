//! `cargo bench` — one group per paper table/figure, exercising the
//! end-to-end policy runs the experiment harness uses (reduced request
//! counts so the suite completes in minutes; full-scale numbers come from
//! `akpc exp <id>` and are recorded in EXPERIMENTS.md).
//!
//! Uses the in-tree harness `akpc::util::benchkit` (offline env — no
//! criterion); output lines are `bench <group>/<name> ... med=...`.

use akpc::algo::CachePolicy;
use akpc::bench::sweep::{run_policy_set, EngineChoice, PolicyChoice};
use akpc::config::AkpcConfig;
use akpc::trace::generator::{netflix_like, spotify_like};
use akpc::util::benchkit::Group;

fn bench_cfg() -> AkpcConfig {
    AkpcConfig {
        n_servers: 100,
        ..Default::default()
    }
}

/// Fig. 5 — full policy-set comparison per dataset.
fn fig5() {
    let cfg = bench_cfg();
    let traces = [
        ("netflix", netflix_like(cfg.n_items, cfg.n_servers, 20_000, 1)),
        ("spotify", spotify_like(cfg.n_items, cfg.n_servers, 20_000, 1)),
    ];
    let g = Group::new("fig5_cost_comparison").iters(5);
    for (name, trace) in &traces {
        g.bench(name, || {
            run_policy_set(&cfg, trace, PolicyChoice::FIG5, EngineChoice::Native)
        });
    }
}

/// Fig. 6 — α / ρ single-point policy runs (the sweeps repeat these).
fn fig6() {
    let base = bench_cfg();
    let trace = netflix_like(base.n_items, base.n_servers, 20_000, 1);
    let g = Group::new("fig6_sensitivity_point").iters(5);
    for alpha in [0.6, 0.8, 1.0] {
        let cfg = AkpcConfig { alpha, ..base.clone() };
        g.bench(&format!("alpha_{alpha}"), || {
            run_policy_set(&cfg, &trace, PolicyChoice::SWEEP, EngineChoice::Native)
        });
    }
    for rho in [1.0, 10.0] {
        let cfg = AkpcConfig {
            lambda: rho,
            rho: 1.0,
            ..base.clone()
        };
        g.bench(&format!("rho_{rho}"), || {
            run_policy_set(&cfg, &trace, PolicyChoice::SWEEP, EngineChoice::Native)
        });
    }
}

/// Fig. 7 — hyperparameter single-point runs (θ, γ, ω).
fn fig7() {
    let base = bench_cfg();
    let trace = netflix_like(base.n_items, base.n_servers, 20_000, 1);
    let g = Group::new("fig7_hyperparameters").iters(5);
    for (name, cfg) in [
        ("theta_0.2", AkpcConfig { theta: 0.2, ..base.clone() }),
        ("gamma_0.85", AkpcConfig { gamma_approx: 0.85, ..base.clone() }),
        ("omega_5", AkpcConfig { omega: 5, ..base.clone() }),
        ("omega_10", AkpcConfig { omega: 10, ..base.clone() }),
    ] {
        g.bench(name, || {
            let mut p = PolicyChoice::Akpc.build(&cfg, EngineChoice::Native);
            akpc::sim::run(p.as_mut(), &trace, cfg.batch_size).total()
        });
    }
}

/// Fig. 8 — scalability points (servers / items / batch).
fn fig8() {
    let base = bench_cfg();
    let g = Group::new("fig8_scalability").iters(5);
    for m in [30u32, 600] {
        let cfg = AkpcConfig { n_servers: m, ..base.clone() };
        let trace = netflix_like(cfg.n_items, m, 20_000, 1);
        g.bench(&format!("servers_{m}"), || {
            let mut p = PolicyChoice::Akpc.build(&cfg, EngineChoice::Native);
            akpc::sim::run(p.as_mut(), &trace, cfg.batch_size).total()
        });
    }
    for n in [60u32, 3600] {
        let cfg = AkpcConfig { n_items: n, ..base.clone() };
        let trace = netflix_like(n, cfg.n_servers, 20_000, 1);
        g.bench(&format!("items_{n}"), || {
            let mut p = PolicyChoice::Akpc.build(&cfg, EngineChoice::Native);
            akpc::sim::run(p.as_mut(), &trace, cfg.batch_size).total()
        });
    }
    for bs in [50usize, 500] {
        let cfg = AkpcConfig { batch_size: bs, ..base.clone() };
        let trace = netflix_like(cfg.n_items, cfg.n_servers, 20_000, 1);
        g.bench(&format!("batch_{bs}"), || {
            let mut p = PolicyChoice::Akpc.build(&cfg, EngineChoice::Native);
            akpc::sim::run(p.as_mut(), &trace, cfg.batch_size).total()
        });
    }
}

/// Fig. 9(b) — clique-generation tick latency vs item-universe size.
fn fig9b() {
    let base = bench_cfg();
    let g = Group::new("fig9b_clique_generation").iters(5);
    for n in [100u32, 1_000, 10_000] {
        let cfg = AkpcConfig { n_items: n, ..base.clone() };
        let trace = netflix_like(n, cfg.n_servers, cfg.batch_size * 4, 1);
        g.bench(&format!("n_{n}"), || {
            let mut akpc = akpc::algo::Akpc::new(&cfg);
            for batch in trace.batches(cfg.batch_size) {
                akpc.end_batch(batch);
            }
            akpc.windows
        });
    }
}

fn main() {
    println!("== paper_experiments bench suite ==");
    fig5();
    fig6();
    fig7();
    fig8();
    fig9b();
}
