//! Micro-benches over the L3 hot paths (the §Perf targets):
//! request handling (Algorithm 5), CRM construction, clique generation,
//! XLA-vs-native CRM ablation, and trace generation.
//!
//! Throughput lines are printed alongside the raw timings so the §Perf
//! table in EXPERIMENTS.md can quote requests/s directly.

use akpc::algo::{Akpc, CachePolicy, NoPacking};
use akpc::clique::CliqueSet;
use akpc::config::AkpcConfig;
use akpc::crm::{diff_windows, native::build_native, CrmBuilder, CrmWindow};
use akpc::trace::generator::netflix_like;
use akpc::util::benchkit::Group;

fn request_path() {
    let cfg = AkpcConfig {
        n_servers: 100,
        ..Default::default()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 100_000, 1);

    let g = Group::new("request_path").iters(5);
    let s = g.bench("akpc_100k_requests", || {
        let mut p = Akpc::new(&cfg);
        for batch in trace.batches(cfg.batch_size) {
            for r in batch {
                p.handle_request(r);
            }
            p.end_batch(batch);
        }
        p.ledger().total()
    });
    println!(
        "  -> {:.0} requests/s (AKPC end-to-end incl. window ticks)",
        trace.len() as f64 / s.median_secs()
    );
    let s = g.bench("no_packing_100k_requests", || {
        let mut p = NoPacking::new(&cfg);
        for r in &trace.requests {
            p.handle_request(r);
        }
        p.ledger().total()
    });
    println!(
        "  -> {:.0} requests/s (NoPacking)",
        trace.len() as f64 / s.median_secs()
    );
}

fn crm_native() {
    let g = Group::new("crm_native_build").iters(10);
    for n in [64u32, 256, 1024] {
        let trace = netflix_like(n, 10, 256, 1);
        g.bench(&format!("n_{n}"), || {
            build_native(&trace.requests, n, 0.2, 0.1)
        });
    }
}

fn crm_xla_vs_native() {
    // Ablation: the AOT XLA artifact vs the native Rust path, same inputs.
    let g = Group::new("crm_engine_ablation").iters(10);
    for n in [64u32, 256] {
        let trace = netflix_like(n, 10, 256, 1);
        g.bench(&format!("native_n{n}"), || {
            build_native(&trace.requests, n, 0.2, 0.1)
        });
        match akpc::runtime::XlaCrmBuilder::new("artifacts") {
            Ok(mut xla) => {
                g.bench(&format!("xla_n{n}"), || {
                    xla.build(&trace.requests, n, 0.2, 0.1)
                });
            }
            Err(e) => println!("  (xla_n{n} skipped: {e})"),
        }
    }
}

fn clique_generation() {
    let g = Group::new("clique_generate").iters(10);
    for n in [64u32, 256, 1024] {
        let t1 = netflix_like(n, 10, 256, 1);
        let t2 = netflix_like(n, 10, 256, 2);
        let w1 = build_native(&t1.requests, n, 0.2, 1.0);
        let w2 = build_native(&t2.requests, n, 0.2, 1.0);
        let prev = CliqueSet::generate(
            &CliqueSet::new(),
            &w1,
            &diff_windows(&CrmWindow::default(), &w1),
            5,
            0.85,
            true,
            true,
        );
        g.bench(&format!("n_{n}"), || {
            CliqueSet::generate(
                &prev,
                &w2,
                &diff_windows(&w1, &w2),
                5,
                0.85,
                true,
                true,
            )
        });
    }
}

fn trace_generation() {
    let g = Group::new("trace_generate").iters(5);
    let s = g.bench("netflix_100k", || netflix_like(60, 600, 100_000, 1).len());
    println!(
        "  -> {:.0} requests generated/s",
        100_000.0 / s.median_secs()
    );
}

fn sharded_replay() {
    // Serving-path scaling: parallel replay through the sharded
    // coordinator at 1/2/4/8 shards (async window ticks — the throughput
    // configuration; see DESIGN.md §2.3).
    use akpc::sim::{replay_sharded, ReplayMode};
    let cfg = AkpcConfig {
        n_servers: 96,
        ..Default::default()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 50_000, 1);
    let g = Group::new("sharded_replay").iters(3);
    for n_shards in [1usize, 2, 4, 8] {
        let s = g.bench(&format!("shards_{n_shards}_50k"), || {
            replay_sharded(
                &cfg,
                akpc::runtime::CrmEngine::Native,
                &trace,
                n_shards,
                ReplayMode::Parallel,
            )
            .expect("replay failed")
            .metrics
            .ledger
            .total()
        });
        println!(
            "  -> {:.0} requests/s through {n_shards} shard(s)",
            trace.len() as f64 / s.median_secs()
        );
    }
}

fn main() {
    println!("== hot_paths bench suite ==");
    request_path();
    crm_native();
    crm_xla_vs_native();
    clique_generation();
    trace_generation();
    sharded_replay();
}
