//! End-to-end integration over the simulator, the experiment harness and
//! the online coordinator — small-scale versions of the paper's
//! experiments asserting the *shape* of each result.

use akpc::bench::experiments::{self, ExpOptions};
use akpc::bench::sweep::{run_policy_set, EngineChoice, PolicyChoice, RelativeCosts};
use akpc::config::AkpcConfig;
use akpc::coordinator::{Coordinator, ServeRequest};
use akpc::runtime::CrmEngine;
use akpc::trace::generator::{netflix_like, spotify_like};

fn base_cfg() -> AkpcConfig {
    AkpcConfig::default() // Table II
}

fn opts(n: usize) -> ExpOptions {
    ExpOptions {
        n_requests: n,
        engine: EngineChoice::Native,
        seed: 21,
    }
}

#[test]
fn fig5_ordering_on_both_datasets() {
    let cfg = base_cfg();
    let r = experiments::fig5(&opts(30_000), &cfg);
    for ds in ["Netflix", "Spotify"] {
        let v = |p: &str| r.rel_total(ds, p).unwrap();
        assert!((v("OPT") - 1.0).abs() < 1e-9);
        assert!(v("AKPC") < v("PackCache"), "{ds}: AKPC !< PackCache");
        assert!(v("AKPC") < v("NoPacking"), "{ds}: AKPC !< NoPacking");
        assert!(v("PackCache") < v("NoPacking"), "{ds}: PackCache !< NoPacking");
        assert!(v("DP_Greedy") < v("NoPacking"), "{ds}: DP_Greedy !< NoPacking");
        // "Even the AKPC variant without CS and ACM outperforms all
        // existing baselines" (paper §V-C-1).
        assert!(
            v("AKPC w/o CS, w/o ACM") < v("PackCache"),
            "{ds}: reduced AKPC !< PackCache"
        );
    }
}

#[test]
fn fig6b_akpc_stays_best_across_rho() {
    // Paper: AKPC incurs the lowest cost across all cost ratios, and keeps
    // a clear edge over the 2-packing SOTA at ρ = 10 (~30%/27% there; the
    // exact growth-vs-ρ trend depends on the C_P attribution subtleties
    // discussed in EXPERIMENTS.md §Fig6b).
    let cfg = base_cfg();
    let r = experiments::fig6b(&opts(20_000), &cfg);
    for ds in ["Netflix", "Spotify"] {
        let akpc = r.series_for(ds, "AKPC").unwrap();
        let np = r.series_for(ds, "NoPacking").unwrap();
        let pc = r.series_for(ds, "PackCache").unwrap();
        for (i, a) in akpc.iter().enumerate() {
            // 2% tolerance vs NoPacking: at large ρ the C_P component (the
            // packing-driven saving under extension accounting) becomes
            // negligible and the two converge on transfer-noise.
            assert!(
                *a <= np[i] * 1.02 && *a <= pc[i] + 1e-9,
                "{ds}: AKPC not best at rho index {i} ({a:.3} vs np {:.3} pc {:.3})",
                np[i],
                pc[i]
            );
        }
        // The edge over PackCache persists at the largest ρ.
        let edge = 1.0 - akpc.last().unwrap() / pc.last().unwrap();
        assert!(edge > 0.05, "{ds}: edge over PackCache at rho=10 is {edge:.3}");
    }
}

#[test]
fn fig8c_batch_size_helps() {
    let cfg = base_cfg();
    let r = experiments::fig8c(&opts(30_000), &cfg);
    let akpc = r.series_for("Netflix", "AKPC").unwrap();
    // Paper: increasing batch size 50 -> 500 reduces relative cost.
    assert!(
        akpc.last().unwrap() < &akpc[0],
        "batch sweep not decreasing: {akpc:?}"
    );
}

#[test]
fn fig9a_acm_shifts_distribution_up() {
    let cfg = base_cfg();
    let r = experiments::fig9a(&opts(20_000), &cfg);
    for ds in ["Netflix", "Spotify"] {
        let base = r.mean_size(ds, "AKPC w/o CS, w/o ACM").unwrap();
        let full = r.mean_size(ds, "AKPC (Proposed)").unwrap();
        // ACM merges near-cliques to ω -> mean size goes up vs the capped
        // w/o-ACM variant; vs the uncapped variant it must stay within ω.
        let no_acm = r.mean_size(ds, "AKPC w/o ACM").unwrap();
        assert!(
            full > no_acm,
            "{ds}: ACM did not shift sizes up ({no_acm:.2} -> {full:.2})"
        );
        assert!(base > 0.0 && full > 0.0);
    }
}

#[test]
fn dp_greedy_offline_beats_online_packcache() {
    // Offline knowledge should not hurt (paper Fig. 5: DP_Greedy below
    // PackCache).
    let cfg = base_cfg();
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 30_000, 22);
    let reports = run_policy_set(
        &cfg,
        &trace,
        &[PolicyChoice::DpGreedy, PolicyChoice::PackCache, PolicyChoice::Opt],
        EngineChoice::Native,
    );
    let rel = RelativeCosts::from_reports(&reports);
    assert!(rel.of("DP_Greedy").unwrap() <= rel.of("PackCache").unwrap());
}

#[test]
fn coordinator_replay_matches_simulator() {
    // The online coordinator and the offline simulator implement the same
    // Algorithm 1: replaying a trace through the service must produce the
    // same ledger as sim::run.
    let cfg = AkpcConfig {
        n_servers: 40,
        ..base_cfg()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 5_000, 23);

    let coord = Coordinator::start(cfg.clone(), CrmEngine::Native, 1).unwrap();
    for r in &trace.requests {
        coord
            .serve(ServeRequest {
                items: r.items.clone(),
                server: r.server,
                time: Some(r.time),
            })
            .unwrap();
    }
    let m = coord.shutdown();

    let mut policy = akpc::algo::Akpc::new(&cfg);
    let rep = akpc::sim::run(&mut policy, &trace, cfg.batch_size);

    assert!(
        (m.ledger.total() - rep.ledger.total()).abs() < 1e-6,
        "coordinator {} vs simulator {}",
        m.ledger.total(),
        rep.ledger.total()
    );
    assert_eq!(m.ledger.full_hits, rep.ledger.full_hits);
}

#[test]
fn spotify_churn_stresses_adjustment_without_breaking() {
    let cfg = base_cfg();
    let trace = spotify_like(cfg.n_items, cfg.n_servers, 60_000, 24);
    let mut akpc = akpc::algo::Akpc::new(&cfg);
    let rep = akpc::sim::run(&mut akpc, &trace, cfg.batch_size);
    akpc.cliques().check_invariants().unwrap();
    assert_eq!(rep.ledger.requests, 60_000);
    assert!(rep.ledger.hit_rate() > 0.3, "churn collapsed the hit rate");
}

#[test]
fn ablation_crm_window_span_helps() {
    // DESIGN.md §6: single-batch CRMs fragment cliques; the sliding
    // multi-batch window must not be worse.
    let cfg = base_cfg();
    let ab = experiments::ablations(&opts(15_000), &cfg);
    let window = ab
        .iter()
        .find(|r| r.id.contains("CRM window"))
        .expect("window ablation present");
    let akpc = window.series_for("Netflix", "AKPC").unwrap();
    assert!(
        akpc.last().unwrap() <= &(akpc[0] * 1.02),
        "wider CRM window should not hurt: {akpc:?}"
    );
}

#[test]
fn adversarial_cli_table_is_tight() {
    let cfg = base_cfg();
    for s in 1..=cfg.omega {
        let (measured, bound) = experiments::adversarial_ratio(&cfg, s, 50);
        assert!((measured - bound).abs() < 1e-9);
    }
}
