//! Unified Run API acceptance tests (DESIGN.md §8):
//!
//! * registry round-trip — every registered name builds, names are
//!   unique, the FIG5/SWEEP policy sets resolve;
//! * `RunSpec` validation errors — unknown policy (enumerating valid
//!   names), sharded driver with an unsupported policy, missing
//!   workload;
//! * facade equivalence — `RunSpec` totals pin to the legacy `sim::run`
//!   entry point within 1e-9 relative, single-leader and 4-shard;
//! * config-derivation regression — sharded and single-leader runs of
//!   the same spec see identical effective configs.

use akpc::bench::sweep::{EngineChoice, PolicyChoice};
use akpc::config::AkpcConfig;
use akpc::run::{
    Driver, JsonlSink, Observer, PolicyRegistry, RunSpec, WindowEvent, WorkloadData,
};
use akpc::scenario::ScenarioSpec;
use akpc::sim::{self, ReplayMode};
use akpc::trace::generator::{netflix_like, TraceKind};

fn small_cfg() -> AkpcConfig {
    AkpcConfig {
        n_items: 40,
        n_servers: 24,
        crm_top_frac: 1.0,
        ..Default::default()
    }
}

fn small_scenario() -> ScenarioSpec {
    ScenarioSpec::from_toml_str(
        r#"
        name = "api"
        seed = 11
        n_items = 30
        n_servers = 12

        [phase]
        label = "a"
        generator = "netflix"
        requests = 900

        [phase]
        label = "b"
        generator = "netflix"
        requests = 450
        flash_frac = 0.4
        flash_items = 3
        "#,
    )
    .unwrap()
}

#[test]
fn registry_round_trip_every_name_builds_and_runs() {
    let registry = PolicyRegistry::builtin();
    let cfg = small_cfg();
    let names = registry.names();
    let unique: std::collections::HashSet<_> = names.iter().collect();
    assert_eq!(unique.len(), names.len(), "duplicate names: {names:?}");

    let trace = netflix_like(cfg.n_items, cfg.n_servers, 600, 5);
    for name in &names {
        let mut policy = registry.build(name, &cfg, EngineChoice::Native).unwrap();
        let rep = sim::run(policy.as_mut(), &trace, cfg.batch_size);
        assert_eq!(rep.ledger.requests, 600, "{name} dropped requests");
        assert!(rep.ledger.total() > 0.0, "{name} accrued no cost");
    }

    // The sweep policy sets resolve to registry entries.
    for &choice in PolicyChoice::FIG5.iter().chain(PolicyChoice::SWEEP) {
        let entry = registry
            .get(choice.cli_name())
            .unwrap_or_else(|| panic!("{choice:?} ({}) not registered", choice.cli_name()));
        assert_eq!(entry.choice(), Some(choice));
    }
}

#[test]
fn validation_errors_are_actionable() {
    let registry = PolicyRegistry::builtin();

    let err = RunSpec::new()
        .generated(TraceKind::Netflix, 100)
        .policy("lru")
        .validate(&registry)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown policy `lru`"), "{err}");
    assert!(
        err.contains("no-packing") && err.contains("akpc"),
        "error should enumerate valid names: {err}"
    );

    let err = RunSpec::new()
        .config(small_cfg())
        .generated(TraceKind::Netflix, 100)
        .policy("dp-greedy")
        .sharded(2, ReplayMode::Ordered)
        .validate(&registry)
        .unwrap_err()
        .to_string();
    assert!(err.contains("does not support the sharded driver"), "{err}");

    let err = RunSpec::new().validate(&registry).unwrap_err().to_string();
    assert!(err.contains("needs a workload"), "{err}");
}

#[test]
fn facade_matches_legacy_sim_run_single_leader_and_4_shard() {
    let cfg = small_cfg();
    let registry = PolicyRegistry::builtin();
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 4_000, 41);

    let mut legacy_policy = akpc::algo::Akpc::new(&cfg);
    let legacy = sim::run(&mut legacy_policy, &trace, cfg.batch_size);
    let tol = 1e-9 * legacy.ledger.total().abs().max(1.0);

    let base = RunSpec::new()
        .config(cfg.clone())
        .inline_trace(trace.clone())
        .policy("akpc")
        .engine(EngineChoice::Native);

    let single = base.clone().execute(&registry).unwrap();
    assert_eq!(single.n_shards, 0);
    assert_eq!(single.ledger.requests, legacy.ledger.requests);
    assert_eq!(single.ledger.transfers, legacy.ledger.transfers);
    assert!(
        (single.total() - legacy.ledger.total()).abs() <= tol,
        "single-leader facade {} vs legacy {}",
        single.total(),
        legacy.ledger.total()
    );

    let sharded = base
        .sharded(4, ReplayMode::Ordered)
        .execute(&registry)
        .unwrap();
    assert_eq!(sharded.n_shards, 4);
    assert_eq!(sharded.shard_ledgers().len(), 4);
    assert!(
        (sharded.total() - legacy.ledger.total()).abs() <= tol,
        "4-shard facade {} vs legacy {}",
        sharded.total(),
        legacy.ledger.total()
    );
}

#[test]
fn sharded_and_single_leader_specs_derive_identical_configs() {
    // Regression for the old split derivation: the single-leader
    // scenario path built cell_cfg at the call site while
    // run_phased_sharded cloned-and-overrode internally. Both now come
    // from RunSpec::validate.
    let registry = PolicyRegistry::builtin();
    let base = RunSpec::new()
        .config(small_cfg()) // 40×24 base; scenario universe is 30×12
        .scenario(small_scenario(), 1.0)
        .policy("akpc");

    let single = base.clone().validate(&registry).unwrap();
    let sharded = base
        .clone()
        .sharded(4, ReplayMode::Ordered)
        .validate(&registry)
        .unwrap();
    assert_eq!(single.effective_config(), sharded.effective_config());
    assert_eq!(single.effective_config().n_items, 30);
    assert_eq!(single.effective_config().n_servers, 12);

    // with_policy rebinds without re-materializing the workload and
    // still enforces driver capabilities.
    let rebound = single.with_policy(&registry, "no-packing").unwrap();
    assert_eq!(rebound.policy(), "no-packing");
    assert!(sharded.with_policy(&registry, "opt").is_err());
}

#[test]
fn scenario_outcome_carries_phases_and_metrics() {
    let registry = PolicyRegistry::builtin();
    let base = RunSpec::new()
        .scenario(small_scenario(), 1.0)
        .policy("akpc");

    let single = base.clone().execute(&registry).unwrap();
    assert_eq!(single.phases.len(), 2);
    assert!(single.metrics.is_none());
    assert!(single.clique_hist.is_some(), "AKPC tracks cliques");
    let phase_sum: f64 = single.phases.iter().map(|p| p.ledger.total()).sum();
    assert!(
        (phase_sum - single.total()).abs() <= 1e-9 * single.total().abs().max(1.0),
        "phases {phase_sum} != total {}",
        single.total()
    );

    let sharded = base
        .sharded(2, ReplayMode::Ordered)
        .execute(&registry)
        .unwrap();
    assert_eq!(sharded.phases.len(), 2);
    assert_eq!(sharded.shard_ledgers().len(), 2);
    assert!(
        (sharded.total() - single.total()).abs() <= 1e-9 * single.total().abs().max(1.0),
        "sharded scenario {} vs single-leader {}",
        sharded.total(),
        single.total()
    );
    // Both report through the same outcome surface.
    assert!(sharded.row().contains("2-shard/ordered"));
    akpc::util::json::parse(&sharded.to_json().to_string()).unwrap();
    akpc::util::json::parse(&single.to_json().to_string()).unwrap();
}

#[test]
fn baseline_policies_report_untracked_histograms() {
    let registry = PolicyRegistry::builtin();
    let cfg = small_cfg();
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 1_000, 3);
    let spec = RunSpec::new()
        .config(cfg)
        .inline_trace(trace)
        .engine(EngineChoice::Native);

    let np = spec.clone().policy("no-packing").execute(&registry).unwrap();
    assert!(np.clique_hist.is_none(), "NoPacking does not pack");
    let opt = spec.clone().policy("opt").execute(&registry).unwrap();
    assert!(opt.clique_hist.is_none(), "OPT's packing is per-request, untracked");
    let pc = spec.policy("packcache").execute(&registry).unwrap();
    assert!(pc.clique_hist.is_some(), "PackCache tracks pairs");
}

#[test]
fn observers_stream_windows_and_jsonl_parses() {
    struct Count {
        windows: u64,
        done: usize,
    }
    impl Observer for Count {
        fn on_window(&mut self, ev: &WindowEvent<'_>) {
            self.windows += 1;
            self.done = ev.requests_done;
        }
    }

    let registry = PolicyRegistry::builtin();
    let cfg = small_cfg();
    let spec = RunSpec::new()
        .config(cfg.clone())
        .generated(TraceKind::Netflix, 1_000)
        .policy("packcache");

    let mut count = Count { windows: 0, done: 0 };
    spec.run(&registry, &mut count).unwrap();
    assert_eq!(count.windows, 5, "1000 requests / batch {}", cfg.batch_size);
    assert_eq!(count.done, 1_000);

    let mut sink = JsonlSink::new(Vec::new());
    spec.run(&registry, &mut sink).unwrap();
    let text = String::from_utf8(sink.into_inner()).unwrap();
    let lines: Vec<_> = text.lines().collect();
    assert_eq!(lines.len(), 6, "5 window events + 1 done event");
    for line in &lines {
        let v = akpc::util::json::parse(line).unwrap();
        assert!(v.get("event").is_some());
    }
    assert!(lines.last().unwrap().contains("\"done\""));
}

#[test]
fn workload_data_exposes_materialization() {
    let registry = PolicyRegistry::builtin();
    let prepared = RunSpec::new()
        .config(small_cfg())
        .generated(TraceKind::Spotify, 700)
        .policy("no-packing")
        .validate(&registry)
        .unwrap();
    match prepared.workload() {
        WorkloadData::Trace(t) => assert_eq!(t.len(), 700),
        WorkloadData::Scenario(_) => panic!("generated workloads are traces"),
    }
    assert!(matches!(prepared.driver(), Driver::SingleLeader));
    assert_eq!(prepared.policy(), "no-packing");
}
