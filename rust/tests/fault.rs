//! End-to-end fault-tolerance tests (DESIGN.md §14):
//!
//! 1. **Property sweep** — 30 seeded random fault plans through the
//!    offline supervisor: every run converges (all requests served,
//!    duplicates rejected) and the ledger equals the never-faulted
//!    oracle plus exactly the recovery recharge, within 1e-9 relative.
//! 2. **Checkpoint restart over the wire** — daemon A ingests half a
//!    trace through a real socket and drains (writing its final
//!    checkpoint); daemon B restores from the slot, the retrying client
//!    resends the *full* trace, the resume handshake skips exactly the
//!    served half, and the merged ledger matches the offline sharded
//!    replay of the whole trace.
//! 3. **Live shard panic** — an injected shard panic mid-stream is
//!    recovered in place by the replay thread; `admitted == served`.
//! 4. **Overload shedding** — with `shed_depth` set and the packer
//!    stalled, queued chunks shed to pass-through;
//!    `admitted == served + shed`.
//!
//! The fault registry and the coordinator reply timeout are
//! process-global, so every test here serializes on one mutex.

use std::sync::Mutex;

use akpc::config::AkpcConfig;
use akpc::fault::{
    arm, disarm_all, read_from_dir, run_fault_plan, FaultAction, FaultPlan, FaultRunOptions,
};
use akpc::run::EngineChoice;
use akpc::serve::{ingest_trace, IngestOptions, ServeConfig, ServeDaemon, ServeOptions};
use akpc::sim::{replay_sharded_stream, ReplayMode};
use akpc::trace::generator;
use akpc::trace::model::Trace;
use akpc::trace::stream::MemorySource;
use akpc::util::tempdir::TempDir;

static LOCK: Mutex<()> = Mutex::new(());

fn fault_cfg() -> AkpcConfig {
    AkpcConfig {
        n_items: 24,
        n_servers: 6,
        batch_size: 12,
        ..Default::default()
    }
}

fn serve_cfg(cfg: &AkpcConfig, shards: usize) -> ServeConfig {
    ServeConfig {
        shards,
        slack: 0.5,
        chunk: 64,
        akpc: cfg.clone(),
        ..Default::default()
    }
}

fn run(
    cfg: &AkpcConfig,
    n_shards: usize,
    plan: FaultPlan,
    trace: &Trace,
) -> akpc::fault::FaultRunReport {
    let mut opts = FaultRunOptions::new(
        cfg.clone(),
        EngineChoice::Native.to_engine(),
        n_shards,
        plan,
    );
    opts.stall_ms = 150;
    opts.reply_timeout_ms = 50;
    run_fault_plan(&opts, &trace.requests).expect("fault run")
}

fn ledger_matches(live: &akpc::cache::CostLedger, offline: &akpc::cache::CostLedger, what: &str) {
    let tol = |x: f64| 1e-9 * x.abs().max(1.0);
    assert!(
        (live.total() - offline.total()).abs() <= tol(offline.total()),
        "{what}: total {} vs {}",
        live.total(),
        offline.total()
    );
    assert_eq!(live.requests, offline.requests, "{what}: request counts");
    assert_eq!(live.full_hits, offline.full_hits, "{what}: full hits");
    assert_eq!(live.transfers, offline.transfers, "{what}: transfers");
}

/// 1. The exactness contract over 30 random plans: total - recharge
///    lands on the oracle total, and every request is served exactly
///    once no matter what the plan injected.
#[test]
fn thirty_seed_fault_plans_converge_and_account_exactly() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let cfg = fault_cfg();
    let n = 180;
    let n_shards = 3;
    let trace = generator::netflix_like(cfg.n_items, cfg.n_servers, n, 11);

    let oracle = run(&cfg, n_shards, FaultPlan::new(Vec::new()), &trace);
    assert_eq!(oracle.recoveries, 0);
    assert_eq!(oracle.snapshot.served, n as u64);

    let n_windows = (n / cfg.batch_size) as u64;
    for seed in 0..30u64 {
        let plan = FaultPlan::random(seed, 2, n_windows, n_shards);
        let spec = plan.spec();
        let r = run(&cfg, n_shards, plan, &trace);
        assert_eq!(
            r.snapshot.served, n as u64,
            "plan `{spec}`: every request must be served exactly once"
        );
        assert_eq!(r.resubmitted, r.recoveries, "plan `{spec}`");
        let adjusted = r.total_cost - r.recharges;
        let tol = 1e-9 * oracle.total_cost.abs().max(1.0);
        assert!(
            (adjusted - oracle.total_cost).abs() <= tol,
            "plan `{spec}`: total {} - recharge {} = {adjusted}, oracle {}",
            r.total_cost,
            r.recharges,
            oracle.total_cost
        );
    }
}

/// 2. Socket-level restart from checkpoint: serve half, drain, restore,
///    resend everything, and land on the offline ledger of the full
///    trace — exactly-once across the restart.
#[test]
fn checkpoint_restart_resumes_exactly_over_the_wire() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    disarm_all();
    let cfg = fault_cfg();
    let n = 1_200;
    let half = n / 2;
    let shards = 2;
    let trace = generator::netflix_like(cfg.n_items, cfg.n_servers, n, 23);
    let dir = TempDir::new("fault-ckpt").expect("tempdir");

    let offline = {
        let mut src = MemorySource::new(&trace);
        replay_sharded_stream(
            &cfg,
            EngineChoice::Native.to_engine(),
            &mut src,
            shards,
            ReplayMode::Ordered,
        )
        .expect("offline replay")
    };

    let opts = ServeOptions {
        listen: "127.0.0.1:0".into(),
        checkpoint_dir: Some(dir.path().to_string_lossy().into_owned()),
        ..Default::default()
    };

    // Daemon A: first half, then drain (which writes the final
    // checkpoint). Standing in for the kill -9 the CI chaos step does
    // at process level.
    let a = ServeDaemon::start(serve_cfg(&cfg, shards), opts.clone()).expect("daemon A");
    let ingest = IngestOptions::new(a.ingest_addr().to_string());
    let sent = ingest_trace(&trace.requests[..half], &ingest).expect("ingest A");
    assert_eq!((sent.sent, sent.skipped), (half as u64, 0));
    let report_a = a.drain().expect("drain A");
    assert_eq!(report_a.admission.admitted, half as u64);
    assert_eq!(report_a.metrics.served, half as u64);
    assert!(report_a.counters.checkpoints_written >= 1);
    assert!(read_from_dir(dir.path()).expect("slot parse").is_some());

    // Daemon B: restore, resend the FULL trace; the resume handshake
    // must skip exactly the half daemon A already served.
    let b = ServeDaemon::start(serve_cfg(&cfg, shards), opts).expect("daemon B");
    let ingest = IngestOptions::new(b.ingest_addr().to_string());
    let resent = ingest_trace(&trace.requests, &ingest).expect("ingest B");
    assert_eq!(
        (resent.sent, resent.skipped),
        ((n - half) as u64, half as u64),
        "resume handshake must dedup the served half"
    );
    let report_b = b.drain().expect("drain B");
    assert_eq!(report_b.admission.admitted, (n - half) as u64);
    assert_eq!(report_b.admission.rejected_late, 0);
    assert_eq!(
        report_b.metrics.served, n as u64,
        "merged epochs span both daemon lifetimes"
    );
    ledger_matches(&report_b.metrics.ledger, &offline.metrics.ledger, "restart");
}

/// 3. A shard panic injected mid-stream is recovered by the replay
///    thread without losing or duplicating a request.
#[test]
fn live_daemon_recovers_from_injected_shard_panic() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    disarm_all();
    let cfg = fault_cfg();
    let n = 300;
    let trace = generator::netflix_like(cfg.n_items, cfg.n_servers, n, 31);

    let daemon = ServeDaemon::start(
        serve_cfg(&cfg, 2),
        ServeOptions {
            listen: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )
    .expect("daemon");
    // Shard 1 panics on its 21st serve — mid-chunk, after state built up.
    arm("shard-serve", Some(1), FaultAction::Panic, 20);
    let ingest = IngestOptions::new(daemon.ingest_addr().to_string());
    ingest_trace(&trace.requests, &ingest).expect("ingest");
    let report = daemon.drain().expect("drain");
    disarm_all();

    assert_eq!(report.counters.recoveries, 1, "one fleet rebuild");
    assert_eq!(report.admission.admitted, n as u64);
    assert_eq!(
        report.metrics.served, n as u64,
        "admitted == served across the recovery"
    );
    assert_eq!(report.epochs, 2, "recovery retires the pre-fault epoch");
}

/// 4. Overload degradation: stall the first serve so admitted chunks
///    pile up, then watch every backlogged chunk shed at pass-through
///    cost. The drain identity is `admitted == served + shed`.
#[test]
fn overload_sheds_backlogged_chunks_to_pass_through() {
    let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
    disarm_all();
    let cfg = fault_cfg();
    let n = 50;
    let trace = generator::netflix_like(cfg.n_items, cfg.n_servers, n, 41);

    let mut scfg = serve_cfg(&cfg, 1);
    scfg.slack = 0.0;
    scfg.chunk = 1; // every request is its own chunk
    scfg.shed_depth = 1; // any backlog at all triggers shedding
    let daemon = ServeDaemon::start(
        scfg,
        ServeOptions {
            listen: "127.0.0.1:0".into(),
            ..Default::default()
        },
    )
    .expect("daemon");
    // Wedge the first serve long enough for the rest of the stream to
    // queue behind it.
    arm("shard-serve", None, FaultAction::Stall(std::time::Duration::from_millis(500)), 0);
    let ingest = IngestOptions::new(daemon.ingest_addr().to_string());
    ingest_trace(&trace.requests, &ingest).expect("ingest");
    let report = daemon.drain().expect("drain");
    disarm_all();

    let c = report.counters;
    assert!(c.shed_requests > 0, "backlog must shed: {c:?}");
    assert!(c.shed_items >= c.shed_requests);
    assert!(c.shed_cost > 0.0);
    assert_eq!(c.recoveries, 0, "a stall below the reply timeout is not a loss");
    assert_eq!(
        report.metrics.served + c.shed_requests,
        report.admission.admitted,
        "drain identity: admitted == served + shed"
    );
}
