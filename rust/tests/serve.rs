//! End-to-end tests for the live serving daemon (DESIGN.md §12):
//!
//! 1. **Live-ingest equivalence** — a trace streamed through a real TCP
//!    socket into a drained daemon lands on the same ledger as the
//!    offline sharded streaming replay of that trace, within 1e-9
//!    relative, for 1 and 4 shards.
//! 2. Admission semantics over the wire: in-slack reorder repaired,
//!    beyond-slack regression rejected, malformed lines counted.
//! 3. The HTTP endpoint: /healthz, /metrics, /drain, /reload.
//! 4. Hot-reload: invalid configs rejected (daemon untouched), valid
//!    live-knob changes applied, and a shard-count-only change routes
//!    through the stateful elastic handoff (DESIGN.md §13) — items
//!    cached before the resize still hit after it.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use akpc::config::AkpcConfig;
use akpc::run::{generated_source, EngineChoice};
use akpc::serve::{ServeConfig, ServeDaemon, ServeOptions};
use akpc::sim::{replay_sharded_stream, ReplayMode};
use akpc::trace::generator::TraceKind;
use akpc::trace::model::{Request, Trace};
use akpc::trace::stream::{MemorySource, TraceSource};

fn small_cfg() -> AkpcConfig {
    AkpcConfig {
        n_items: 30,
        n_servers: 12,
        batch_size: 50,
        ..Default::default()
    }
}

fn serve_cfg(cfg: &AkpcConfig, shards: usize) -> ServeConfig {
    ServeConfig {
        shards,
        slack: 0.5,
        chunk: 256,
        akpc: cfg.clone(),
        ..Default::default()
    }
}

fn start_daemon(scfg: ServeConfig, http: bool) -> ServeDaemon {
    ServeDaemon::start(
        scfg,
        ServeOptions {
            listen: "127.0.0.1:0".into(),
            http: http.then(|| "127.0.0.1:0".to_string()),
            ..Default::default()
        },
    )
    .expect("daemon start")
}

/// Write requests as text frames over one socket, then close the write
/// side so the daemon's handler sees EOF.
fn send_text_frames(addr: std::net::SocketAddr, reqs: &[Request]) {
    let stream = TcpStream::connect(addr).expect("connect ingest");
    let mut out = std::io::BufWriter::new(&stream);
    for r in reqs {
        write!(out, "{} {}", r.time, r.server).unwrap();
        for it in &r.items {
            write!(out, " {it}").unwrap();
        }
        writeln!(out).unwrap();
    }
    out.flush().unwrap();
    drop(out);
    stream.shutdown(std::net::Shutdown::Write).unwrap();
}

/// Poll until every submitted frame reached admission (the socket pump
/// is asynchronous; drain must not race it).
fn await_submitted(daemon: &ServeDaemon, expect: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = daemon.admission_stats();
        let seen = s.admitted + s.rejected_late + s.rejected_malformed;
        if seen >= expect {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "timed out: {seen}/{expect} frames reached admission"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Poll the merged scrape until the coordinator has *served* `expect`
/// requests. `await_submitted` only proves frames reached admission —
/// not enough when a test must pin which coordinator epoch handled
/// them (the reorder buffer may still be holding the frames).
fn await_served(daemon: &ServeDaemon, expect: u64) {
    let needle = format!("akpc_requests_served_total {expect}\n");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if daemon.metrics_text().expect("scrape").contains(&needle) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for `{}`",
            needle.trim_end()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn assert_ledgers_match(
    live: &akpc::cache::CostLedger,
    offline: &akpc::cache::CostLedger,
    what: &str,
) {
    let tol = |x: f64| 1e-9 * x.abs().max(1.0);
    assert!(
        (live.total() - offline.total()).abs() <= tol(offline.total()),
        "{what}: total {} vs offline {}",
        live.total(),
        offline.total()
    );
    assert!(
        (live.c_t - offline.c_t).abs() <= tol(offline.c_t),
        "{what}: C_T {} vs {}",
        live.c_t,
        offline.c_t
    );
    assert!(
        (live.c_p - offline.c_p).abs() <= tol(offline.c_p),
        "{what}: C_P {} vs {}",
        live.c_p,
        offline.c_p
    );
    assert_eq!(live.requests, offline.requests, "{what}: request counts");
    assert_eq!(live.full_hits, offline.full_hits, "{what}: full hits");
    assert_eq!(live.misses, offline.misses, "{what}: misses");
    assert_eq!(live.transfers, offline.transfers, "{what}: transfers");
}

/// The tentpole pin: socket → admission → replay → drain reproduces the
/// offline sharded streaming replay exactly, for 1 and 4 shards.
#[test]
fn live_ingest_matches_offline_replay() {
    let cfg = small_cfg();
    let n = 3_000;
    for shards in [1usize, 4] {
        // Offline reference on the identical generated trace.
        let mut src = generated_source(TraceKind::Netflix, &cfg, n, 512).unwrap();
        let offline = replay_sharded_stream(
            &cfg,
            EngineChoice::Native.to_engine(),
            &mut src,
            shards,
            ReplayMode::Ordered,
        )
        .unwrap();

        // Live: same trace, re-generated, streamed through TCP.
        let mut src = generated_source(TraceKind::Netflix, &cfg, n, 512).unwrap();
        let trace = src.collect().unwrap();
        assert_eq!(trace.len(), n);
        let daemon = start_daemon(serve_cfg(&cfg, shards), false);
        send_text_frames(daemon.ingest_addr(), &trace.requests);
        await_submitted(&daemon, n as u64);
        let report = daemon.drain().expect("drain");

        assert_eq!(report.admission.admitted, n as u64);
        assert_eq!(report.admission.rejected_late, 0);
        assert_eq!(report.metrics.served, n as u64);
        assert_eq!(report.metrics.per_shard.len(), shards);
        assert_ledgers_match(
            &report.metrics.ledger,
            &offline.metrics.ledger,
            &format!("shards={shards}"),
        );
    }
}

/// An in-slack timestamp swap over the wire is repaired by admission, so
/// the ledger equals the offline replay of the *sorted* trace.
#[test]
fn in_slack_reorder_is_transparent() {
    let cfg = small_cfg();
    let mut src = generated_source(TraceKind::Netflix, &cfg, 600, 128).unwrap();
    let collected = src.collect().unwrap();

    // Re-time to strictly distinct 0.1-spaced stamps so the sorted order
    // is unambiguous, then swap adjacent pairs — a 0.1 regression, well
    // inside the daemon's 1.0 slack.
    let mut requests = collected.requests;
    for (i, r) in requests.iter_mut().enumerate() {
        r.time = i as f64 * 0.1;
    }
    let sorted = Trace {
        requests: requests.clone(),
        n_items: collected.n_items,
        n_servers: collected.n_servers,
        name: "reorder-fixture".into(),
    };
    let mut shuffled = requests;
    let mut i = 0;
    while i + 1 < shuffled.len() {
        shuffled.swap(i, i + 1);
        i += 3;
    }

    let mut offline_src = MemorySource::new(&sorted);
    let offline = replay_sharded_stream(
        &cfg,
        EngineChoice::Native.to_engine(),
        &mut offline_src,
        1,
        ReplayMode::Ordered,
    )
    .unwrap();

    let mut scfg = serve_cfg(&cfg, 1);
    scfg.slack = 1.0;
    let daemon = start_daemon(scfg, false);
    send_text_frames(daemon.ingest_addr(), &shuffled);
    await_submitted(&daemon, shuffled.len() as u64);
    let report = daemon.drain().expect("drain");

    assert_eq!(report.admission.admitted, shuffled.len() as u64);
    assert_eq!(report.admission.rejected_late, 0);
    assert_ledgers_match(&report.metrics.ledger, &offline.metrics.ledger, "reorder");
}

/// Wire-level admission rejections: malformed lines and beyond-slack
/// regressions are counted, never served, and never kill the socket.
#[test]
fn malformed_and_late_frames_rejected_over_wire() {
    let cfg = small_cfg();
    let mut scfg = serve_cfg(&cfg, 1);
    scfg.slack = 0.5;
    scfg.max_items = 4;
    let daemon = start_daemon(scfg, false);

    let stream = TcpStream::connect(daemon.ingest_addr()).unwrap();
    let mut out = std::io::BufWriter::new(&stream);
    writeln!(out, "1.0 0 1 2").unwrap(); // ok
    writeln!(out, "not-a-frame").unwrap(); // malformed: parse error
    writeln!(out, "2.0 0 1 2 3 4 5 6").unwrap(); // malformed: > max_items
    writeln!(out, "2.0 99 1").unwrap(); // malformed: server out of range
    writeln!(out, "5.0 1 3").unwrap(); // ok (watermark -> 5.0)
    writeln!(out, "1.5 1 3").unwrap(); // late: 1.5 < 5.0 - 0.5
    writeln!(out, "4.8 2 7").unwrap(); // ok: within slack
    out.flush().unwrap();
    drop(out);
    stream.shutdown(std::net::Shutdown::Write).unwrap();

    await_submitted(&daemon, 7);
    let report = daemon.drain().expect("drain");
    assert_eq!(report.admission.admitted, 4);
    assert_eq!(report.admission.rejected_malformed, 3);
    assert_eq!(report.admission.rejected_late, 1);
    assert_eq!(report.metrics.served, 4);
}

fn http_roundtrip(addr: std::net::SocketAddr, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect http");
    stream.write_all(request.as_bytes()).unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    resp
}

#[test]
fn http_endpoint_serves_health_metrics_and_drain() {
    let cfg = small_cfg();
    let daemon = start_daemon(serve_cfg(&cfg, 2), true);
    let http = daemon.http_addr().expect("http enabled");

    let health = http_roundtrip(http, "GET /healthz HTTP/1.0\r\n\r\n");
    assert!(health.starts_with("HTTP/1.0 200"), "{health}");
    assert!(health.ends_with("ok\n"), "{health}");

    send_text_frames(daemon.ingest_addr(), &[Request::new(vec![1, 2], 0, 1.0)]);
    await_submitted(&daemon, 1);

    let metrics = http_roundtrip(http, "GET /metrics HTTP/1.0\r\n\r\n");
    assert!(metrics.starts_with("HTTP/1.0 200"), "{metrics}");
    for family in [
        "akpc_requests_served_total",
        "akpc_cost_transfer_total",
        "akpc_admission_admitted_total",
        "akpc_serve_epochs 1",
        "akpc_shards 2",
    ] {
        assert!(metrics.contains(family), "missing {family} in:\n{metrics}");
    }

    let missing = http_roundtrip(http, "GET /nope HTTP/1.0\r\n\r\n");
    assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");

    let drain = http_roundtrip(http, "POST /drain HTTP/1.0\r\n\r\n");
    assert!(drain.starts_with("HTTP/1.0 202"), "{drain}");
    let report = daemon.join().expect("join after POST /drain");
    assert_eq!(report.metrics.served, 1);
    assert_eq!(report.epochs, 1);
}

/// Hot-reload: an invalid file is rejected (daemon keeps serving), and
/// each valid tier takes its own route — live knobs apply in place, a
/// shard-count-only change is a stateful resize, a coordinator-knob
/// change is a fresh epoch swap. Counters stay monotone across all of
/// them.
#[test]
fn reload_rejects_invalid_and_applies_valid_configs() {
    let cfg = small_cfg();
    let dir = std::env::temp_dir().join(format!("akpc-serve-reload-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.toml");
    let base = format!(
        "slack = 0.5\nshards = 1\n\n[akpc]\nn_items = {}\nn_servers = {}\nbatch_size = {}\n",
        cfg.n_items, cfg.n_servers, cfg.batch_size
    );
    std::fs::write(&path, &base).unwrap();

    let scfg = ServeConfig::from_toml_str(&base).unwrap();
    let daemon = ServeDaemon::start(
        scfg,
        ServeOptions {
            listen: "127.0.0.1:0".into(),
            http: None,
            config_path: Some(path.to_string_lossy().into_owned()),
            ..Default::default()
        },
    )
    .unwrap();

    send_text_frames(daemon.ingest_addr(), &[Request::new(vec![1], 0, 1.0)]);
    await_submitted(&daemon, 1);

    // Invalid: unknown policy must be rejected by the RunSpec probe.
    std::fs::write(&path, format!("policy = \"no-such-policy\"\n{base}")).unwrap();
    let err = daemon.reload().unwrap_err().to_string();
    assert!(err.contains("rejected"), "{err}");

    // Invalid: negative slack.
    std::fs::write(&path, base.replace("slack = 0.5", "slack = -1.0")).unwrap();
    assert!(daemon.reload().is_err());

    // Invalid: universe change is a restart, not a reload.
    std::fs::write(&path, base.replace("n_items = 30", "n_items = 31")).unwrap();
    let err = daemon.reload().unwrap_err().to_string();
    assert!(err.contains("universe"), "{err}");

    // Valid live-knob change.
    std::fs::write(&path, base.replace("slack = 0.5", "slack = 2.0")).unwrap();
    let summary = daemon.reload().expect("valid reload");
    assert!(summary.contains("slack=2"), "{summary}");

    // Valid shard-count-only change: the stateful elastic handoff, not
    // a fresh epoch (warmth is pinned end-to-end by
    // `live_resize_keeps_the_warm_cache_hot` below).
    std::fs::write(&path, base.replace("shards = 1", "shards = 2")).unwrap();
    let summary = daemon.reload().expect("shard reload");
    assert!(summary.contains("stateful resize"), "{summary}");

    send_text_frames(daemon.ingest_addr(), &[Request::new(vec![2], 1, 2.0)]);
    await_submitted(&daemon, 2);

    // Valid coordinator-knob change: a genuine fresh-state epoch swap,
    // counters monotone across it.
    let swapped = base.replace("shards = 1", "shards = 2").replace(
        &format!("batch_size = {}", cfg.batch_size),
        &format!("batch_size = {}", cfg.batch_size / 2),
    );
    std::fs::write(&path, swapped).unwrap();
    let summary = daemon.reload().expect("batch reload");
    assert!(summary.contains("new coordinator epoch"), "{summary}");

    send_text_frames(daemon.ingest_addr(), &[Request::new(vec![3], 2, 3.0)]);
    await_submitted(&daemon, 3);
    let report = daemon.drain().expect("drain");
    assert_eq!(report.epochs, 3, "resize + swap each retired an epoch");
    assert_eq!(report.metrics.served, 3, "counters span all three epochs");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The live-resize regression (DESIGN.md §13): items cached *before* a
/// shard-count-only reload still hit *after* it. Zero slack and
/// `chunk = 1` make admission ship every frame the moment it arrives,
/// so `await_served` pins the warm fetches to the donor fleet and the
/// re-requests to the resized one — the post-resize full hits can only
/// come from copies that crossed the handoff.
#[test]
fn live_resize_keeps_the_warm_cache_hot() {
    let cfg = small_cfg();
    let dir = std::env::temp_dir().join(format!("akpc-serve-resize-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.toml");
    let base = format!(
        "slack = 0.0\nchunk = 1\nshards = 1\n\n[akpc]\nn_items = {}\nn_servers = {}\nbatch_size = {}\n",
        cfg.n_items, cfg.n_servers, cfg.batch_size
    );
    std::fs::write(&path, &base).unwrap();

    let scfg = ServeConfig::from_toml_str(&base).unwrap();
    let daemon = ServeDaemon::start(
        scfg,
        ServeOptions {
            listen: "127.0.0.1:0".into(),
            http: None,
            config_path: Some(path.to_string_lossy().into_owned()),
            ..Default::default()
        },
    )
    .unwrap();

    // Warm the 1-shard fleet: the first touch of each item is a
    // transfer that leaves a copy behind (expiry Δt = ρλ/μ = 1 time
    // unit out). Servers 3 and 4 land on *different* shards after the
    // resize (3 % 2 = 1, 4 % 2 = 0), so both destination shards must
    // receive migrated state for the re-requests to hit.
    send_text_frames(
        daemon.ingest_addr(),
        &[Request::new(vec![7], 3, 1.0), Request::new(vec![8], 4, 1.2)],
    );
    await_served(&daemon, 2);
    let pre = daemon.metrics_text().expect("pre-resize scrape");
    assert!(
        pre.contains("akpc_full_hits_total 0\n"),
        "warm-up must be all misses:\n{pre}"
    );

    // Shard-count-only reload: the stateful elastic handoff.
    std::fs::write(&path, base.replace("shards = 1", "shards = 2")).unwrap();
    let summary = daemon.reload().expect("resize reload");
    assert!(summary.contains("carried over"), "{summary}");

    // Re-request the same items at the same servers inside the expiry
    // window. On a fresh-state swap these would be transfers again; on
    // the stateful resize they are pure hits on the migrated copies.
    send_text_frames(
        daemon.ingest_addr(),
        &[Request::new(vec![7], 3, 1.5), Request::new(vec![8], 4, 1.7)],
    );
    await_served(&daemon, 4);

    let report = daemon.drain().expect("drain");
    assert_eq!(report.epochs, 2);
    assert_eq!(report.metrics.served, 4);
    assert_eq!(
        report.metrics.ledger.transfers, 2,
        "only the warm-up should fetch"
    );
    assert_eq!(
        report.metrics.ledger.full_hits, 2,
        "pre-resize cached items must still hit after the live resize"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// The binary wire path: pipe a v2 chunk-framed `.akpt` byte stream
/// (exactly what `akpc ingest --binary` sends) and drain.
#[test]
fn binary_wire_format_roundtrips() {
    let cfg = small_cfg();
    let n = 500;
    let mut src = generated_source(TraceKind::Spotify, &cfg, n, 128).unwrap();
    let dir = std::env::temp_dir().join(format!("akpc-serve-bin-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.akpt");
    let written = akpc::trace::io::write_binary_chunked_from(&mut src, &path).unwrap();
    assert_eq!(written, n as u64);

    let mut src = generated_source(TraceKind::Spotify, &cfg, n, 128).unwrap();
    let offline = replay_sharded_stream(
        &cfg,
        EngineChoice::Native.to_engine(),
        &mut src,
        1,
        ReplayMode::Ordered,
    )
    .unwrap();

    let daemon = start_daemon(serve_cfg(&cfg, 1), false);
    let mut stream = TcpStream::connect(daemon.ingest_addr()).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    stream.write_all(&bytes).unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    await_submitted(&daemon, n as u64);
    let report = daemon.drain().expect("drain");
    assert_eq!(report.admission.admitted, n as u64);
    assert_ledgers_match(&report.metrics.ledger, &offline.metrics.ledger, "binary");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Offered-after-drop safety: dropping the daemon drains it; a second
/// daemon can bind immediately after.
#[test]
fn drop_drains_and_port_is_released() {
    let cfg = small_cfg();
    let addr;
    {
        let daemon = start_daemon(serve_cfg(&cfg, 1), false);
        addr = daemon.ingest_addr();
        send_text_frames(addr, &[Request::new(vec![0], 0, 0.0)]);
        await_submitted(&daemon, 1);
        // Dropped here without an explicit drain().
    }
    // The listener thread has exited; a fresh daemon starts cleanly.
    let daemon = start_daemon(serve_cfg(&cfg, 1), false);
    assert_ne!(daemon.ingest_addr().port(), 0);
    let report = daemon.drain().unwrap();
    assert_eq!(report.metrics.served, 0);
    let _ = addr;
}

/// `Trace` workload sanity for the helpers above (guards the fixture,
/// not the daemon).
#[test]
fn fixtures_are_well_formed() {
    let cfg = small_cfg();
    let mut src = generated_source(TraceKind::Netflix, &cfg, 100, 32).unwrap();
    let t: Trace = src.collect().unwrap();
    assert!(t
        .requests
        .windows(2)
        .all(|w| w[0].time <= w[1].time));
    assert!(t.requests.iter().all(|r| r.server < cfg.n_servers));
}
