//! Scenario Lab integration tests: the built-in library is deterministic
//! and well-formed, and the phased sharded replay is ledger-equivalent to
//! the single-leader driver while AKPC keeps beating the no-packing
//! baseline under non-stationary traffic (ISSUE 2 acceptance criteria).

use akpc::algo::{Akpc, NoPacking};
use akpc::config::AkpcConfig;
use akpc::runtime::CrmEngine;
use akpc::scenario::{self, run_phased, run_phased_sharded};
use akpc::sim::ReplayMode;

/// Every built-in scenario compiles deterministically under its fixed
/// seed and produces a valid, phase-monotone global timeline.
#[test]
fn builtin_scenarios_compile_deterministically() {
    for name in scenario::builtin_names() {
        let spec = scenario::builtin(name).expect("builtin resolves");
        let a = spec.compile(0.02).unwrap_or_else(|e| panic!("{name}: {e}"));
        let b = spec.compile(0.02).unwrap();
        assert_eq!(a.phases.len(), b.phases.len(), "{name}");
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            assert_eq!(
                pa.trace.requests, pb.trace.requests,
                "{name}/{} not deterministic",
                pa.label
            );
            pa.trace.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        // Phases join into one monotone timeline.
        a.concat_trace()
            .validate()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

/// Built-in scenarios run end-to-end through the single-leader driver,
/// with per-phase ledgers that sum to the run total.
#[test]
fn builtin_scenarios_replay_end_to_end() {
    let cfg = AkpcConfig {
        crm_top_frac: 1.0,
        ..Default::default()
    };
    for name in scenario::builtin_names() {
        let sc = scenario::builtin(name).unwrap().compile(0.02).unwrap();
        let cell_cfg = AkpcConfig {
            n_items: sc.n_items,
            n_servers: sc.n_servers,
            ..cfg.clone()
        };
        let run = run_phased(&mut Akpc::new(&cell_cfg), &sc, cell_cfg.batch_size);
        assert_eq!(
            run.total.requests as usize,
            sc.total_requests(),
            "{name}: dropped requests"
        );
        let phase_sum: f64 = run.phases.iter().map(|p| p.ledger.total()).sum();
        let tol = 1e-9 * run.total_cost().abs().max(1.0);
        assert!(
            (phase_sum - run.total_cost()).abs() <= tol,
            "{name}: phase ledgers sum {phase_sum} != total {}",
            run.total_cost()
        );
    }
}

/// The ISSUE 2 acceptance check, on the churn-heavy built-in: the phased
/// sharded replay (1 and 4 shards, ordered mode) matches the
/// single-leader driver's total within 1e-9 relative, and AKPC beats the
/// no-packing baseline on total cost.
#[test]
fn churn_storm_sharded_matches_single_leader() {
    let sc = scenario::builtin("churn-storm")
        .unwrap()
        .compile(0.15)
        .unwrap();
    let cfg = AkpcConfig {
        n_items: sc.n_items,
        n_servers: sc.n_servers,
        crm_top_frac: 1.0,
        ..Default::default()
    };

    let single = run_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size);
    let no_packing = run_phased(&mut NoPacking::new(&cfg), &sc, cfg.batch_size);
    assert!(
        single.total_cost() < no_packing.total_cost(),
        "AKPC {} not better than NoPacking {}",
        single.total_cost(),
        no_packing.total_cost()
    );

    for n_shards in [1usize, 4] {
        let sharded = run_phased_sharded(
            &cfg,
            CrmEngine::Native,
            &sc,
            n_shards,
            ReplayMode::Ordered,
        )
        .unwrap();
        assert_eq!(sharded.n_shards, n_shards);
        assert_eq!(sharded.total.requests, single.total.requests);
        assert_eq!(sharded.total.full_hits, single.total.full_hits);
        assert_eq!(sharded.total.transfers, single.total.transfers);
        let tol = 1e-9 * single.total_cost().abs().max(1.0);
        assert!(
            (sharded.total_cost() - single.total_cost()).abs() <= tol,
            "{n_shards}-shard total {} != single-leader {} (diff {:.3e})",
            sharded.total_cost(),
            single.total_cost(),
            (sharded.total_cost() - single.total_cost()).abs()
        );
        // Per-phase breakdowns line up too (same request partition).
        assert_eq!(sharded.phases.len(), single.phases.len());
        for (s, l) in sharded.phases.iter().zip(&single.phases) {
            assert_eq!(s.n_requests, l.n_requests, "phase {} request count", s.label);
            assert_eq!(s.ledger.requests, l.ledger.requests);
        }
    }
}

/// Scenario runs are reproducible: the same spec + seed + policy yields
/// bit-identical ledgers.
#[test]
fn scenario_replay_is_deterministic() {
    let sc = scenario::builtin("smoke").unwrap().compile(1.0).unwrap();
    let cfg = AkpcConfig {
        n_items: sc.n_items,
        n_servers: sc.n_servers,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let a = run_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size);
    let b = run_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size);
    assert_eq!(a.total.c_p, b.total.c_p);
    assert_eq!(a.total.c_t, b.total.c_t);
    assert_eq!(a.total.full_hits, b.total.full_hits);
    for (pa, pb) in a.phases.iter().zip(&b.phases) {
        assert_eq!(pa.ledger.c_t, pb.ledger.c_t);
        assert_eq!(pa.ledger.c_p, pb.ledger.c_p);
    }
}
