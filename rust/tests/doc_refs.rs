//! Documentation-citation checker (the CI `docs` job runs this): every
//! section citation in the Rust sources — e.g. `DESIGN.md §7.3` or
//! `EXPERIMENTS.md §Perf` — must point at a heading that actually
//! exists, so module docs can never drift ahead of (or outlive) the
//! design documents.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Collect the `§`-tokens of every markdown heading (`## §7 ...`,
/// `### §7.3 ...`, `## §Perf`).
fn headings(md: &str) -> BTreeSet<String> {
    md.lines()
        .filter(|l| l.trim_start().starts_with('#'))
        .filter_map(|l| {
            let tail = l.split('§').nth(1)?;
            let tok: String = tail
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '.')
                .collect();
            let tok = tok.trim_end_matches('.').to_string();
            (!tok.is_empty()).then(|| format!("§{tok}"))
        })
        .collect()
}

/// Extract every `<DOC> §TOKEN` citation from a source text.
/// Both `DESIGN.md §7.3` and the shorthand `DESIGN §8.4` count.
fn citations(src: &str, doc: &str) -> Vec<String> {
    let mut found = Vec::new();
    for pat in [format!("{doc}.md §"), format!("{doc} §")] {
        for (idx, _) in src.match_indices(&pat) {
            let tail = &src[idx + pat.len()..];
            let tok: String = tail
                .chars()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '.')
                .collect();
            let tok = tok.trim_end_matches('.').to_string();
            if !tok.is_empty() {
                found.push(format!("§{tok}"));
            }
        }
    }
    found
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn every_design_and_experiments_citation_resolves() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let repo_root = manifest.parent().expect("rust/ sits under the repo root");

    let docs = [
        ("DESIGN", repo_root.join("DESIGN.md")),
        ("EXPERIMENTS", repo_root.join("EXPERIMENTS.md")),
    ];
    let mut missing = Vec::new();
    let mut total_citations = 0usize;

    let mut files = Vec::new();
    for sub in ["src", "tests", "examples", "benches"] {
        rust_files(&manifest.join(sub), &mut files);
    }
    assert!(files.len() > 30, "walker found too few sources: {}", files.len());

    for (doc_name, doc_path) in &docs {
        let md = std::fs::read_to_string(doc_path)
            .unwrap_or_else(|e| panic!("{} must exist: {e}", doc_path.display()));
        let sections = headings(&md);
        assert!(
            !sections.is_empty(),
            "{doc_name}.md has no §-headings — checker misconfigured?"
        );
        for file in &files {
            let src = std::fs::read_to_string(file).unwrap();
            for cite in citations(&src, doc_name) {
                total_citations += 1;
                if !sections.contains(&cite) {
                    missing.push(format!(
                        "{}: cites {doc_name}.md {cite}, which has no such heading \
                         (have: {})",
                        file.display(),
                        sections.iter().cloned().collect::<Vec<_>>().join(" ")
                    ));
                }
            }
        }
    }
    assert!(
        total_citations > 20,
        "only {total_citations} citations found — extraction misconfigured?"
    );
    assert!(missing.is_empty(), "dangling doc citations:\n{}", missing.join("\n"));
}

#[test]
fn extraction_helpers_work() {
    let md = "# T\n## §1 One\n### §2.3 Two point three\n## §Perf\ntext §9 not a heading\n";
    let h = headings(md);
    assert!(h.contains("§1") && h.contains("§2.3") && h.contains("§Perf"));
    assert!(!h.contains("§9"));

    let src = "see DESIGN.md §2.3, and DESIGN §8.4; but EXPERIMENTS.md §Perf too.";
    assert_eq!(citations(src, "DESIGN"), vec!["§2.3", "§8.4"]);
    assert_eq!(citations(src, "EXPERIMENTS"), vec!["§Perf"]);
}
