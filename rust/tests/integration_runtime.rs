//! Cross-layer integration: the AOT XLA artifact (L1 Pallas kernel + L2
//! pipeline, compiled via PJRT) must agree with the native Rust CRM
//! engine on every decision-level output — the guarantee that lets the
//! experiments run on either engine interchangeably.
//!
//! Requires `make artifacts` *and* the `xla` cargo feature; tests are
//! skipped (with a message) when either is absent so `cargo test` works
//! from a fresh offline clone.

use akpc::crm::{sessionize, CrmBuilder, NativeCrmBuilder};
use akpc::runtime::{ArtifactRegistry, XlaCrmBuilder};
use akpc::trace::generator::{netflix_like, spotify_like};

fn artifacts_available() -> bool {
    cfg!(feature = "xla") && ArtifactRegistry::load("artifacts").is_ok()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!(
                "skipping: artifacts/ missing or built without the `xla` \
                 feature (run `make artifacts`, build with --features xla)"
            );
            return;
        }
    };
}

#[test]
fn registry_lists_built_artifacts() {
    require_artifacts!();
    let reg = ArtifactRegistry::load("artifacts").unwrap();
    assert!(!reg.specs().is_empty());
    // The base Table-II shape (n=60 -> 64, batch<=1024) must be covered.
    assert!(reg.select(60, 256).is_some());
    assert!(reg.select(60, 1024).is_some());
}

#[test]
fn xla_agrees_with_native_on_netflix_windows() {
    require_artifacts!();
    let mut xla = XlaCrmBuilder::new("artifacts").unwrap();
    let mut native = NativeCrmBuilder;
    let trace = netflix_like(60, 30, 2_000, 11);

    for (i, batch) in trace.requests.chunks(200).take(5).enumerate() {
        let txs = sessionize(batch, 1.0);
        for (theta, frac) in [(0.2f32, 1.0f32), (0.15, 1.0), (0.4, 0.5)] {
            let a = xla.build(&txs, 60, theta, frac);
            let b = native.build(&txs, 60, theta, frac);
            assert_eq!(a.active, b.active, "window {i}: kept set differs");
            assert_eq!(a.edges(), b.edges(), "window {i}: binary CRM differs");
            for &u in &a.active {
                for &v in &a.active {
                    let (x, y) = (a.weight(u, v), b.weight(u, v));
                    assert!(
                        (x - y).abs() < 1e-5,
                        "window {i}: norm differs at ({u},{v}): {x} vs {y}"
                    );
                }
            }
        }
    }
    assert!(xla.xla_windows > 0, "XLA path never exercised");
    assert_eq!(xla.native_windows, 0, "unexpected native fallback");
}

#[test]
fn xla_agrees_with_native_on_spotify_windows() {
    require_artifacts!();
    let mut xla = XlaCrmBuilder::new("artifacts").unwrap();
    let mut native = NativeCrmBuilder;
    let trace = spotify_like(60, 30, 2_000, 12);
    for batch in trace.requests.chunks(250).take(4) {
        let txs = sessionize(batch, 1.0);
        let a = xla.build(&txs, 60, 0.2, 1.0);
        let b = native.build(&txs, 60, 0.2, 1.0);
        assert_eq!(a.active, b.active);
        assert_eq!(a.edges(), b.edges());
    }
}

#[test]
fn oversized_windows_fall_back_to_native() {
    require_artifacts!();
    let mut xla = XlaCrmBuilder::new("artifacts").unwrap();
    // n larger than any artifact -> native fallback, same semantics.
    let trace = netflix_like(2000, 10, 1_500, 13);
    let txs = sessionize(&trace.requests, 1.0);
    let a = xla.build(&txs, 2000, 0.2, 0.1);
    let b = NativeCrmBuilder.build(&txs, 2000, 0.2, 0.1);
    assert_eq!(a.active, b.active);
    assert_eq!(a.edges(), b.edges());
    assert!(xla.native_windows > 0);
}

#[test]
fn end_to_end_policy_identical_across_engines() {
    require_artifacts!();
    // The headline integration check: a full simulated run makes *exactly*
    // the same caching decisions (hence costs) on both engines.
    use akpc::bench::sweep::{EngineChoice, PolicyChoice};
    let cfg = akpc::config::AkpcConfig {
        n_items: 60,
        n_servers: 50,
        ..Default::default()
    };
    let trace = netflix_like(60, 50, 10_000, 14);
    let mut native = PolicyChoice::Akpc.build(&cfg, EngineChoice::Native);
    let mut xla = PolicyChoice::Akpc.build(&cfg, EngineChoice::Xla);
    let rn = akpc::sim::run(native.as_mut(), &trace, cfg.batch_size);
    let rx = akpc::sim::run(xla.as_mut(), &trace, cfg.batch_size);
    assert_eq!(rn.ledger.c_t, rx.ledger.c_t, "C_T diverged across engines");
    assert_eq!(rn.ledger.c_p, rx.ledger.c_p, "C_P diverged across engines");
    assert_eq!(rn.ledger.full_hits, rx.ledger.full_hits);
}
