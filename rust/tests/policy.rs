//! Cross-policy differential test harness (ISSUE 10, DESIGN.md §15.3).
//!
//! Everything here is **auto-generated over the registry** — no hardcoded
//! policy-name lists in the differential sections — so any future
//! `PolicyRegistry::register` call inherits these invariants for free:
//!
//! 1. ledger accounting identities on every registered policy;
//! 2. `CostLedger::delta_from` consistency across a mid-run snapshot;
//! 3. bit-exact determinism across a rerun with the same seed;
//! 4. sharded == single-leader totals (1e-9) for `supports_sharded`;
//! 5. a brute-force offline oracle for micro-universes sandwiching every
//!    policy between a certified transfer floor and the exhaustive
//!    static-partition minimum;
//! 6. the headline ordering AKPC < BundleOpt < NoPacking on the
//!    flash-crowd scenario;
//! 7. registry-extension regression (toy policy registration, CLI list
//!    rows, unknown-policy enumeration, capability gating in `run`).

use akpc::algo::{CachePolicy, NoPacking, PackedCacheCore};
use akpc::bench::experiments::adversarial_bound_derived;
use akpc::bench::scenarios::scenario_suite_names;
use akpc::cache::{CostLedger, CostModel};
use akpc::config::AkpcConfig;
use akpc::run::{
    EngineChoice, NullObserver, PolicyCaps, PolicyEntry, PolicyRegistry, RunSpec,
};
use akpc::sim::ReplayMode;
use akpc::trace::generator::netflix_like;
use akpc::trace::model::{Request, Trace};
use akpc::util::Rng;

/// Config for the differential replays (small but multi-window).
fn diff_cfg() -> AkpcConfig {
    AkpcConfig {
        n_items: 24,
        n_servers: 8,
        ..Default::default()
    }
}

/// The single-leader replay loop (mirror of `sim::run` without reports):
/// offline policies see the trace up front, everyone replays in batches.
fn replay(policy: &mut dyn CachePolicy, trace: &Trace, batch: usize) {
    if policy.needs_offline_trace() {
        policy.prepare(trace);
    }
    for b in trace.batches(batch) {
        for r in b {
            policy.handle_request(r);
        }
        policy.end_batch(b);
    }
}

// ------------------------------------------------- differential harness

/// (i) Ledger accounting identities for *every* registered policy.
///
/// Note on the identity set: a request touching k > 1 packed groups
/// performs k transfers but counts as ONE miss, so the literal
/// "transfers + full_hits == requests" only holds for single-group
/// requests. The identities that hold universally are
/// `full_hits + misses == requests` and `transfers >= misses` (each miss
/// performs at least one transfer), which together imply
/// `transfers + full_hits >= requests`.
#[test]
fn ledger_identities_hold_for_every_registered_policy() {
    let cfg = diff_cfg();
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 4_000, 7);
    let total_items: u64 = trace.requests.iter().map(|r| r.items.len() as u64).sum();
    let registry = PolicyRegistry::builtin();
    for e in registry.iter() {
        let mut p = e.build(&cfg, EngineChoice::Native);
        replay(p.as_mut(), &trace, cfg.batch_size);
        let l = p.ledger();
        assert_eq!(l.requests, trace.len() as u64, "`{}`: request count", e.name());
        assert_eq!(
            l.full_hits + l.misses,
            l.requests,
            "`{}`: hits + misses != requests",
            e.name()
        );
        assert!(
            l.transfers >= l.misses,
            "`{}`: {} transfers < {} misses",
            e.name(),
            l.transfers,
            l.misses
        );
        assert!(
            l.transfers + l.full_hits >= l.requests,
            "`{}`: transfers+hits < requests",
            e.name()
        );
        // Non-negative rent and transfer spend; total is their sum.
        assert!(l.c_p >= 0.0 && l.c_t >= 0.0, "`{}`: negative cost", e.name());
        assert!(
            (l.total() - (l.c_p + l.c_t)).abs() < 1e-12,
            "`{}`: total != c_p + c_t",
            e.name()
        );
        // Every requested item is delivered (possibly alongside packed
        // extras — never fewer).
        assert_eq!(l.items_requested, total_items, "`{}`: items_requested", e.name());
        assert!(
            l.items_delivered >= l.items_requested,
            "`{}`: delivered {} < requested {}",
            e.name(),
            l.items_delivered,
            l.items_requested
        );
    }
}

/// (i b) `CostLedger::delta_from` over a mid-run snapshot is consistent
/// with the final ledger for every registered policy.
#[test]
fn delta_from_is_consistent_for_every_registered_policy() {
    let cfg = diff_cfg();
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 4_000, 7);
    let half = trace.len() / 2;
    let registry = PolicyRegistry::builtin();
    for e in registry.iter() {
        let mut p = e.build(&cfg, EngineChoice::Native);
        if p.needs_offline_trace() {
            p.prepare(&trace);
        }
        let mut snapshot: Option<CostLedger> = None;
        let mut served = 0usize;
        for b in trace.batches(cfg.batch_size) {
            for r in b {
                p.handle_request(r);
            }
            p.end_batch(b);
            served += b.len();
            if snapshot.is_none() && served >= half {
                snapshot = Some(p.ledger().clone());
            }
        }
        let snap = snapshot.expect("trace spans multiple batches");
        let l = p.ledger();
        let delta = l.delta_from(&snap);
        assert_eq!(delta.requests, l.requests - snap.requests, "`{}`", e.name());
        assert_eq!(delta.transfers, l.transfers - snap.transfers, "`{}`", e.name());
        assert_eq!(delta.full_hits, l.full_hits - snap.full_hits, "`{}`", e.name());
        assert_eq!(delta.misses, l.misses - snap.misses, "`{}`", e.name());
        // Costs are monotone over a run, so the saturating delta is exact
        // and snapshot + delta reassembles the final ledger.
        let tol = 1e-9 * l.total().abs().max(1.0);
        assert!(
            (snap.total() + delta.total() - l.total()).abs() <= tol,
            "`{}`: snapshot {} + delta {} != total {}",
            e.name(),
            snap.total(),
            delta.total(),
            l.total()
        );
        assert!(delta.c_p >= 0.0 && delta.c_t >= 0.0, "`{}`", e.name());
    }
}

/// (iii) Same seed ⇒ bit-identical ledgers for every registered policy.
#[test]
fn reruns_with_same_seed_are_deterministic() {
    let cfg = diff_cfg();
    let registry = PolicyRegistry::builtin();
    for e in registry.iter() {
        let mut ledgers = Vec::new();
        for _ in 0..2 {
            // Regenerate the trace too: determinism must cover the whole
            // seed → workload → policy pipeline.
            let trace = netflix_like(cfg.n_items, cfg.n_servers, 3_000, 13);
            let mut p = e.build(&cfg, EngineChoice::Native);
            replay(p.as_mut(), &trace, cfg.batch_size);
            ledgers.push(p.ledger().clone());
        }
        let (a, b) = (&ledgers[0], &ledgers[1]);
        assert_eq!(a.c_p.to_bits(), b.c_p.to_bits(), "`{}`: c_p drifted", e.name());
        assert_eq!(a.c_t.to_bits(), b.c_t.to_bits(), "`{}`: c_t drifted", e.name());
        assert_eq!(
            (a.transfers, a.full_hits, a.misses, a.requests, a.items_delivered),
            (b.transfers, b.full_hits, b.misses, b.requests, b.items_delivered),
            "`{}`: counters drifted",
            e.name()
        );
    }
}

/// (ii) Sharded totals equal single-leader totals (within 1e-9) for every
/// policy whose capability flags claim `supports_sharded`.
#[test]
fn sharded_matches_single_leader_for_capable_policies() {
    let registry = PolicyRegistry::builtin();
    let trace = netflix_like(24, 8, 4_000, 11);
    let mut checked = 0;
    for e in registry.iter() {
        if !e.caps().supports_sharded {
            continue;
        }
        let single = RunSpec::new()
            .config(diff_cfg())
            .engine(EngineChoice::Native)
            .policy(e.name())
            .inline_trace(trace.clone())
            .run(&registry, &mut NullObserver)
            .unwrap();
        let sharded = RunSpec::new()
            .config(diff_cfg())
            .engine(EngineChoice::Native)
            .policy(e.name())
            .inline_trace(trace.clone())
            .sharded(2, ReplayMode::Ordered)
            .run(&registry, &mut NullObserver)
            .unwrap();
        let tol = 1e-9 * single.total().abs().max(1.0);
        assert!(
            (single.total() - sharded.total()).abs() <= tol,
            "`{}`: single-leader {} != sharded {}",
            e.name(),
            single.total(),
            sharded.total()
        );
        checked += 1;
    }
    assert!(checked >= 1, "no sharded-capable policy in the registry");
}

// --------------------------------------------------- micro-universe oracle

/// A tiny instance the oracle can search exhaustively.
struct Micro {
    cfg: AkpcConfig,
    requests: Vec<Request>,
}

fn random_micro(rng: &mut Rng) -> Micro {
    let n_items = 3 + rng.below(4) as u32; // 3..=6
    let n_servers = 1 + rng.below(2) as u32; // 1..=2
    let len = 8 + rng.below(13); // 8..=20 requests
    let mut t = 0.0;
    let requests = (0..len)
        .map(|_| {
            t += rng.f64() * 0.4;
            let k = 1 + rng.below(3.min(n_items as usize));
            let mut items: Vec<u32> = rng
                .sample_distinct(n_items as usize, k)
                .into_iter()
                .map(|d| d as u32)
                .collect();
            items.sort_unstable();
            Request::new(items, rng.below(n_servers as usize) as u32, t)
        })
        .collect();
    Micro {
        cfg: AkpcConfig {
            n_items,
            n_servers,
            batch_size: 5,
            ..Default::default()
        },
        requests,
    }
}

/// All set partitions of `0..n` with blocks of at most `max_block` items
/// (restricted-growth enumeration; Bell(6) = 203, so this is tiny).
fn partitions(n: u32, max_block: usize) -> Vec<Vec<Vec<u32>>> {
    let mut out = Vec::new();
    let mut blocks: Vec<Vec<u32>> = Vec::new();
    fn go(item: u32, n: u32, max_block: usize, blocks: &mut Vec<Vec<u32>>, out: &mut Vec<Vec<Vec<u32>>>) {
        if item == n {
            out.push(blocks.clone());
            return;
        }
        for i in 0..blocks.len() {
            if blocks[i].len() < max_block {
                blocks[i].push(item);
                go(item + 1, n, max_block, blocks, out);
                blocks[i].pop();
            }
        }
        blocks.push(vec![item]);
        go(item + 1, n, max_block, blocks, out);
        blocks.pop();
    }
    go(0, n, max_block, &mut blocks, &mut out);
    out
}

/// Exhaustive static-partition minimum: replay the instance under every
/// disjoint clique partition (one `set_cliques` up front, Algorithm 5/6
/// semantics throughout) and take the cheapest. A concrete schedule, so
/// an UPPER bound on the true offline optimum.
fn static_partition_min(m: &Micro) -> f64 {
    let mut best = f64::INFINITY;
    for partition in partitions(m.cfg.n_items, m.cfg.omega as usize) {
        let mut core = PackedCacheCore::new(
            CostModel::from_config(&m.cfg),
            m.cfg.charge_policy,
        );
        core.set_cliques(partition.iter().map(|b| b.as_slice()));
        for r in &m.requests {
            core.handle_request(r);
        }
        if core.ledger.total() < best {
            best = core.ledger.total();
        }
    }
    best
}

/// Certified transfer floor: every item requested at a server must reach
/// that server at least once, and packed transfer cost is subadditive for
/// α ≤ 1 (k transfers covering u items cost ≥ `(1 + (u−1)α)·λ`), so
/// `Σ_servers transfer_packed(distinct items requested there)` LOWER
/// bounds any policy's total (rent excluded — also nonnegative).
fn transfer_floor(m: &Micro) -> f64 {
    let cost = CostModel::from_config(&m.cfg);
    let mut per_server: Vec<std::collections::BTreeSet<u32>> =
        vec![Default::default(); m.cfg.n_servers as usize];
    for r in &m.requests {
        per_server[r.server as usize].extend(r.items.iter().copied());
    }
    per_server
        .iter()
        .filter(|s| !s.is_empty())
        .map(|s| cost.transfer_packed(s.len() as u32))
        .sum()
}

/// ~30 seeded micro-instances: the floor ≤ static-partition-min sandwich
/// holds, **no registered policy ever beats the oracle's floor**, and
/// `bundle-opt` / `akpc` stay within the claimed competitive factor of
/// the oracle's upper bound (the Theorem-1/2 derivation
/// `S·(2+(ω−1)α)/(1+(S−1)α)` instantiated at S = universe size — the
/// adversarial worst case over exactly this instance family).
#[test]
fn micro_oracle_sandwiches_every_policy() {
    let registry = PolicyRegistry::builtin();
    for seed in 1..=30u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let m = random_micro(&mut rng);
        let floor = transfer_floor(&m);
        let upper = static_partition_min(&m);
        assert!(
            floor <= upper + 1e-9,
            "seed {seed}: floor {floor} > static-min {upper}"
        );
        let trace = Trace {
            requests: m.requests.clone(),
            n_items: m.cfg.n_items,
            n_servers: m.cfg.n_servers,
            name: format!("micro-{seed}"),
        };
        let bound = adversarial_bound_derived(&m.cfg, m.cfg.n_items);
        for e in registry.iter() {
            let mut p = e.build(&m.cfg, EngineChoice::Native);
            replay(p.as_mut(), &trace, m.cfg.batch_size);
            let total = p.ledger().total();
            assert!(
                total >= floor - 1e-9,
                "seed {seed}: `{}` total {total} beats the certified floor {floor}",
                e.name()
            );
            if matches!(e.name(), "bundle-opt" | "akpc") {
                assert!(
                    total <= bound * upper + 1e-9,
                    "seed {seed}: `{}` total {total} outside {bound}× oracle bound {upper}",
                    e.name()
                );
            }
        }
    }
}

// ----------------------------------------------------- headline ordering

/// Acceptance pin: on the flash-crowd scenario, AKPC beats BundleOpt
/// (cross-request clique packing) which beats NoPacking (per-request
/// bundle packing) on total cost.
#[test]
fn flash_crowd_orders_akpc_bundle_opt_no_packing() {
    let cfg = AkpcConfig::default();
    let m = scenario_suite_names(
        &cfg,
        &["flash-crowd"],
        &["no-packing", "bundle-opt", "akpc"],
        EngineChoice::Native,
        0.25,
    )
    .unwrap();
    let np = m.total(0, 0);
    let bo = m.total(1, 0);
    let akpc = m.total(2, 0);
    assert!(bo < np, "BundleOpt {bo} !< NoPacking {np}");
    assert!(akpc < bo, "AKPC {akpc} !< BundleOpt {bo}");
}

// ------------------------------------------- registry-extension regression

/// Register a toy policy from outside the crate: it must show up in the
/// `akpc policy list` rows and in unknown-policy error enumerations, and
/// build/run like any builtin.
#[test]
fn registered_toy_policy_is_fully_wired() {
    let mut registry = PolicyRegistry::builtin();
    registry
        .register(PolicyEntry::new(
            "toy-lru",
            "per-item caching registered from a test",
            PolicyCaps::default(),
            Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                Box::new(NoPacking::new(cfg))
            }),
        ))
        .unwrap();
    assert!(registry.names().contains(&"toy-lru"));

    // The exact rows `akpc policy list` prints (main.rs renders
    // name/caps-summary/description per entry): the toy row must appear.
    let rows: Vec<String> = registry
        .iter()
        .map(|e| format!("{:<20} {:<16} {}", e.name(), e.caps().summary(), e.description()))
        .collect();
    assert!(
        rows.iter()
            .any(|r| r.starts_with("toy-lru") && r.contains("online")),
        "toy policy missing from list rows: {rows:?}"
    );

    // Unknown-policy errors enumerate it alongside the builtins.
    let err = registry.resolve("nope").unwrap_err().to_string();
    assert!(err.contains("toy-lru"), "{err}");
    assert!(err.contains("akpc") && err.contains("bundle-opt"), "{err}");

    // And it runs through the same facade as everything else.
    let outcome = RunSpec::new()
        .config(diff_cfg())
        .engine(EngineChoice::Native)
        .policy("toy-lru")
        .inline_trace(netflix_like(24, 8, 500, 5))
        .run(&registry, &mut NullObserver)
        .unwrap();
    assert_eq!(outcome.ledger.requests, 500);
}

/// Capability pins for the two new families: flags agree with the policy
/// instances, and `run`'s sharded gating rejects them with the canonical
/// error (enumerating the capable set).
#[test]
fn new_policy_capability_flags_gate_the_sharded_driver() {
    let registry = PolicyRegistry::builtin();
    for name in ["predictive", "bundle-opt"] {
        let e = registry.resolve(name).unwrap();
        assert!(!e.caps().supports_sharded, "`{name}` must be single-leader");
        assert!(!e.caps().supports_elastic);
        let p = e.build(&diff_cfg(), EngineChoice::Native);
        assert_eq!(
            e.caps().needs_offline_trace,
            p.needs_offline_trace(),
            "`{name}`: registry/instance offline flag disagrees"
        );
        let err = RunSpec::new()
            .config(diff_cfg())
            .engine(EngineChoice::Native)
            .policy(name)
            .inline_trace(netflix_like(24, 8, 200, 3))
            .sharded(2, ReplayMode::Ordered)
            .validate(&registry)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not support the sharded driver"), "{err}");
        assert!(err.contains("akpc"), "capable set not enumerated: {err}");
    }
}

/// Both new families resolve by name and produce working policies with
/// the display names the tables use.
#[test]
fn new_policies_resolve_and_run_by_name() {
    let registry = PolicyRegistry::builtin();
    for (name, display) in [("predictive", "Predictive"), ("bundle-opt", "BundleOpt")] {
        let outcome = RunSpec::new()
            .config(diff_cfg())
            .engine(EngineChoice::Native)
            .policy(name)
            .inline_trace(netflix_like(24, 8, 1_000, 9))
            .run(&registry, &mut NullObserver)
            .unwrap();
        assert_eq!(outcome.policy, display);
        assert_eq!(outcome.ledger.requests, 1_000);
        assert!(outcome.total() > 0.0);
    }
}
