//! Elastic resharding acceptance (DESIGN.md §13):
//!
//! 1. **Exact handoff** — over ~50 randomized schedules (grow, shrink,
//!    no-op at random window boundaries), an N→M resize mid-run yields
//!    ledger totals identical (1e-9 relative) to a never-resized
//!    M-shard oracle, the post-handoff epoch matches the oracle's
//!    suffix delta, and the CopyBoard's retention decisions are
//!    unchanged.
//! 2. **The autoscale win** — on the flash-crowd scenario, the elastic
//!    fleet beats both static baselines (always-min, always-max) on
//!    total cost, with rental billed at actual shard-seconds.

use akpc::bench::elastic_suite;
use akpc::config::AkpcConfig;
use akpc::coordinator::{Coordinator, MetricsSnapshot, ServeRequest, TickMode};
use akpc::run::EngineChoice;
use akpc::runtime::CrmEngine;
use akpc::trace::generator::netflix_like;
use akpc::trace::model::Request;

fn serve_all(coord: &Coordinator, reqs: &[Request]) {
    for r in reqs {
        coord
            .serve(ServeRequest {
                items: r.items.clone(),
                server: r.server,
                time: Some(r.time),
            })
            .expect("serve");
    }
}

fn total_retentions(m: &MetricsSnapshot) -> u64 {
    m.per_shard.iter().map(|s| s.retentions).sum()
}

fn assert_rel_close(what: &str, seed: u64, a: f64, b: f64) {
    let tol = 1e-9 * b.abs().max(1.0);
    assert!(
        (a - b).abs() <= tol,
        "seed {seed}: {what} diverged — elastic {a} vs oracle {b} \
         (diff {:.3e}, tol {:.3e})",
        (a - b).abs(),
        tol
    );
}

/// The resharding exactness property, randomized over fleet sizes and
/// cut points. For each seed: serve a prefix on N shards, hand off to M
/// at a window boundary, serve the suffix — then replay the same trace
/// on a static M-shard fleet and compare.
#[test]
fn random_resizes_match_the_static_oracle() {
    let cfg = AkpcConfig {
        n_items: 24,
        n_servers: 12,
        batch_size: 16,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let n_req = 480usize; // 30 windows of 16
    let windows = n_req / cfg.batch_size;

    for seed in 0..50u64 {
        let trace = netflix_like(cfg.n_items, cfg.n_servers, n_req, seed + 1);
        let n_from = 1 + (seed % 4) as usize; // 1..=4
        let n_to = 1 + ((seed / 4) % 4) as usize; // 1..=4 — grow, shrink, no-op
        // A random window boundary strictly inside the trace.
        let cut = cfg.batch_size * (1 + (seed as usize * 7) % (windows - 1));

        // Elastic path: N shards for the prefix, stateful handoff, M for
        // the suffix. In Sync tick mode the cut lands right after a
        // window install, so every shard's sweep clock sits exactly at
        // the cut time — the same point `decommission` quiesces to.
        let coord =
            Coordinator::start_with(cfg.clone(), CrmEngine::Native, n_from, TickMode::Sync)
                .expect("boot donor");
        serve_all(&coord, &trace.requests[..cut]);
        let (next, retired) = coord.resize(n_to).expect("resize");
        assert_eq!(next.n_shards(), n_to);
        serve_all(&next, &trace.requests[cut..]);
        next.quiesce();
        let last = next.shutdown();
        let merged = MetricsSnapshot::merge_epochs(
            &[retired.into_handoff_epoch()],
            last.clone(),
        );

        // Oracle: a never-resized M-shard fleet over the same trace,
        // with a snapshot at the same window boundary.
        let oracle =
            Coordinator::start_with(cfg.clone(), CrmEngine::Native, n_to, TickMode::Sync)
                .expect("boot oracle");
        serve_all(&oracle, &trace.requests[..cut]);
        let at_cut = oracle.metrics().expect("oracle metrics");
        serve_all(&oracle, &trace.requests[cut..]);
        oracle.quiesce();
        let full = oracle.shutdown();

        // Whole-run totals: identical to float round-off.
        assert_rel_close("total ledger", seed, merged.ledger.total(), full.ledger.total());
        assert_rel_close("C_T", seed, merged.ledger.c_t, full.ledger.c_t);
        assert_rel_close("C_P", seed, merged.ledger.c_p, full.ledger.c_p);
        assert_eq!(merged.served, full.served, "seed {seed}: served");
        assert_eq!(merged.windows, full.windows, "seed {seed}: windows");
        assert_eq!(
            merged.ledger.full_hits, full.ledger.full_hits,
            "seed {seed}: full hits"
        );
        assert_eq!(
            merged.ledger.transfers, full.ledger.transfers,
            "seed {seed}: transfers"
        );

        // The post-handoff epoch alone equals the oracle's suffix delta.
        assert_rel_close(
            "post-handoff ledger delta",
            seed,
            last.ledger.total(),
            full.ledger.total() - at_cut.ledger.total(),
        );
        assert_eq!(
            last.served,
            full.served - at_cut.served,
            "seed {seed}: post-handoff serve count"
        );

        // Global retention (Algorithm 6's G[c] rule through the
        // CopyBoard) made the same decisions with and without a resize.
        assert_eq!(
            total_retentions(&merged),
            total_retentions(&full),
            "seed {seed}: retention decisions changed across the handoff"
        );
    }
}

/// The headline autoscale claim: on the flash-crowd scenario, elastic
/// total cost (ledger + shard-second rental + overload) undercuts both
/// an always-min and an always-max static fleet. The ledger term is
/// placement-invariant, so the win is pure fleet-sizing.
#[test]
fn elastic_beats_both_static_fleets_on_the_flash_crowd() {
    let cfg = AkpcConfig {
        batch_size: 50,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let sweep = elastic_suite(
        &cfg,
        &["autoscale-flash-crowd"],
        1,
        8,
        EngineChoice::Native,
        0.05,
    )
    .expect("sweep");
    let name = "autoscale-flash-crowd";
    let elastic = sweep.total(name, "elastic").expect("elastic cell");
    let always_min = sweep.total(name, "static-1").expect("min cell");
    let always_max = sweep.total(name, "static-8").expect("max cell");
    assert!(
        elastic < always_min,
        "elastic {elastic} must beat always-min {always_min}"
    );
    assert!(
        elastic < always_max,
        "elastic {elastic} must beat always-max {always_max}"
    );
    // And the fleet really flexed: up for the spike, back down after.
    let cell = sweep
        .cells
        .iter()
        .find(|c| c.label == "elastic")
        .expect("elastic cell");
    assert!(cell.outcome.peak_shards > 1, "never scaled up");
    assert!(
        cell.outcome.final_shards < cell.outcome.peak_shards,
        "never scaled back down"
    );
    // The three cells served identical traffic and agree on the ledger.
    let ledgers: Vec<f64> = sweep
        .cells
        .iter()
        .map(|c| c.outcome.cost.ledger_total)
        .collect();
    for w in ledgers.windows(2) {
        assert!(
            (w[0] - w[1]).abs() <= 1e-9 * w[1].abs().max(1.0),
            "ledger must be placement-invariant: {ledgers:?}"
        );
    }
}
