//! The lint gate: `cargo test -q --test lint` fails whenever `src/**`
//! violates an enforced invariant (DESIGN.md §11) — the same check as
//! `akpc lint` and the CI `lint` job, run from the test harness so a
//! plain `cargo test` blocks on it too.
//!
//! Rule-level behavior (bad fixture trips, near-miss passes, allow
//! grammar) is specified by the unit tests in `src/analysis/mod.rs`;
//! this file asserts tree-level properties of the real source.

use std::path::Path;

use akpc::analysis::{lint_tree, rules};

fn src_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[test]
fn source_tree_is_lint_clean() {
    let report = lint_tree(&src_root()).expect("scan src/");
    assert!(
        report.is_clean(),
        "akpc-lint violations in src/ — fix them or add a justified \
         `// akpc-lint: allow(<rule>) -- <why>`:\n{}",
        report.render()
    );
}

#[test]
fn scan_covers_the_whole_tree() {
    let report = lint_tree(&src_root()).expect("scan src/");
    // The crate has well over 70 source files (the serve/ daemon PR
    // pushed it past that); a collapsed walk (broken recursion, wrong
    // root) would silently pass the clean check above.
    assert!(
        report.files_scanned >= 70,
        "only {} files scanned — tree walk is broken",
        report.files_scanned
    );
}

#[test]
fn every_suppression_is_justified() {
    let report = lint_tree(&src_root()).expect("scan src/");
    for a in &report.allows {
        assert!(
            !a.justification.trim().is_empty(),
            "{}:{} allow({}) has an empty justification",
            a.file,
            a.line,
            a.rule
        );
        assert!(
            rules::known_rule(&a.rule),
            "{}:{} allows unknown rule {}",
            a.file,
            a.line,
            a.rule
        );
    }
    // The escape-hatch surface should stay small; growing it is a
    // reviewed decision, not drift.
    assert!(
        report.allows.len() <= 8,
        "{} suppressions — audit before raising this bound:\n{}",
        report.allows.len(),
        report.render()
    );
}

#[test]
fn catalog_ids_are_unique_and_stable() {
    let mut ids: Vec<&str> = rules::RULES.iter().map(|r| r.id).collect();
    let n = ids.len();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate rule ids");
    assert_eq!(ids, vec!["L1", "L2", "L3", "L4", "L5", "L6"]);
}
