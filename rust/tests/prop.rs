//! Property-based tests over the coordinator invariants (offline
//! environment — no proptest crate; the in-tree rig draws hundreds of
//! randomized cases from `akpc::util::Rng` and reports the failing seed,
//! which reproduces deterministically).

use akpc::algo::{Akpc, CachePolicy, NoPacking, Opt, PackCache2};
use akpc::cache::CacheState;
use akpc::clique::CliqueSet;
use akpc::config::AkpcConfig;
use akpc::crm::{diff_windows, native::build_native, sessionize, top_k_keep_mask, CrmWindow};
use akpc::policy::{predictive::DECAY, CoAccessPredictor};
use akpc::trace::model::{Request, Trace};
use akpc::util::{json, Rng};

/// Run `f` over `cases` random seeds; panic with the seed on failure.
fn forall(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 1..=cases {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property `{name}` failed at seed {seed}: {e:?}");
        }
    }
}

/// Random request window over `n` items / `m` servers.
fn random_window(rng: &mut Rng, len: usize, n: u32, m: u32, t0: f64) -> Vec<Request> {
    let mut t = t0;
    (0..len)
        .map(|_| {
            t += rng.exp(0.01);
            let k = rng.range(1, 4);
            let items: Vec<u32> = (0..k).map(|_| rng.below(n as usize) as u32).collect();
            Request::new(items, rng.below(m as usize) as u32, t)
        })
        .collect()
}

#[test]
fn prop_cliques_always_disjoint_and_bounded() {
    forall("cliques_disjoint", 200, |rng| {
        let n = 24 + rng.below(40) as u32;
        let omega = 2 + rng.below(6) as u32;
        let gamma = 0.5 + rng.f64() as f32 * 0.5;
        let w1 = random_window(rng, 150, n, 4, 0.0);
        let w2 = random_window(rng, 150, n, 4, 100.0);
        let c1 = build_native(&sessionize(&w1, 1.0), n, 0.2, 1.0);
        let c2 = build_native(&sessionize(&w2, 1.0), n, 0.2, 1.0);

        let prev = CliqueSet::generate(
            &CliqueSet::new(),
            &c1,
            &diff_windows(&CrmWindow::default(), &c1),
            omega,
            gamma,
            true,
            true,
        );
        prev.check_invariants().expect("window 1 invariants");
        for c in prev.iter() {
            assert!(c.len() <= omega as usize, "oversized clique with CS on");
        }

        // Incremental window with all module combinations.
        for (cs, acm) in [(true, true), (true, false), (false, true), (false, false)] {
            let set = CliqueSet::generate(
                &prev,
                &c2,
                &diff_windows(&c1, &c2),
                omega,
                gamma,
                cs,
                acm,
            );
            set.check_invariants().expect("window 2 invariants");
            if cs {
                for c in set.iter() {
                    assert!(c.len() <= omega as usize);
                }
            }
        }
    });
}

#[test]
fn prop_cache_state_g_count_consistent() {
    forall("cache_g_consistent", 200, |rng| {
        let mut cache = CacheState::new();
        let mut now = 0.0;
        let keys: Vec<u64> = (0..8).map(|i| 1000 + i).collect();
        let current: std::collections::HashSet<u64> =
            keys.iter().copied().take(4).collect();
        for _ in 0..300 {
            now += rng.exp(0.3);
            cache.process_expirations(now, &current, 1.0);
            let key = keys[rng.below(keys.len())];
            let server = rng.below(5) as u32;
            if cache.is_cached(key, server, now) {
                cache.extend(key, server, now + 1.0);
            } else if cache.expiry_of(key, server).is_none() {
                cache.insert(key, 1 + rng.below(5) as u32, server, now + 1.0);
            }
            cache.check_invariants().expect("G[c] consistency");
        }
    });
}

#[test]
fn prop_board_retention_matches_global_g_rule() {
    // The cross-shard CopyBoard restates Algorithm 6's "G[c] == 1" as a
    // structural latest-copy predicate (cache/board.rs). Feed one
    // G-rule state and one board-backed state the identical random op
    // sequence: every observable — retentions, retained units, copy
    // counts, expiries — must stay equal throughout.
    forall("board_matches_g", 200, |rng| {
        let board = std::sync::Arc::new(akpc::cache::CopyBoard::new());
        let mut plain = CacheState::new();
        let mut sharded = CacheState::new();
        sharded.attach_board(board);
        let keys: Vec<u64> = (0..6).map(|i| 500 + i).collect();
        let current: std::collections::HashSet<u64> =
            keys.iter().copied().take(3).collect();
        let mut now = 0.0;
        for step in 0..300 {
            now += rng.exp(0.4);
            plain.process_expirations(now, &current, 1.0);
            sharded.process_expirations(now, &current, 1.0);
            let key = keys[rng.below(keys.len())];
            let server = rng.below(4) as u32;
            let horizon = now + 0.2 + rng.f64();
            if plain.is_cached(key, server, now) {
                plain.extend(key, server, horizon);
                sharded.extend(key, server, horizon);
            } else {
                let size = 1 + rng.below(4) as u32;
                plain.insert(key, size, server, horizon);
                sharded.insert(key, size, server, horizon);
            }
            assert_eq!(
                plain.retentions, sharded.retentions,
                "retention count diverged at step {step}"
            );
            assert_eq!(
                plain.retained_units, sharded.retained_units,
                "retained units diverged at step {step}"
            );
            for &k in &keys {
                assert_eq!(plain.copy_count(k), sharded.copy_count(k));
                for s in 0..4u32 {
                    assert_eq!(
                        plain.expiry_of(k, s),
                        sharded.expiry_of(k, s),
                        "expiry diverged for ({k},{s}) at step {step}"
                    );
                }
            }
            plain.check_invariants().expect("plain invariants");
            sharded.check_invariants().expect("sharded invariants");
        }
    });
}

#[test]
fn prop_insert_over_stale_never_inflates_g() {
    // Regression property for the lazy-deletion insert fix: random
    // insert/extend traffic with *no* sweeps in between must keep G[c]
    // equal to the number of distinct (key, server) pairs.
    forall("insert_over_stale", 200, |rng| {
        let mut cache = CacheState::new();
        let mut pairs = std::collections::HashSet::new();
        let mut now = 0.0;
        for _ in 0..200 {
            now += rng.exp(0.5);
            let key = 100 + rng.below(4) as u64;
            let server = rng.below(3) as u32;
            if cache.is_cached(key, server, now) {
                cache.extend(key, server, now + 1.0);
            } else {
                // May overwrite an expired-but-unswept entry.
                cache.insert(key, 1, server, now + 1.0);
            }
            pairs.insert((key, server));
            cache.check_invariants().expect("G consistency");
        }
        let total: u32 = (100..104u64).map(|k| cache.copy_count(k)).sum();
        assert_eq!(total as usize, pairs.len(), "G[c] drifted from live pairs");
    });
}

#[test]
fn prop_no_data_loss_for_current_cliques() {
    // Observation 3: a clique in Clique(W) that was cached at least once
    // keeps >= 1 alive copy across any expiry pattern.
    forall("no_data_loss", 100, |rng| {
        let mut cache = CacheState::new();
        let current: std::collections::HashSet<u64> = [7u64].into_iter().collect();
        cache.insert(7, 3, 0, 1.0);
        let mut now = 0.0;
        for _ in 0..100 {
            now += rng.exp(0.7);
            cache.process_expirations(now, &current, 1.0);
            assert!(
                cache.copy_count(7) >= 1,
                "last copy of a current clique was dropped"
            );
            // Sometimes add/expire extra copies.
            if rng.chance(0.3) {
                let s = 1 + rng.below(4) as u32;
                if !cache.is_cached(7, s, now) && cache.expiry_of(7, s).is_none() {
                    cache.insert(7, 3, s, now + 0.5);
                }
            }
        }
    });
}

#[test]
fn prop_costs_nonnegative_and_accumulating() {
    forall("costs_monotone", 60, |rng| {
        let cfg = AkpcConfig {
            n_items: 40,
            n_servers: 8,
            batch_size: 50,
            crm_window_batches: 2,
            ..Default::default()
        };
        let mut policy = Akpc::new(&cfg);
        let window = random_window(rng, 400, 40, 8, 0.0);
        let mut last_total = 0.0;
        for (i, r) in window.iter().enumerate() {
            policy.handle_request(r);
            let l = policy.ledger();
            assert!(l.c_p >= 0.0 && l.c_t >= 0.0);
            assert!(
                l.total() >= last_total - 1e-9,
                "total cost decreased at step {i}"
            );
            last_total = l.total();
        }
        let l = policy.ledger();
        assert_eq!(l.requests, window.len() as u64);
        assert!(l.items_delivered >= l.items_requested);
    });
}

#[test]
fn prop_policies_agree_on_request_count() {
    forall("request_accounting", 40, |rng| {
        let n = 30u32;
        let m = 6u32;
        let reqs = random_window(rng, 300, n, m, 0.0);
        let trace = Trace {
            requests: reqs,
            n_items: n,
            n_servers: m,
            name: "prop".into(),
        };
        let cfg = AkpcConfig {
            n_items: n,
            n_servers: m,
            batch_size: 64,
            ..Default::default()
        };
        let mut policies: Vec<Box<dyn CachePolicy>> = vec![
            Box::new(NoPacking::new(&cfg)),
            Box::new(PackCache2::new(&cfg)),
            Box::new(Akpc::new(&cfg)),
            Box::new(Opt::new(&cfg)),
        ];
        for p in policies.iter_mut() {
            let rep = akpc::sim::run(p.as_mut(), &trace, cfg.batch_size);
            assert_eq!(rep.ledger.requests, 300);
            assert_eq!(
                rep.ledger.full_hits + rep.ledger.misses,
                300,
                "{}: hits+misses != requests",
                rep.name
            );
        }
    });
}

#[test]
fn prop_sessionize_preserves_items_and_respects_gap() {
    forall("sessionize", 200, |rng| {
        let window = random_window(rng, 120, 30, 4, 0.0);
        let gap = 0.2 + rng.f64();
        let txs = sessionize(&window, gap);
        // Item preservation per server.
        let items_of = |rs: &[Request]| {
            let mut v: Vec<(u32, u32)> = rs
                .iter()
                .flat_map(|r| r.items.iter().map(move |&d| (r.server, d)))
                .collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        assert_eq!(items_of(&window), items_of(&txs));
        // Transactions are fewer or equal, sorted-deduped item lists.
        assert!(txs.len() <= window.len());
        for tx in &txs {
            assert!(tx.items.windows(2).all(|w| w[0] < w[1]));
        }
    });
}

#[test]
fn prop_competitive_ratio_bound_holds_on_adversary() {
    // The measured adversarial ratio never exceeds the derived Theorem-1
    // bound, for any (ω, α, S).
    forall("competitive_bound", 200, |rng| {
        let cfg = AkpcConfig {
            omega: 2 + rng.below(8) as u32,
            alpha: 0.05 + rng.f64() * 0.95,
            ..Default::default()
        };
        let s = 1 + rng.below(cfg.omega as usize) as u32;
        let (measured, bound) =
            akpc::bench::experiments::adversarial_ratio(&cfg, s, 1 + rng.below(20) as u32);
        assert!(
            measured <= bound + 1e-9,
            "ratio {measured} exceeds bound {bound} (omega={}, alpha={}, S={s})",
            cfg.omega,
            cfg.alpha
        );
    });
}

#[test]
fn prop_json_roundtrip() {
    forall("json_roundtrip", 300, |rng| {
        // Random JSON value, depth-limited.
        fn gen(rng: &mut Rng, depth: usize) -> json::Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => json::Json::Null,
                1 => json::Json::Bool(rng.chance(0.5)),
                2 => json::Json::Num((rng.f64() * 2e6).round() / 2.0 - 5e5),
                3 => {
                    let len = rng.below(12);
                    json::Json::Str(
                        (0..len)
                            .map(|_| {
                                let c = rng.below(128) as u8;
                                if c.is_ascii_graphic() || c == b' ' {
                                    c as char
                                } else {
                                    '\n'
                                }
                            })
                            .collect(),
                    )
                }
                4 => json::Json::Arr(
                    (0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect(),
                ),
                _ => json::Json::Obj(
                    (0..rng.below(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let parsed = json::parse(&v.to_string()).expect("parse back");
        assert_eq!(parsed, v);
        let pretty = json::parse(&v.to_string_pretty()).expect("parse pretty");
        assert_eq!(pretty, v);
    });
}

/// Dense reference CRM — a direct transcription of the pre-CSR pipeline
/// over full `n×n` matrices (zero outside kept pairs). The sparse window
/// must agree with it bit-for-bit: same f32 expressions, same order.
struct DenseCrm {
    n: usize,
    freq: Vec<f32>,
    keep: Vec<bool>,
    /// Full `n×n` min-max-normalized weights.
    norm: Vec<f32>,
    /// Full `n×n` binarization as 0.0/1.0 (`from_full`'s interchange).
    bin: Vec<f32>,
}

impl DenseCrm {
    fn build(window: &[Request], n_items: u32, theta: f32, top_frac: f32) -> Self {
        let n = n_items as usize;
        let mut freq = vec![0.0f32; n];
        for r in window {
            for &d in &r.items {
                freq[d as usize] += 1.0;
            }
        }
        let keep = top_k_keep_mask(&freq, top_frac);
        let mut raw = vec![0.0f32; n * n];
        let mut kept_buf: Vec<usize> = Vec::new();
        for r in window {
            kept_buf.clear();
            kept_buf.extend(r.items.iter().map(|&d| d as usize).filter(|&d| keep[d]));
            for a in 0..kept_buf.len() {
                for b in (a + 1)..kept_buf.len() {
                    let (i, j) = (kept_buf[a], kept_buf[b]);
                    raw[i * n + j] += 1.0;
                    raw[j * n + i] += 1.0;
                }
            }
        }
        let lo = 0.0f32;
        let mut hi = f32::NEG_INFINITY;
        for i in 0..n {
            for j in 0..n {
                if i != j && keep[i] && keep[j] {
                    hi = hi.max(raw[i * n + j]);
                }
            }
        }
        if !hi.is_finite() {
            hi = 0.0;
        }
        let span = (hi - lo).max(1e-9);
        let mut norm = vec![0.0f32; n * n];
        let mut bin = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                if i != j && keep[i] && keep[j] {
                    let v = (raw[i * n + j] - lo) / span;
                    norm[i * n + j] = v;
                    if v > theta {
                        bin[i * n + j] = 1.0;
                    }
                }
            }
        }
        Self {
            n,
            freq,
            keep,
            norm,
            bin,
        }
    }

    fn edge(&self, u: u32, v: u32) -> bool {
        u != v && self.bin[u as usize * self.n + v as usize] > 0.5
    }

    fn weight(&self, u: u32, v: u32) -> f32 {
        if u == v {
            0.0
        } else {
            self.norm[u as usize * self.n + v as usize]
        }
    }

    fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for u in 0..self.n as u32 {
            for v in (u + 1)..self.n as u32 {
                if self.edge(u, v) {
                    out.push((u, v));
                }
            }
        }
        out
    }
}

#[test]
fn prop_sparse_crm_matches_dense_oracle() {
    forall("sparse_vs_dense_crm", 120, |rng| {
        let n = 16 + rng.below(48) as u32;
        let theta = (rng.f64() * 0.6) as f32;
        let top_frac = (0.3 + rng.f64() * 0.7) as f32;
        let tx1 = sessionize(&random_window(rng, 140, n, 4, 0.0), 1.0);
        let tx2 = sessionize(&random_window(rng, 140, n, 4, 70.0), 1.0);
        let s1 = build_native(&tx1, n, theta, top_frac);
        let s2 = build_native(&tx2, n, theta, top_frac);
        let d1 = DenseCrm::build(&tx1, n, theta, top_frac);
        let d2 = DenseCrm::build(&tx2, n, theta, top_frac);

        for (s, d) in [(&s1, &d1), (&s2, &d2)] {
            let active: Vec<u32> = (0..n).filter(|&i| d.keep[i as usize]).collect();
            assert_eq!(s.active, active, "kept set");
            for u in 0..n {
                for v in 0..n {
                    assert_eq!(s.edge(u, v), d.edge(u, v), "edge ({u},{v})");
                    assert_eq!(s.weight(u, v), d.weight(u, v), "weight ({u},{v})");
                }
            }
            assert_eq!(s.edges(), d.edges(), "edge list");
            assert_eq!(s.edge_count(), d.edges().len(), "edge count");
        }

        // The streaming ΔE merge vs the dense set-difference reference.
        let delta = diff_windows(&s1, &s2);
        let e1: std::collections::HashSet<(u32, u32)> = d1.edges().into_iter().collect();
        let e2: std::collections::HashSet<(u32, u32)> = d2.edges().into_iter().collect();
        let mut removed: Vec<(u32, u32)> = e1.difference(&e2).copied().collect();
        let mut added: Vec<(u32, u32)> = e2.difference(&e1).copied().collect();
        removed.sort_unstable();
        added.sort_unstable();
        assert_eq!(delta.removed, removed, "diff removed");
        assert_eq!(delta.added, added, "diff added");
    });
}

#[test]
fn prop_clique_generate_agrees_across_crm_constructors() {
    // `build_native` (sparse accumulation) and `from_full` over the dense
    // oracle's full matrices must yield decision-identical windows, and
    // the full Algorithm-3 pipeline must produce the same cliques on both
    // — the clique-level half of the dense-vs-sparse equivalence bar.
    forall("generate_equivalence", 60, |rng| {
        let n = 16 + rng.below(40) as u32;
        let theta = (rng.f64() * 0.5) as f32;
        let top_frac = (0.4 + rng.f64() * 0.6) as f32;
        let omega = 3 + rng.below(4) as u32;
        let gamma = 0.5 + rng.f64() as f32 * 0.5;
        let tx1 = sessionize(&random_window(rng, 140, n, 4, 0.0), 1.0);
        let tx2 = sessionize(&random_window(rng, 140, n, 4, 70.0), 1.0);
        let s1 = build_native(&tx1, n, theta, top_frac);
        let s2 = build_native(&tx2, n, theta, top_frac);
        let d1 = DenseCrm::build(&tx1, n, theta, top_frac);
        let d2 = DenseCrm::build(&tx2, n, theta, top_frac);
        let f1 = CrmWindow::from_full(&d1.norm, &d1.bin, &d1.freq, n as usize, top_frac);
        let f2 = CrmWindow::from_full(&d2.norm, &d2.bin, &d2.freq, n as usize, top_frac);
        assert_eq!(f1.active, s1.active);
        assert_eq!(f1.edges(), s1.edges());
        assert_eq!(f2.edges(), s2.edges());

        let gen_chain = |w1: &CrmWindow, w2: &CrmWindow| -> Vec<Vec<u32>> {
            let prev = CliqueSet::generate(
                &CliqueSet::new(),
                w1,
                &diff_windows(&CrmWindow::default(), w1),
                omega,
                gamma,
                true,
                true,
            );
            let set = CliqueSet::generate(&prev, w2, &diff_windows(w1, w2), omega, gamma, true, true);
            set.check_invariants().expect("invariants");
            let mut v: Vec<Vec<u32>> = set.iter().map(|c| c.to_vec()).collect();
            v.sort();
            v
        };
        assert_eq!(gen_chain(&s1, &s2), gen_chain(&f1, &f2), "clique sets diverge");
    });
}

#[test]
fn prop_clique_pipeline_deterministic_under_relabeling() {
    // The akpc-lint L1/L2 sweep exists so that no decision in the
    // sessionize → CRM → clique pipeline depends on float partial orders
    // or hash-bucket iteration order. This property pins that down two
    // ways, over 100 random workloads:
    //
    // 1. Rerun: the same input yields byte-identical cliques. `HashMap`'s
    //    per-instance `RandomState` reseeds on every construction, so any
    //    surviving hash-order dependence flakes *within* one process.
    // 2. Monotone relabeling: mapping every item id `d → 3d + 5` permutes
    //    every hash bucket assignment while preserving the id *order*
    //    that legitimate tie-breaks use. The relabeled run must produce
    //    exactly the relabeled cliques.
    forall("relabel_determinism", 100, |rng| {
        let n = 20 + rng.below(30) as u32;
        let omega = 3 + rng.below(4) as u32;
        let gamma = 0.5 + rng.f64() as f32 * 0.5;
        let w1 = random_window(rng, 150, n, 4, 0.0);
        let w2 = random_window(rng, 150, n, 4, 100.0);

        let relabel = |d: u32| d * 3 + 5;
        let relabel_reqs = |rs: &[Request]| -> Vec<Request> {
            rs.iter()
                .map(|r| {
                    Request::new(
                        r.items.iter().map(|&d| relabel(d)).collect(),
                        r.server,
                        r.time,
                    )
                })
                .collect()
        };

        let run = |wa: &[Request], wb: &[Request], n: u32| -> Vec<Vec<u32>> {
            let c1 = build_native(&sessionize(wa, 1.0), n, 0.2, 1.0);
            let c2 = build_native(&sessionize(wb, 1.0), n, 0.2, 1.0);
            let prev = CliqueSet::generate(
                &CliqueSet::new(),
                &c1,
                &diff_windows(&CrmWindow::default(), &c1),
                omega,
                gamma,
                true,
                true,
            );
            let set = CliqueSet::generate(
                &prev,
                &c2,
                &diff_windows(&c1, &c2),
                omega,
                gamma,
                true,
                true,
            );
            set.check_invariants().expect("invariants");
            let mut v: Vec<Vec<u32>> = set.iter().map(|c| c.to_vec()).collect();
            v.sort();
            v
        };

        let base = run(&w1, &w2, n);
        let again = run(&w1, &w2, n);
        assert_eq!(base, again, "same input, different cliques (rerun)");

        let n_rel = relabel(n - 1) + 1;
        let rel = run(&relabel_reqs(&w1), &relabel_reqs(&w2), n_rel);
        let mut expected: Vec<Vec<u32>> = base
            .iter()
            .map(|c| c.iter().map(|&d| relabel(d)).collect())
            .collect();
        expected.sort();
        assert_eq!(
            rel, expected,
            "item relabeling changed the clique decisions"
        );
    });
}

/// Absorb a sequence of request windows into a fresh predictor through
/// the exact observation pipeline `Predictive::end_batch` uses
/// (sessionize → native CRM → `absorb_crm`).
fn absorb_windows(windows: &[Vec<Request>], n: u32) -> CoAccessPredictor {
    let mut p = CoAccessPredictor::new();
    for w in windows {
        p.absorb_crm(&build_native(&sessionize(w, 1.0), n, 0.2, 1.0));
    }
    p
}

#[test]
fn prop_predictor_invariant_under_monotone_relabeling() {
    // The learned affinities are a function of co-access *structure*, not
    // of item-id values: a monotone relabeling `d → 3d + 5` (which
    // permutes every hash bucket while preserving the id order that
    // legitimate tie-breaks use) must map scores and predicted-window
    // edges exactly onto their relabeled counterparts.
    forall("predictor_relabel", 100, |rng| {
        let n = 16 + rng.below(24) as u32;
        let w1 = random_window(rng, 120, n, 4, 0.0);
        let w2 = random_window(rng, 120, n, 4, 80.0);

        let relabel = |d: u32| d * 3 + 5;
        let relabel_reqs = |rs: &[Request]| -> Vec<Request> {
            rs.iter()
                .map(|r| {
                    Request::new(
                        r.items.iter().map(|&d| relabel(d)).collect(),
                        r.server,
                        r.time,
                    )
                })
                .collect()
        };

        let base = absorb_windows(&[w1.clone(), w2.clone()], n);
        let n_rel = relabel(n - 1) + 1;
        let rel = absorb_windows(&[relabel_reqs(&w1), relabel_reqs(&w2)], n_rel);

        assert_eq!(base.len(), rel.len(), "live pair count changed");
        for u in 0..n {
            for v in (u + 1)..n {
                assert_eq!(
                    base.score(u, v),
                    rel.score(relabel(u), relabel(v)),
                    "score of ({u},{v}) drifted under relabeling"
                );
            }
        }
        // The forecast relabels edge-for-edge (relabel is monotone, so
        // the sorted u<v edge list maps directly).
        let expected: Vec<(u32, u32)> = base
            .predicted_window(0.2)
            .edges()
            .iter()
            .map(|&(u, v)| (relabel(u), relabel(v)))
            .collect();
        assert_eq!(rel.predicted_window(0.2).edges(), expected);
    });
}

#[test]
fn prop_predictor_deterministic_across_reruns() {
    // policy/ sits in the akpc-lint L2 (no-hash-iter-decision) scope for
    // a reason: the predictor must be a pure function of its observation
    // sequence. Rebuilding the whole pipeline twice in one process gives
    // every transient HashMap a fresh RandomState, so any surviving
    // hash-order dependence shows up as a bit-level diff here.
    forall("predictor_rerun", 100, |rng| {
        let n = 16 + rng.below(24) as u32;
        let windows: Vec<Vec<Request>> = (0..3)
            .map(|k| random_window(rng, 100, n, 4, k as f64 * 60.0))
            .collect();
        let a = absorb_windows(&windows, n);
        let b = absorb_windows(&windows, n);
        assert_eq!(a.len(), b.len());
        for u in 0..n {
            for v in (u + 1)..n {
                assert_eq!(
                    a.score(u, v).to_bits(),
                    b.score(u, v).to_bits(),
                    "score of ({u},{v}) flaked across reruns"
                );
            }
        }
        let (pa, pb) = (a.predicted_window(0.2), b.predicted_window(0.2));
        assert_eq!(pa.active, pb.active);
        assert_eq!(pa.edges(), pb.edges());
        for &(u, v) in &pa.edges() {
            assert_eq!(pa.weight(u, v).to_bits(), pb.weight(u, v).to_bits());
        }
    });
}

#[test]
fn prop_predictor_decay_is_monotone_and_old_never_outweighs_new() {
    // Decay semantics (DESIGN.md §15.1): every boundary multiplies each
    // affinity by DECAY and prunes dust, so (1) scores shrink
    // geometrically and never rise without fresh signal, and (2) a window
    // observed k boundaries ago can never outweigh the *same* window
    // observed just now — older windows never beat newer at equal counts.
    forall("predictor_decay", 100, |rng| {
        let n = 12 + rng.below(20) as u32;
        let w = random_window(rng, 120, n, 4, 0.0);
        let crm = build_native(&sessionize(&w, 1.0), n, 0.2, 1.0);

        let mut aged = CoAccessPredictor::new();
        aged.absorb_crm(&crm);
        let pairs: Vec<((u32, u32), f64)> = (0..n)
            .flat_map(|u| ((u + 1)..n).map(move |v| (u, v)))
            .map(|(u, v)| ((u, v), aged.score(u, v)))
            .filter(|&(_, s)| s > 0.0)
            .collect();

        let mut prev: Vec<f64> = pairs.iter().map(|&(_, s)| s).collect();
        for round in 1..=12i32 {
            aged.decay();
            for (i, &((u, v), s0)) in pairs.iter().enumerate() {
                let s = aged.score(u, v);
                assert!(
                    s <= prev[i] + 1e-15,
                    "score of ({u},{v}) rose under decay at round {round}"
                );
                let expected = s0 * DECAY.powi(round);
                if s == 0.0 {
                    // Pruned — only legal once the signal fell to dust.
                    assert!(
                        expected <= 0.05 + 1e-12,
                        "({u},{v}) pruned early: would be {expected}"
                    );
                } else {
                    assert!(
                        (s - expected).abs() <= 1e-12 * expected.max(1.0),
                        "({u},{v}) decayed off-geometric: {s} vs {expected}"
                    );
                }
                prev[i] = s;
            }
        }

        // Equal observation counts, different ages: the fresh predictor
        // strictly dominates the aged one on every pair that had signal
        // (the aged copy decayed 12 boundaries; DECAY < 1 guarantees
        // strictness whether or not the pair was pruned).
        let mut newer = CoAccessPredictor::new();
        newer.absorb_crm(&crm);
        for &((u, v), _) in &pairs {
            assert!(
                newer.score(u, v) > aged.score(u, v),
                "aged ({u},{v}) outweighs the identical fresh observation"
            );
        }
    });
}

#[test]
fn prop_trace_binary_roundtrip() {
    forall("trace_io_roundtrip", 50, |rng| {
        let n = 10 + rng.below(50) as u32;
        let m = 1 + rng.below(20) as u32;
        let len = 1 + rng.below(200);
        let reqs = random_window(rng, len, n, m, 0.0);
        let trace = Trace {
            requests: reqs,
            n_items: n,
            n_servers: m,
            name: format!("prop-{}", rng.below(1000)),
        };
        let dir = akpc::util::tempdir::TempDir::new("prop-io").unwrap();
        let p = dir.file("t.bin");
        akpc::trace::io::write_binary(&trace, &p).unwrap();
        let back = akpc::trace::io::read_binary(&p).unwrap();
        assert_eq!(back.requests, trace.requests);
        assert_eq!(back.name, trace.name);
    });
}

#[test]
fn prop_trace_csv_binary_csv_roundtrip() {
    // The full format chain CSV → binary → CSV preserves requests (times
    // included: both formats round-trip f64 exactly — CSV via Rust's
    // shortest-roundtrip float formatting), metadata, and ordering.
    forall("trace_format_chain", 40, |rng| {
        use akpc::trace::io;
        let n = 5 + rng.below(40) as u32;
        let m = 1 + rng.below(12) as u32;
        let len = 1 + rng.below(150);
        let t0 = rng.f64() * 100.0;
        let trace = Trace {
            requests: random_window(rng, len, n, m, t0),
            n_items: n,
            n_servers: m,
            name: format!("chain-{}", rng.below(1000)),
        };
        trace.validate().unwrap();
        let dir = akpc::util::tempdir::TempDir::new("prop-chain").unwrap();

        let csv1 = dir.file("a.csv");
        io::write_csv(&trace, &csv1).unwrap();
        let from_csv = io::read_csv(&csv1).unwrap();
        assert_eq!(from_csv.requests, trace.requests, "CSV read drifted");

        let bin = dir.file("b.bin");
        io::write_binary(&from_csv, &bin).unwrap();
        let from_bin = io::read_binary(&bin).unwrap();

        let csv2 = dir.file("c.csv");
        io::write_csv(&from_bin, &csv2).unwrap();
        let back = io::read_csv(&csv2).unwrap();

        assert_eq!(back.requests, trace.requests, "chain mangled requests");
        assert_eq!(back.n_items, trace.n_items);
        assert_eq!(back.n_servers, trace.n_servers);
        assert_eq!(back.name, trace.name);
        back.validate().unwrap();
    });
}
