//! Integration tests for the sharded multi-ESS coordinator: ledger
//! equivalence with the single leader (the tentpole determinism check),
//! concurrent clients across shards, retention accounting across shard
//! boundaries, and shutdown behavior.

use akpc::algo::Akpc;
use akpc::config::AkpcConfig;
use akpc::coordinator::{Coordinator, ServeRequest, TickMode};
use akpc::runtime::CrmEngine;
use akpc::sim::replay::assert_shard_sum_matches;
use akpc::sim::{self, replay_sharded, ReplayMode};
use akpc::trace::generator::{netflix_like, spotify_like};
use akpc::trace::model::{Request, Trace};

/// The acceptance-criterion check: an 8-shard ordered replay's per-shard
/// ledgers sum to the single-leader run's total within 1e-9 (relative),
/// and the integer decision counters match exactly.
#[test]
fn eight_shard_ledgers_sum_to_single_leader() {
    let cfg = AkpcConfig {
        n_items: 60,
        n_servers: 64,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 20_000, 31);

    let mut policy = Akpc::new(&cfg);
    let single = sim::run(&mut policy, &trace, cfg.batch_size);

    let rep = replay_sharded(
        &cfg,
        CrmEngine::Native,
        &trace,
        8,
        ReplayMode::Ordered,
    )
    .unwrap();
    assert_eq!(rep.n_shards, 8);
    assert_eq!(rep.metrics.per_shard.len(), 8);
    assert_shard_sum_matches(&rep, single.ledger.total());
    // Decision-level equality, not just cost-level.
    assert_eq!(rep.metrics.ledger.requests, single.ledger.requests);
    assert_eq!(rep.metrics.ledger.full_hits, single.ledger.full_hits);
    assert_eq!(rep.metrics.ledger.misses, single.ledger.misses);
    assert_eq!(rep.metrics.ledger.transfers, single.ledger.transfers);
    assert_eq!(
        rep.metrics.ledger.items_delivered,
        single.ledger.items_delivered
    );
    // Every shard actually participated.
    for s in &rep.metrics.per_shard {
        assert!(s.served > 0, "shard {} served nothing", s.shard);
    }
}

/// Same equivalence on the churny Spotify-like workload (clique set
/// rotates, so snapshot installs and retention currency changes are
/// exercised harder) and a shard count that does not divide the server
/// count evenly.
#[test]
fn churny_trace_equivalence_with_odd_shards() {
    let cfg = AkpcConfig {
        n_items: 60,
        n_servers: 30,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let trace = spotify_like(cfg.n_items, cfg.n_servers, 15_000, 32);

    let mut policy = Akpc::new(&cfg);
    let single = sim::run(&mut policy, &trace, cfg.batch_size);

    for n_shards in [2usize, 7] {
        let rep = replay_sharded(
            &cfg,
            CrmEngine::Native,
            &trace,
            n_shards,
            ReplayMode::Ordered,
        )
        .unwrap();
        assert_shard_sum_matches(&rep, single.ledger.total());
        assert_eq!(rep.metrics.ledger.full_hits, single.ledger.full_hits);
        assert_eq!(rep.metrics.ledger.transfers, single.ledger.transfers);
    }
}

/// Retention (Algorithm 6 line 3) must account identically when the
/// copies of one clique live on servers owned by different shards. The
/// trace is handcrafted so the last copies expire with the clique still
/// current, forcing retention chains that cross shard sweep gaps.
#[test]
fn cross_shard_retention_matches_single_leader() {
    let cfg = AkpcConfig {
        n_items: 8,
        n_servers: 4,
        batch_size: 4,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let mut requests = Vec::new();
    // Window 1: learn the {0,1} bundle (four servers, distinct sessions).
    for (i, server) in (0..4u32).enumerate() {
        requests.push(Request::new(vec![0, 1], server, i as f64 * 0.3));
    }
    // Sparse phase under the learned packing: copies on servers 0 (shard
    // 0) and 1 (shard 1), then long gaps so both expire while {0,1} is
    // still current. Server 1's retention chain runs entirely between its
    // own requests — the single leader sweeps it from other servers'
    // requests, a 2-shard run only via install/quiesce sweeps.
    requests.push(Request::new(vec![0], 0, 10.0)); // cache on ESS 0 (exp 11)
    requests.push(Request::new(vec![1], 1, 10.2)); // cache on ESS 1 (exp 11.2)
    requests.push(Request::new(vec![5], 2, 20.0)); // advances the leader clock
    requests.push(Request::new(vec![0], 2, 20.5)); // refetch on ESS 2
    let trace = Trace {
        requests,
        n_items: cfg.n_items,
        n_servers: cfg.n_servers,
        name: "retention-handcrafted".into(),
    };
    trace.validate().unwrap();

    let mut policy = Akpc::new(&cfg);
    let single = sim::run(&mut policy, &trace, cfg.batch_size);

    let rep = replay_sharded(
        &cfg,
        CrmEngine::Native,
        &trace,
        2,
        ReplayMode::Ordered,
    )
    .unwrap();
    assert!(
        rep.metrics.retentions() > 0,
        "scenario failed to exercise retention"
    );
    assert_shard_sum_matches(&rep, single.ledger.total());
    let c_p_sum: f64 = rep.metrics.per_shard.iter().map(|s| s.ledger.c_p).sum();
    assert!(
        (c_p_sum - single.ledger.c_p).abs() <= 1e-9 * single.ledger.c_p.max(1.0),
        "retention rent diverged: shards {} vs leader {}",
        c_p_sum,
        single.ledger.c_p
    );
}

/// Many concurrent clients over many shards: every request is accounted
/// exactly once, across shards.
#[test]
fn concurrent_clients_across_shards() {
    let cfg = AkpcConfig {
        n_items: 32,
        n_servers: 16,
        batch_size: 50,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let coord = Coordinator::start(cfg, CrmEngine::Native, 4).unwrap();
    let mut handles = Vec::new();
    for c in 0..12u32 {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            for i in 0..100u32 {
                client
                    .serve(ServeRequest {
                        items: vec![(c * 3 + i) % 32, (c + i) % 32],
                        server: (c + i) % 16,
                        time: None, // wall clock
                    })
                    .unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.shutdown();
    assert_eq!(m.served, 1200);
    assert_eq!(m.ledger.requests, 1200);
    assert_eq!(m.per_shard.len(), 4);
    assert_eq!(m.per_shard.iter().map(|s| s.served).sum::<u64>(), 1200);
    assert_eq!(
        m.ledger.full_hits + m.ledger.misses,
        1200,
        "hits+misses must partition requests"
    );
}

/// Shutdown must be clean and idempotent with N shards: explicit
/// shutdown, drop-without-shutdown, and drop with live clients.
#[test]
fn shutdown_with_n_shards_is_clean() {
    let cfg = AkpcConfig {
        n_items: 16,
        n_servers: 8,
        crm_top_frac: 1.0,
        ..Default::default()
    };

    // Explicit shutdown returns aggregated finals.
    let coord = Coordinator::start(cfg.clone(), CrmEngine::Native, 8).unwrap();
    for i in 0..8u32 {
        coord
            .serve(ServeRequest {
                items: vec![i % 16],
                server: i % 8,
                time: Some(i as f64 * 0.1),
            })
            .unwrap();
    }
    let m = coord.shutdown();
    assert_eq!(m.served, 8);
    assert_eq!(m.per_shard.len(), 8);

    // Drop without explicit shutdown must not hang or panic.
    let coord = Coordinator::start(cfg.clone(), CrmEngine::Native, 8).unwrap();
    coord
        .serve(ServeRequest {
            items: vec![1],
            server: 0,
            time: Some(0.0),
        })
        .unwrap();
    drop(coord);

    // A surviving client observes a clean "down" error after shutdown.
    let coord = Coordinator::start(cfg, CrmEngine::Native, 3).unwrap();
    let client = coord.client();
    coord.shutdown();
    let err = client
        .serve(ServeRequest {
            items: vec![1],
            server: 0,
            time: Some(0.0),
        })
        .unwrap_err();
    assert!(err.to_string().contains("down"), "got: {err}");
}

/// Async tick mode over a parallel replay still serves everything and
/// keeps per-shard accounting consistent (costs may differ from the
/// ordered run — window composition is arrival-order dependent).
#[test]
fn parallel_async_replay_accounts_every_request() {
    let cfg = AkpcConfig {
        n_items: 40,
        n_servers: 32,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 10_000, 33);
    let rep = replay_sharded(
        &cfg,
        CrmEngine::Native,
        &trace,
        4,
        ReplayMode::Parallel,
    )
    .unwrap();
    assert_eq!(rep.metrics.ledger.requests, 10_000);
    assert_eq!(
        rep.metrics.ledger.full_hits + rep.metrics.ledger.misses,
        10_000
    );
    assert!(rep.metrics.windows > 0, "async ticks never ran");
    assert!(rep.metrics.ledger.total() > 0.0);
}

/// An ordered replay through `start_with(.., TickMode::Sync)` equals the
/// plain `start` path (same defaults), pinning the public API contract.
#[test]
fn start_defaults_to_sync_ticks() {
    let cfg = AkpcConfig {
        n_items: 24,
        n_servers: 12,
        crm_top_frac: 1.0,
        ..Default::default()
    };
    let trace = netflix_like(cfg.n_items, cfg.n_servers, 3_000, 34);
    let serve_all = |coord: &Coordinator| {
        for r in &trace.requests {
            coord
                .serve(ServeRequest {
                    items: r.items.clone(),
                    server: r.server,
                    time: Some(r.time),
                })
                .unwrap();
        }
    };
    let a = Coordinator::start(cfg.clone(), CrmEngine::Native, 3).unwrap();
    serve_all(&a);
    let ma = a.shutdown();
    let b = Coordinator::start_with(cfg, CrmEngine::Native, 3, TickMode::Sync)
        .unwrap();
    serve_all(&b);
    let mb = b.shutdown();
    assert_eq!(ma.ledger.c_t, mb.ledger.c_t);
    assert_eq!(ma.ledger.c_p, mb.ledger.c_p);
    assert_eq!(ma.ledger.full_hits, mb.ledger.full_hits);
}
