//! Streaming-engine acceptance suite (DESIGN.md §10):
//!
//! 1. streaming replay == materialized replay at 1e-9 rel on ledger
//!    totals, across binary (v1 + chunked v2) / CSV / generated sources,
//!    on the single-leader driver;
//! 2. the same equivalence through the 4-shard ordered coordinator;
//! 3. chunked-binary round-trips at many frame sizes, with corrupted
//!    headers rejected by self-explaining messages;
//! 4. the replay never pulls more than one bounded chunk at a time.

use akpc::algo::Akpc;
use akpc::config::AkpcConfig;
use akpc::run::{drive_trace, NullObserver};
use akpc::runtime::CrmEngine;
use akpc::sim::{self, replay_sharded_stream, ReplayMode};
use akpc::trace::generator::{generate, GeneratorParams, TraceKind};
use akpc::trace::io;
use akpc::trace::model::{Request, Trace};
use akpc::trace::stream::{
    BinaryStreamSource, CsvStreamSource, GeneratorSource, MemorySource, TraceMeta, TraceSource,
};
use akpc::util::tempdir::TempDir;

fn cfg(n_items: u32, n_servers: u32) -> AkpcConfig {
    AkpcConfig {
        n_items,
        n_servers,
        crm_top_frac: 1.0,
        ..Default::default()
    }
}

fn workload() -> (GeneratorParams, Trace) {
    let mut p = GeneratorParams::netflix(40, 24, 6_000);
    p.seed ^= 9;
    let t = generate(&p, TraceKind::Netflix);
    (p, t)
}

fn assert_close(label: &str, streamed: f64, materialized: f64) {
    let tol = 1e-9 * materialized.abs().max(1.0);
    assert!(
        (streamed - materialized).abs() <= tol,
        "{label}: streamed total {streamed} != materialized {materialized} \
         (diff {:.3e}, tol {:.3e})",
        (streamed - materialized).abs(),
        tol
    );
}

#[test]
fn streaming_replay_matches_materialized_single_leader() {
    let (params, trace) = workload();
    let cfg = cfg(trace.n_items, trace.n_servers);
    let dir = TempDir::new("stream-eq").unwrap();
    let bin = dir.file("t.bin");
    let chunked = dir.file("t.akpt");
    let csv = dir.file("t.csv");
    io::write_binary(&trace, &bin).unwrap();
    io::write_binary_chunked(&trace, &chunked, 500).unwrap();
    io::write_csv(&trace, &csv).unwrap();

    // Materialized baseline: the legacy path (now a MemorySource shim —
    // same code, but pinned against the pre-refactor semantics by the
    // unchanged sim/ and run_api tests).
    let baseline = sim::run(&mut Akpc::new(&cfg), &trace, cfg.batch_size);
    assert_eq!(baseline.ledger.requests, trace.len() as u64);

    // Chunk lengths deliberately coprime to the batch size: window
    // boundaries must not depend on how the source chunks.
    let sources: Vec<(&str, Box<dyn TraceSource>)> = vec![
        (
            "memory",
            Box::new(MemorySource::new(&trace).with_chunk_len(1_013)),
        ),
        (
            "binary-v1",
            Box::new(BinaryStreamSource::open(&bin, 777).unwrap()),
        ),
        (
            "binary-v2-chunked",
            Box::new(BinaryStreamSource::open(&chunked, 999).unwrap()),
        ),
        ("csv", Box::new(CsvStreamSource::open(&csv, 333).unwrap())),
        (
            "generated",
            Box::new(GeneratorSource::new(&params, TraceKind::Netflix, 431).unwrap()),
        ),
    ];
    for (label, mut source) in sources {
        let rep = drive_trace(
            &mut Akpc::new(&cfg),
            source.as_mut(),
            cfg.batch_size,
            &mut NullObserver,
        )
        .unwrap();
        assert_eq!(rep.ledger.requests, trace.len() as u64, "{label}");
        assert_eq!(rep.ledger.transfers, baseline.ledger.transfers, "{label}");
        assert_eq!(rep.ledger.full_hits, baseline.ledger.full_hits, "{label}");
        assert_close(label, rep.ledger.total(), baseline.ledger.total());
    }
}

#[test]
fn streaming_replay_matches_materialized_4shard_ordered() {
    let (params, trace) = workload();
    let cfg = cfg(trace.n_items, trace.n_servers);
    let dir = TempDir::new("stream-shard").unwrap();
    let chunked = dir.file("t.akpt");
    io::write_binary_chunked(&trace, &chunked, 640).unwrap();

    let single = sim::run(&mut Akpc::new(&cfg), &trace, cfg.batch_size);
    let materialized =
        sim::replay_sharded(&cfg, CrmEngine::Native, &trace, 4, ReplayMode::Ordered).unwrap();
    assert_close(
        "materialized-4shard-vs-single",
        materialized.metrics.ledger.total(),
        single.ledger.total(),
    );

    for (label, mut source) in [
        (
            "binary-v2-chunked",
            Box::new(BinaryStreamSource::open(&chunked, 512).unwrap()) as Box<dyn TraceSource>,
        ),
        (
            "generated",
            Box::new(GeneratorSource::new(&params, TraceKind::Netflix, 700).unwrap()),
        ),
    ] {
        let rep = replay_sharded_stream(
            &cfg,
            CrmEngine::Native,
            source.as_mut(),
            4,
            ReplayMode::Ordered,
        )
        .unwrap();
        assert_eq!(rep.n_shards, 4, "{label}");
        assert_eq!(rep.metrics.ledger.requests, trace.len() as u64, "{label}");
        assert_close(
            label,
            rep.metrics.ledger.total(),
            materialized.metrics.ledger.total(),
        );
        assert_close(label, rep.metrics.ledger.total(), single.ledger.total());
        sim::replay::assert_shard_sum_matches(&rep, single.ledger.total());
    }
}

#[test]
fn streaming_parallel_sharded_accounts_all_requests() {
    // Parallel mode is nondeterministic in window composition but must
    // still serve every request exactly once through bounded channels.
    let (params, trace) = workload();
    let cfg = cfg(trace.n_items, trace.n_servers);
    let mut source = GeneratorSource::new(&params, TraceKind::Netflix, 256).unwrap();
    let rep = replay_sharded_stream(
        &cfg,
        CrmEngine::Native,
        &mut source,
        4,
        ReplayMode::Parallel,
    )
    .unwrap();
    assert_eq!(rep.metrics.ledger.requests, trace.len() as u64);
    assert_eq!(rep.metrics.per_shard.len(), 4);
    assert!(rep.metrics.ledger.total() > 0.0);
}

#[test]
fn chunked_binary_round_trips_at_many_frame_sizes() {
    let (_, trace) = workload();
    let dir = TempDir::new("stream-rt").unwrap();
    for chunk in [1usize, 7, 100, 4_096, 100_000] {
        let p = dir.file(&format!("t-{chunk}.akpt"));
        io::write_binary_chunked(&trace, &p, chunk).unwrap();
        let back = io::read_binary(&p).unwrap();
        assert_eq!(back.requests, trace.requests, "chunk {chunk}");
        assert_eq!(back.n_items, trace.n_items);
        assert_eq!(back.name, trace.name);
        // And the streaming reader sees one frame per pull.
        let mut src = BinaryStreamSource::open(&p, 1).unwrap();
        let mut buf = Vec::new();
        assert!(src.next_chunk(&mut buf).unwrap());
        assert_eq!(buf.len(), chunk.min(trace.len()), "chunk {chunk}");
    }
}

#[test]
fn corrupted_headers_fail_with_named_causes() {
    let dir = TempDir::new("stream-corrupt").unwrap();

    // Wrong magic: the error names the expected format.
    let garbage = dir.file("garbage.akpt");
    std::fs::write(&garbage, b"JUNKJUNKJUNKJUNKJUNK").unwrap();
    let err = BinaryStreamSource::open(&garbage, 16).unwrap_err().to_string();
    assert!(err.contains("AKPT"), "magic error should name the format: {err}");
    assert!(io::read_binary(&garbage).unwrap_err().to_string().contains("AKPT"));

    // Unsupported version.
    let vfile = dir.file("v7.akpt");
    let mut bytes = b"AKPT".to_vec();
    bytes.extend_from_slice(&7u32.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 20]);
    std::fs::write(&vfile, &bytes).unwrap();
    let err = BinaryStreamSource::open(&vfile, 16).unwrap_err().to_string();
    assert!(err.contains("unsupported version 7"), "{err}");

    // Truncated mid-header and mid-frame.
    let (_, trace) = workload();
    let full = dir.file("full.akpt");
    io::write_binary_chunked(&trace, &full, 512).unwrap();
    let data = std::fs::read(&full).unwrap();
    let cut_header = dir.file("cut-header.akpt");
    std::fs::write(&cut_header, &data[..10]).unwrap();
    let err = BinaryStreamSource::open(&cut_header, 16)
        .unwrap_err()
        .to_string();
    assert!(err.contains("truncated"), "{err}");
    let cut_frame = dir.file("cut-frame.akpt");
    std::fs::write(&cut_frame, &data[..data.len() / 2]).unwrap();
    let mut src = BinaryStreamSource::open(&cut_frame, 16).unwrap();
    let err = src.collect().unwrap_err().to_string();
    assert!(err.contains("truncated") || err.contains("corrupt"), "{err}");
}

/// Wraps a source and audits the chunk discipline: how many pulls, and
/// the largest chunk ever resident.
struct ChunkAudit<S: TraceSource> {
    inner: S,
    max_chunk: usize,
    pulls: usize,
}

impl<S: TraceSource> TraceSource for ChunkAudit<S> {
    fn meta(&self) -> &TraceMeta {
        self.inner.meta()
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        let more = self.inner.next_chunk(buf)?;
        self.max_chunk = self.max_chunk.max(buf.len());
        if more {
            self.pulls += 1;
        }
        Ok(more)
    }
}

#[test]
fn streaming_replay_never_holds_more_than_one_chunk() {
    // The acceptance property behind the 1M-request CI smoke run: the
    // driver consumes a generated stream chunk by chunk — the full
    // Vec<Request> never exists. Audited here at 50k requests so the
    // test stays fast; the chunk bound is independent of length.
    let mut p = GeneratorParams::netflix(40, 24, 50_000);
    p.seed ^= 31;
    let chunk_len = 1_024;
    let mut audit = ChunkAudit {
        inner: GeneratorSource::new(&p, TraceKind::Netflix, chunk_len).unwrap(),
        max_chunk: 0,
        pulls: 0,
    };
    let cfg = cfg(40, 24);
    let rep = drive_trace(
        &mut Akpc::new(&cfg),
        &mut audit,
        cfg.batch_size,
        &mut NullObserver,
    )
    .unwrap();
    assert_eq!(rep.ledger.requests, 50_000);
    assert!(
        audit.max_chunk <= chunk_len,
        "chunk bound violated: {} > {chunk_len}",
        audit.max_chunk
    );
    assert_eq!(audit.pulls, 50_000 / chunk_len + 1, "stream was pulled chunkwise");
}
