//! Daemon configuration (DESIGN.md §12.3): a small TOML file with the
//! serving knobs at the root and the full cost-model block in an
//! `[akpc]` table, parsed by the same `toml_lite` reader as `akpc
//! sweep` configs.
//!
//! ```toml
//! policy = "akpc"
//! engine = "native"
//! shards = 4
//! slack = 1.0            # admission reorder window (time units)
//! reorder_capacity = 65536
//! chunk = 8192           # replay chunk length
//! max_items = 64         # per-request item cap
//! queue_depth = 64       # admission -> replay chunks in flight
//! shed_depth = 0         # overload shed threshold, 0 = never shed
//!
//! [akpc]
//! n_servers = 600
//! n_items = 60
//! ```
//!
//! Validation is delegated, not duplicated: [`ServeConfig::validate`]
//! builds a one-request probe [`RunSpec`](crate::run::RunSpec) with the
//! configured policy/engine/shards/cost-model and runs it through
//! `RunSpec::validate()`, so the daemon accepts exactly the specs the
//! offline runner would — hot-reload (`reload.rs`) re-runs the same
//! check before swapping anything in.

use crate::bench::sweep::EngineChoice;
use crate::config::{toml_lite, AkpcConfig};
use crate::run::{PolicyRegistry, RunSpec};
use crate::sim::ReplayMode;
use crate::trace::model::{Request, Trace};
use crate::trace::stream::DEFAULT_CHUNK_LEN;

/// Everything `akpc serve` needs to run: serving knobs + cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Policy name resolved against the registry (default `"akpc"`).
    pub policy: String,
    /// CRM engine backing the coordinator shards.
    pub engine: EngineChoice,
    /// Shard-actor count for the live coordinator.
    pub shards: usize,
    /// Admission slack window in trace-time units (see §12.2).
    pub slack: f64,
    /// Reorder-buffer capacity before force-release kicks in.
    pub reorder_capacity: usize,
    /// Chunk length shipped from admission to the replay thread.
    pub chunk: usize,
    /// Per-request item-count cap enforced at admission.
    pub max_items: usize,
    /// Bounded admission→replay channel depth, in chunks.
    pub queue_depth: usize,
    /// Overload degradation threshold (DESIGN.md §14.4): when the
    /// admission→replay queue holds at least this many chunks, the
    /// replay thread sheds whole chunks at NoPacking pass-through cost
    /// instead of running the packer. `0` disables shedding entirely.
    pub shed_depth: usize,
    /// The cost-model / universe block (the `[akpc]` table).
    pub akpc: AkpcConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            policy: "akpc".into(),
            engine: EngineChoice::Native,
            shards: 1,
            slack: 1.0,
            reorder_capacity: 65_536,
            chunk: DEFAULT_CHUNK_LEN,
            max_items: 64,
            queue_depth: 64,
            shed_depth: 0,
            akpc: AkpcConfig::default(),
        }
    }
}

fn num_field(key: &str, v: &toml_lite::Value) -> anyhow::Result<usize> {
    let n = v
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("serve config: `{key}` must be a number"))?;
    anyhow::ensure!(
        n.is_finite() && n >= 0.0 && n.fract() == 0.0,
        "serve config: `{key}` must be a non-negative integer, got {n}"
    );
    Ok(n as usize)
}

impl ServeConfig {
    /// Parse from TOML text. Unknown keys are errors in both the root
    /// block and the `[akpc]` table — a typo'd knob must not silently
    /// run with its default.
    pub fn from_toml_str(text: &str) -> anyhow::Result<Self> {
        let doc = toml_lite::parse_doc(text)?;
        let mut cfg = Self::default();
        for (key, v) in &doc.root {
            match key.as_str() {
                "policy" => {
                    cfg.policy = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("serve config: `policy` must be a string"))?
                        .to_string();
                }
                "engine" => {
                    let name = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("serve config: `engine` must be a string"))?;
                    cfg.engine = match name {
                        "native" => EngineChoice::Native,
                        "xla" => EngineChoice::Xla,
                        other => anyhow::bail!("serve config: unknown engine `{other}`"),
                    };
                }
                "shards" => cfg.shards = num_field(key, v)?,
                "reorder_capacity" => cfg.reorder_capacity = num_field(key, v)?,
                "chunk" => cfg.chunk = num_field(key, v)?,
                "max_items" => cfg.max_items = num_field(key, v)?,
                "queue_depth" => cfg.queue_depth = num_field(key, v)?,
                "shed_depth" => cfg.shed_depth = num_field(key, v)?,
                "slack" => {
                    cfg.slack = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("serve config: `slack` must be a number"))?;
                }
                other => anyhow::bail!("serve config: unknown key `{other}`"),
            }
        }
        for (name, table) in &doc.tables {
            match name.as_str() {
                "akpc" => cfg.akpc.apply_toml_map(table)?,
                other => anyhow::bail!("serve config: unknown table `[{other}]`"),
            }
        }
        Ok(cfg)
    }

    /// Parse from a TOML file on disk.
    pub fn from_toml_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read serve config {path}: {e}"))?;
        Self::from_toml_str(&text)
    }

    /// Validate the serving knobs, then prove the policy/engine/shard
    /// combination viable by validating a one-request probe `RunSpec`
    /// against `registry` — the single source of truth for what the
    /// runner accepts.
    pub fn validate(&self, registry: &PolicyRegistry) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.slack.is_finite() && self.slack >= 0.0,
            "serve config: slack must be finite and >= 0, got {}",
            self.slack
        );
        for (key, v) in [
            ("shards", self.shards),
            ("reorder_capacity", self.reorder_capacity),
            ("chunk", self.chunk),
            ("max_items", self.max_items),
            ("queue_depth", self.queue_depth),
        ] {
            anyhow::ensure!(v >= 1, "serve config: `{key}` must be >= 1");
        }
        let probe = Trace {
            requests: vec![Request::new(vec![0], 0, 0.0)],
            n_items: self.akpc.n_items,
            n_servers: self.akpc.n_servers,
            name: "serve-validate-probe".into(),
        };
        RunSpec::new()
            .config(self.akpc.clone())
            .inline_trace(probe)
            .policy(&self.policy)
            .engine(self.engine)
            .sharded(self.shards, ReplayMode::Ordered)
            .validate(registry)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config_with_akpc_table() {
        let cfg = ServeConfig::from_toml_str(
            "policy = \"no-packing\"\nengine = \"xla\"\nshards = 4\n\
             slack = 2.5\nreorder_capacity = 128\nchunk = 16\n\
             max_items = 8\nqueue_depth = 3\nshed_depth = 2\n\n[akpc]\nn_servers = 40\nn_items = 20\n",
        )
        .unwrap();
        assert_eq!(cfg.policy, "no-packing");
        assert_eq!(cfg.engine, EngineChoice::Xla);
        assert_eq!((cfg.shards, cfg.chunk, cfg.queue_depth), (4, 16, 3));
        assert_eq!(cfg.shed_depth, 2);
        assert_eq!(cfg.slack, 2.5);
        assert_eq!(cfg.akpc.n_servers, 40);
        assert_eq!(cfg.akpc.n_items, 20);
    }

    #[test]
    fn defaults_survive_empty_input() {
        let cfg = ServeConfig::from_toml_str("").unwrap();
        assert_eq!(cfg, ServeConfig::default());
        cfg.validate(&PolicyRegistry::builtin()).unwrap();
    }

    #[test]
    fn rejects_unknown_keys_and_tables() {
        assert!(ServeConfig::from_toml_str("slacc = 1.0\n").is_err());
        assert!(ServeConfig::from_toml_str("[akcp]\nn_servers = 4\n").is_err());
        assert!(ServeConfig::from_toml_str("[akpc]\nn_srvrs = 4\n").is_err());
        assert!(ServeConfig::from_toml_str("engine = \"cuda\"\n").is_err());
        assert!(ServeConfig::from_toml_str("shards = 1.5\n").is_err());
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let reg = PolicyRegistry::builtin();
        let mut cfg = ServeConfig::default();
        cfg.slack = f64::NAN;
        assert!(cfg.validate(&reg).is_err());

        let mut cfg = ServeConfig::default();
        cfg.shards = 0;
        assert!(cfg.validate(&reg).is_err());

        let mut cfg = ServeConfig::default();
        cfg.policy = "no-such-policy".into();
        assert!(cfg.validate(&reg).is_err());

        // An invalid cost model must be caught by the RunSpec probe.
        let mut cfg = ServeConfig::default();
        cfg.akpc.mu = -1.0;
        assert!(cfg.validate(&reg).is_err());
    }
}
