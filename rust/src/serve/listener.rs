//! The ingest acceptor (DESIGN.md §12.1): one nonblocking accept loop
//! on a dedicated thread, one pump thread per connection, and a
//! registry so drain can shut every socket down and join every handler
//! deterministically.
//!
//! Each connection's format is sniffed from its first four bytes:
//! `AKPT` selects the binary trace format (header + v1/v2 records),
//! anything else is treated as newline-delimited text frames. The
//! sniffed bytes are chained back in front of the stream so both pumps
//! see the connection from byte zero.

use std::io::{BufReader, Cursor, Read};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

use super::admission::Admission;
use super::framing::{pump_binary, pump_text, MAGIC};

#[derive(Default)]
struct ConnInner {
    streams: Vec<TcpStream>,
    handles: Vec<JoinHandle<()>>,
    closed: bool,
}

/// Tracks live ingest connections so drain can close and join them.
#[derive(Default)]
pub(crate) struct ConnRegistry {
    inner: Mutex<ConnInner>,
}

impl ConnRegistry {
    fn lock(&self) -> std::sync::MutexGuard<'_, ConnInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register a connection's stream clone + handler thread. If the
    /// registry is already closed (drain raced the accept), the socket
    /// is shut down immediately so the handler sees EOF right away.
    fn register(&self, stream: TcpStream, handle: JoinHandle<()>) {
        let mut g = self.lock();
        if g.closed {
            let _ = stream.shutdown(Shutdown::Both);
        }
        g.streams.push(stream);
        g.handles.push(handle);
        // Opportunistically reap finished handlers so a long-lived
        // daemon's registry doesn't grow with every short connection.
        let mut i = 0;
        while i < g.handles.len() {
            if g.handles[i].is_finished() {
                let h = g.handles.swap_remove(i);
                let _ = g.streams.swap_remove(i);
                if let Err(p) = h.join() {
                    std::panic::resume_unwind(p);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Close every live socket and join every handler. New connections
    /// registered afterwards are shut down on sight.
    pub(crate) fn shutdown_all(&self) {
        let (streams, handles) = {
            let mut g = self.lock();
            g.closed = true;
            (std::mem::take(&mut g.streams), std::mem::take(&mut g.handles))
        };
        for s in &streams {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    }
}

/// Handle one ingest connection: sniff the format, then pump frames
/// into admission until EOF / shutdown. Frame-level errors only end
/// this connection; admission-closed errors mean the daemon is
/// draining, which is not this connection's problem to report loudly.
fn handle_conn(mut stream: TcpStream, admission: &Admission) {
    let mut head = [0u8; 4];
    let mut filled = 0usize;
    while filled < head.len() {
        match stream.read(&mut head[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
    if filled == 0 {
        return; // connect-and-close probe (health checks do this)
    }
    let sniffed = Cursor::new(head[..filled].to_vec());
    let mut writer = stream.try_clone().ok();
    let mut rdr = BufReader::new(sniffed.chain(&stream));
    let result = if head[..filled] == *MAGIC {
        pump_binary(&mut rdr, admission)
    } else {
        // Text mode gets the back channel (resume handshake + acks);
        // losing the clone only loses acks, never frames.
        pump_text(
            &mut rdr,
            admission,
            writer.as_mut().map(|w| w as &mut dyn std::io::Write),
        )
    };
    if let Err(e) = result {
        eprintln!("akpc-serve: connection ended with error: {e:#}");
    }
}

/// Spawn the acceptor thread. Polls `stop` between accepts; every
/// accepted connection gets its own named pump thread and a registry
/// entry for drain.
pub(crate) fn spawn_ingest(
    listener: TcpListener,
    admission: Arc<Admission>,
    conns: Arc<ConnRegistry>,
    stop: Arc<AtomicBool>,
) -> anyhow::Result<JoinHandle<()>> {
    listener.set_nonblocking(true)?;
    let handle = std::thread::Builder::new()
        .name("akpc-serve-accept".into())
        .spawn(move || loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_nonblocking(false);
                    let Ok(clone) = stream.try_clone() else {
                        continue;
                    };
                    let adm = Arc::clone(&admission);
                    let spawned = std::thread::Builder::new()
                        .name("akpc-serve-conn".into())
                        .spawn(move || handle_conn(stream, &adm));
                    match spawned {
                        Ok(h) => conns.register(clone, h),
                        Err(e) => {
                            eprintln!("akpc-serve: spawn connection handler: {e}");
                            let _ = clone.shutdown(Shutdown::Both);
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        })?;
    Ok(handle)
}
