//! Daemon lifecycle (DESIGN.md §12): wiring, the control loop, and the
//! graceful-drain sequence.
//!
//! Thread roster (all spawned by [`ServeDaemon::start`], all joined by
//! drain):
//!
//! * `akpc-serve-accept` — ingest acceptor ([`super::listener`]).
//! * `akpc-serve-conn` (×N) — per-connection frame pumps.
//! * `akpc-serve-replay` — drains the admission [`ChannelSource`] and
//!   issues the per-request `serve` loop against the coordinator, the
//!   exact loop `replay_sharded_stream` runs offline. It locks the
//!   client mutex **per chunk**, so hot-reload's epoch swap (which
//!   holds the same mutex) lands only at chunk boundaries.
//! * `akpc-serve-http` — the status endpoint ([`super::http`]).
//! * `akpc-serve-control` — owns the drain sequence; everything else
//!   reaches it through one bounded [`ControlMsg`] channel.
//!
//! Drain ordering (SIGTERM or `POST /drain`), each step a happens-before
//! edge: stop accepting → close + join connections (their final offers
//! complete because the replay thread is still consuming) → close the
//! admission stream (flushing the reorder buffer) → join replay (every
//! admitted request now served) → coordinator `shutdown()` (quiesce
//! barrier sweeps retention rent to the global max time) → final
//! merged-epoch snapshot. The trailing partial clique-generation window
//! is deliberately **not** flushed: offline sharded replay never
//! dispatches it either, and the live-vs-replay ledger equivalence
//! (`tests/serve.rs`) depends on both sides agreeing.
//!
//! [`ChannelSource`]: crate::trace::stream::ChannelSource

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, CoordinatorClient, MetricsSnapshot, ServeRequest, TickMode};
use crate::run::PolicyRegistry;
use crate::trace::stream::{TraceMeta, TraceSource};

use super::admission::{Admission, AdmissionStats};
use super::config::ServeConfig;
use super::listener::ConnRegistry;
use super::reload::{apply_reload, merge_epochs};

/// Requests the HTTP endpoint (and tests) send to the control loop.
pub(crate) enum ControlMsg {
    /// Render the live Prometheus text and reply on the channel.
    Scrape(mpsc::SyncSender<String>),
    /// Begin the graceful-drain sequence.
    Drain,
    /// Re-read the config file; reply `Ok(summary)` or `Err(reason)`.
    Reload(mpsc::SyncSender<Result<String, String>>),
}

/// Shared daemon state: the admission layer plus the current
/// coordinator epoch. `client` is the replay thread's handle — swapping
/// it (hot-reload) requires its mutex, which replay holds per chunk.
pub(crate) struct DaemonState {
    cfg: Mutex<ServeConfig>,
    pub(crate) admission: Arc<Admission>,
    pub(crate) client: Mutex<CoordinatorClient>,
    pub(crate) coordinator: Mutex<Option<Coordinator>>,
    /// Final snapshots of coordinator epochs retired by hot-reload.
    pub(crate) prior: Mutex<Vec<MetricsSnapshot>>,
    config_path: Option<String>,
}

impl DaemonState {
    pub(crate) fn config(&self) -> ServeConfig {
        self.cfg
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    pub(crate) fn set_config(&self, cfg: ServeConfig) {
        *self.cfg.lock().unwrap_or_else(PoisonError::into_inner) = cfg;
    }

    /// Render the merged-epoch Prometheus text plus the admission and
    /// daemon-level families.
    fn render_metrics(&self) -> anyhow::Result<String> {
        // Clone the client out of the lock so a slow scrape never
        // stalls the replay thread.
        let client = self
            .client
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone();
        let live = client.metrics()?;
        let prior = self.prior.lock().unwrap_or_else(PoisonError::into_inner);
        let merged = merge_epochs(&prior, live);
        let epochs = prior.len() + 1;
        drop(prior);
        let mut out = merged.to_prometheus();
        let s = self.admission.stats();
        for (name, help, v) in [
            (
                "akpc_admission_admitted_total",
                "Frames admitted into the reorder buffer",
                s.admitted,
            ),
            (
                "akpc_admission_rejected_late_total",
                "Frames rejected for regressing beyond the slack window",
                s.rejected_late,
            ),
            (
                "akpc_admission_rejected_malformed_total",
                "Frames rejected by validation or parsing",
                s.rejected_malformed,
            ),
            (
                "akpc_admission_forced_releases_total",
                "Reorder-buffer entries force-released at capacity",
                s.forced_releases,
            ),
        ] {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        out.push_str(&format!(
            "# HELP akpc_serve_epochs Coordinator epochs (1 + completed hot-reload swaps)\n\
             # TYPE akpc_serve_epochs gauge\nakpc_serve_epochs {epochs}\n"
        ));
        Ok(out)
    }
}

/// Listener/endpoint addresses and the optional reloadable config file.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Ingest listen address, e.g. `127.0.0.1:4780` (`:0` = ephemeral).
    pub listen: String,
    /// Status-endpoint listen address; `None` disables HTTP.
    pub http: Option<String>,
    /// TOML config path re-read on `POST /reload` / `reload()`.
    pub config_path: Option<String>,
}

/// What a drained daemon hands back.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Final metrics, merged across all coordinator epochs.
    pub metrics: MetricsSnapshot,
    /// Coordinator epochs run (1 + hot-reload restarts).
    pub epochs: usize,
    /// Final admission counters.
    pub admission: AdmissionStats,
    /// Wall-clock seconds from start to drain completion.
    pub wall_secs: f64,
    /// Served requests per wall-clock second.
    pub requests_per_sec: f64,
}

/// A running `akpc serve` daemon. Dropping it drains gracefully.
pub struct ServeDaemon {
    state: Arc<DaemonState>,
    ctl_tx: mpsc::SyncSender<ControlMsg>,
    ingest_addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    control_join: Option<JoinHandle<anyhow::Result<ServeReport>>>,
    stop: Arc<AtomicBool>,
}

/// Bounded control-channel depth: drains, scrapes, and reloads are rare
/// and each sender blocks on its reply anyway.
const CONTROL_QUEUE_DEPTH: usize = 8;

impl ServeDaemon {
    /// Validate `cfg`, bind the listeners, start the coordinator and
    /// all daemon threads. Returns once the daemon is accepting.
    pub fn start(cfg: ServeConfig, opts: ServeOptions) -> anyhow::Result<Self> {
        cfg.validate(&PolicyRegistry::builtin())?;

        let ingest = TcpListener::bind(&opts.listen)
            .map_err(|e| anyhow::anyhow!("bind ingest {}: {e}", opts.listen))?;
        let ingest_addr = ingest.local_addr()?;
        let http_listener = match &opts.http {
            Some(addr) => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("bind http {addr}: {e}"))?;
                Some(l)
            }
            None => None,
        };
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };

        let meta = TraceMeta {
            n_items: cfg.akpc.n_items,
            n_servers: cfg.akpc.n_servers,
            est_len: None,
            name: "live-ingest".into(),
        };
        let (admission, source) = Admission::new(
            meta,
            cfg.slack,
            cfg.reorder_capacity,
            cfg.chunk,
            cfg.queue_depth,
        );
        admission.set_max_items(cfg.max_items);
        let admission = Arc::new(admission);

        let coordinator = Coordinator::start_with(
            cfg.akpc.clone(),
            cfg.engine.to_engine(),
            cfg.shards,
            TickMode::Sync,
        )?;
        let state = Arc::new(DaemonState {
            client: Mutex::new(coordinator.client()),
            coordinator: Mutex::new(Some(coordinator)),
            prior: Mutex::new(Vec::new()),
            admission: Arc::clone(&admission),
            cfg: Mutex::new(cfg),
            config_path: opts.config_path.clone(),
        });

        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(ConnRegistry::default());
        let accept_join = super::listener::spawn_ingest(
            ingest,
            Arc::clone(&admission),
            Arc::clone(&conns),
            Arc::clone(&stop),
        )?;
        let (ctl_tx, ctl_rx) = mpsc::sync_channel(CONTROL_QUEUE_DEPTH);
        let http_join = match http_listener {
            Some(l) => Some(super::http::spawn_http(l, ctl_tx.clone(), Arc::clone(&stop))?),
            None => None,
        };

        sig::install_sigterm_hook();

        let replay_state = Arc::clone(&state);
        let replay_join = std::thread::Builder::new()
            .name("akpc-serve-replay".into())
            .spawn(move || -> anyhow::Result<()> {
                let mut source = source;
                let mut buf = Vec::new();
                while source.next_chunk(&mut buf)? {
                    let client = replay_state
                        .client
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    for r in buf.drain(..) {
                        client.serve(ServeRequest {
                            items: r.items,
                            server: r.server,
                            time: Some(r.time),
                        })?;
                    }
                }
                Ok(())
            })?;

        let ctl_state = Arc::clone(&state);
        let ctl_stop = Arc::clone(&stop);
        let started = Instant::now();
        let control_join = std::thread::Builder::new()
            .name("akpc-serve-control".into())
            .spawn(move || -> anyhow::Result<ServeReport> {
                // Built here, not passed in: the registry's boxed
                // factories are not Send.
                let registry = PolicyRegistry::builtin();
                loop {
                    if sig::take_sigterm() {
                        break;
                    }
                    match ctl_rx.recv_timeout(Duration::from_millis(200)) {
                        Ok(ControlMsg::Drain) => break,
                        Ok(ControlMsg::Scrape(tx)) => {
                            let body = ctl_state
                                .render_metrics()
                                .unwrap_or_else(|e| format!("# scrape failed: {e}\n"));
                            let _ = tx.send(body);
                        }
                        Ok(ControlMsg::Reload(tx)) => {
                            let outcome = match &ctl_state.config_path {
                                None => Err("no --serve-config file to reload".to_string()),
                                Some(path) => apply_reload(&ctl_state, &registry, path)
                                    .map(|o| o.summary)
                                    .map_err(|e| format!("{e:#}")),
                            };
                            let _ = tx.send(outcome);
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        // Every control sender gone: drain rather than
                        // spin forever with no way to be told to stop.
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }

                // ---- drain sequence (see module docs for ordering) ----
                ctl_stop.store(true, Ordering::SeqCst);
                if let Err(p) = accept_join.join() {
                    std::panic::resume_unwind(p);
                }
                conns.shutdown_all();
                // Close the stream; an error here means replay already
                // stopped, which the join below will surface.
                let _ = ctl_state.admission.finish();
                let replay_result = match replay_join.join() {
                    Ok(r) => r,
                    Err(p) => std::panic::resume_unwind(p),
                };
                let last = {
                    let mut slot = ctl_state
                        .coordinator
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    match slot.take() {
                        Some(c) => c.shutdown(),
                        None => anyhow::bail!("coordinator already shut down"),
                    }
                };
                // Shutdown was clean either way; only now surface a
                // replay failure so the ledger above stays exact.
                replay_result?;
                let prior = {
                    let mut g = ctl_state
                        .prior
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    std::mem::take(&mut *g)
                };
                let epochs = prior.len() + 1;
                let metrics = merge_epochs(&prior, last);
                if let Some(h) = http_join {
                    if let Err(p) = h.join() {
                        std::panic::resume_unwind(p);
                    }
                }
                let wall_secs = started.elapsed().as_secs_f64();
                let served = metrics.served;
                Ok(ServeReport {
                    metrics,
                    epochs,
                    admission: ctl_state.admission.stats(),
                    wall_secs,
                    requests_per_sec: if wall_secs > 0.0 {
                        served as f64 / wall_secs
                    } else {
                        0.0
                    },
                })
            })?;

        Ok(Self {
            state,
            ctl_tx,
            ingest_addr,
            http_addr,
            control_join: Some(control_join),
            stop,
        })
    }

    /// The bound ingest address (resolved, so `:0` shows the real port).
    pub fn ingest_addr(&self) -> SocketAddr {
        self.ingest_addr
    }

    /// The bound status-endpoint address, if HTTP was enabled.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Live admission counters.
    pub fn admission_stats(&self) -> AdmissionStats {
        self.state.admission.stats()
    }

    /// Scrape the live Prometheus text in-process (what `GET /metrics`
    /// returns over HTTP).
    pub fn metrics_text(&self) -> anyhow::Result<String> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.ctl_tx
            .send(ControlMsg::Scrape(tx))
            .map_err(|_| anyhow::anyhow!("daemon control loop is gone"))?;
        rx.recv_timeout(Duration::from_secs(5))
            .map_err(|_| anyhow::anyhow!("scrape timed out"))
    }

    /// Re-read the config file (same path `POST /reload` takes).
    pub fn reload(&self) -> anyhow::Result<String> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.ctl_tx
            .send(ControlMsg::Reload(tx))
            .map_err(|_| anyhow::anyhow!("daemon control loop is gone"))?;
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Ok(summary)) => Ok(summary),
            Ok(Err(e)) => anyhow::bail!("reload rejected: {e}"),
            Err(_) => anyhow::bail!("reload timed out"),
        }
    }

    /// Gracefully drain: stop accepting, flush admission, serve every
    /// admitted request, quiesce the coordinator, return the exact
    /// final report.
    pub fn drain(mut self) -> anyhow::Result<ServeReport> {
        let _ = self.ctl_tx.send(ControlMsg::Drain);
        self.join_inner()
    }

    /// Wait for the daemon to drain on its own (SIGTERM or
    /// `POST /drain`).
    pub fn join(mut self) -> anyhow::Result<ServeReport> {
        self.join_inner()
    }

    fn join_inner(&mut self) -> anyhow::Result<ServeReport> {
        let Some(handle) = self.control_join.take() else {
            anyhow::bail!("daemon already joined");
        };
        match handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        if self.control_join.is_some() {
            let _ = self.ctl_tx.send(ControlMsg::Drain);
            self.stop.store(true, Ordering::SeqCst);
            let _ = self.join_inner();
        }
    }
}

/// SIGTERM → drain, without a signal-handling dependency: the handler
/// only flips an atomic the control loop polls (async-signal-safe).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SIGTERM_PENDING: AtomicBool = AtomicBool::new(false);
    static HOOK_INSTALLED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigterm(_signum: i32) {
        SIGTERM_PENDING.store(true, Ordering::SeqCst);
    }

    pub(super) fn install_sigterm_hook() {
        if HOOK_INSTALLED.swap(true, Ordering::SeqCst) {
            return;
        }
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let handler = on_sigterm as extern "C" fn(i32) as usize;
        // akpc-lint has no rule against unsafe; this is the only unsafe
        // block in the crate and it wraps one libc call.
        unsafe {
            signal(SIGTERM, handler);
        }
    }

    pub(super) fn take_sigterm() -> bool {
        SIGTERM_PENDING.swap(false, Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub(super) fn install_sigterm_hook() {}

    pub(super) fn take_sigterm() -> bool {
        false
    }
}
