//! Daemon lifecycle (DESIGN.md §12): wiring, the control loop, and the
//! graceful-drain sequence.
//!
//! Thread roster (all spawned by [`ServeDaemon::start`], all joined by
//! drain):
//!
//! * `akpc-serve-accept` — ingest acceptor ([`super::listener`]).
//! * `akpc-serve-conn` (×N) — per-connection frame pumps.
//! * `akpc-serve-replay` — drains the admission [`ChannelSource`] and
//!   issues the per-request `serve` loop against the coordinator, the
//!   exact loop `replay_sharded_stream` runs offline. It locks the
//!   client mutex **per chunk**, so hot-reload's epoch swap (which
//!   holds the same mutex) lands only at chunk boundaries.
//! * `akpc-serve-http` — the status endpoint ([`super::http`]).
//! * `akpc-serve-control` — owns the drain sequence; everything else
//!   reaches it through one bounded [`ControlMsg`] channel.
//!
//! Drain ordering (SIGTERM or `POST /drain`), each step a happens-before
//! edge: stop accepting → close + join connections (their final offers
//! complete because the replay thread is still consuming) → close the
//! admission stream (flushing the reorder buffer) → join replay (every
//! admitted request now served) → coordinator `shutdown()` (quiesce
//! barrier sweeps retention rent to the global max time) → final
//! merged-epoch snapshot. The trailing partial clique-generation window
//! is deliberately **not** flushed: offline sharded replay never
//! dispatches it either, and the live-vs-replay ledger equivalence
//! (`tests/serve.rs`) depends on both sides agreeing.
//!
//! Robustness (DESIGN.md §14) threads through the same loop: the replay
//! thread captures per-shard shadows at chunk boundaries and rebuilds
//! the fleet in place when a serve surfaces [`ShardLost`]; it sheds
//! whole chunks at NoPacking pass-through cost when the admission queue
//! crosses `shed_depth`; and the control loop writes periodic + final
//! checkpoints when `--checkpoint-dir` is set, which `start` restores
//! from (raising the admission floor to the persisted served watermark
//! so resent frames dedup exactly).
//!
//! [`ChannelSource`]: crate::trace::stream::ChannelSource

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{CopyRecord, CostModel};
use crate::coordinator::{
    Coordinator, CoordinatorClient, MetricsSnapshot, ServeRequest, ShardLost, ShardStats, TickMode,
};
use crate::fault::Checkpoint;
use crate::run::PolicyRegistry;
use crate::trace::model::Request;
use crate::trace::stream::{TraceMeta, TraceSource};

use super::admission::{Admission, AdmissionStats};
use super::config::ServeConfig;
use super::listener::ConnRegistry;
use super::reload::{apply_reload, merge_epochs};

/// Requests the HTTP endpoint (and tests) send to the control loop.
pub(crate) enum ControlMsg {
    /// Render the live Prometheus text and reply on the channel.
    Scrape(mpsc::SyncSender<String>),
    /// Begin the graceful-drain sequence.
    Drain,
    /// Re-read the config file; reply `Ok(summary)` or `Err(reason)`.
    Reload(mpsc::SyncSender<Result<String, String>>),
}

/// Robustness counters (DESIGN.md §14): recoveries, degradation
/// shedding, and checkpoint outcomes, surfaced on `/metrics` and in the
/// final [`ServeReport`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DaemonCounters {
    /// Shard fleets rebuilt after a lost shard (panic or stall).
    pub recoveries: u64,
    /// Total transfer cost charged to re-fetch copies lost with dead
    /// shards (the exact recovery surcharge over a never-faulted run).
    pub recharge_cost: f64,
    /// Requests shed to NoPacking pass-through under overload.
    pub shed_requests: u64,
    /// Items inside those shed requests.
    pub shed_items: u64,
    /// Cost charged for shed traffic (Σ `transfer_packed(1)` per item).
    pub shed_cost: f64,
    /// Checkpoints written successfully.
    pub checkpoints_written: u64,
    /// Checkpoint attempts that failed (I/O error or injected fault);
    /// the previous on-disk slot survives each failure.
    pub checkpoint_failures: u64,
}

/// Shared daemon state: the admission layer plus the current
/// coordinator epoch. `client` is the replay thread's handle — swapping
/// it (hot-reload or recovery) requires its mutex, which replay holds
/// per chunk.
pub(crate) struct DaemonState {
    cfg: Mutex<ServeConfig>,
    pub(crate) admission: Arc<Admission>,
    pub(crate) client: Mutex<CoordinatorClient>,
    pub(crate) coordinator: Mutex<Option<Coordinator>>,
    /// Final snapshots of coordinator epochs retired by hot-reload.
    pub(crate) prior: Mutex<Vec<MetricsSnapshot>>,
    pub(crate) counters: Mutex<DaemonCounters>,
    config_path: Option<String>,
}

impl DaemonState {
    pub(crate) fn config(&self) -> ServeConfig {
        self.cfg
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    pub(crate) fn set_config(&self, cfg: ServeConfig) {
        *self.cfg.lock().unwrap_or_else(PoisonError::into_inner) = cfg;
    }

    pub(crate) fn counters(&self) -> DaemonCounters {
        *self.counters.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn with_counters(&self, f: impl FnOnce(&mut DaemonCounters)) {
        f(&mut self.counters.lock().unwrap_or_else(PoisonError::into_inner));
    }

    /// Render the merged-epoch Prometheus text plus the admission and
    /// daemon-level families.
    fn render_metrics(&self) -> anyhow::Result<String> {
        // Clone the client out of the lock so a slow scrape never
        // stalls the replay thread.
        let client = self
            .client
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .clone();
        let live = client.metrics()?;
        let prior = self.prior.lock().unwrap_or_else(PoisonError::into_inner);
        let merged = merge_epochs(&prior, live);
        let epochs = prior.len() + 1;
        drop(prior);
        let mut out = merged.to_prometheus();
        let s = self.admission.stats();
        for (name, help, v) in [
            (
                "akpc_admission_admitted_total",
                "Frames admitted into the reorder buffer",
                s.admitted,
            ),
            (
                "akpc_admission_rejected_late_total",
                "Frames rejected for regressing beyond the slack window",
                s.rejected_late,
            ),
            (
                "akpc_admission_rejected_malformed_total",
                "Frames rejected by validation or parsing",
                s.rejected_malformed,
            ),
            (
                "akpc_admission_forced_releases_total",
                "Reorder-buffer entries force-released at capacity",
                s.forced_releases,
            ),
            (
                "akpc_admission_truncated_chunks_total",
                "Binary chunks discarded whole for truncation mid-frame",
                s.truncated_chunks,
            ),
        ] {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        let c = self.counters();
        for (name, help, v) in [
            (
                "akpc_recoveries_total",
                "Shard fleets rebuilt after a lost shard",
                c.recoveries,
            ),
            (
                "akpc_degraded_shed_total",
                "Requests shed to NoPacking pass-through under overload",
                c.shed_requests,
            ),
            (
                "akpc_degraded_shed_items_total",
                "Items inside shed requests",
                c.shed_items,
            ),
            (
                "akpc_checkpoints_written_total",
                "Checkpoints written successfully",
                c.checkpoints_written,
            ),
            (
                "akpc_checkpoint_failures_total",
                "Checkpoint attempts that failed",
                c.checkpoint_failures,
            ),
        ] {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        out.push_str(&format!(
            "# HELP akpc_recharge_cost_total Transfer cost charged to re-fetch copies lost with dead shards\n\
             # TYPE akpc_recharge_cost_total counter\nakpc_recharge_cost_total {}\n",
            c.recharge_cost
        ));
        out.push_str(&format!(
            "# HELP akpc_degraded_shed_cost_total Cost charged for shed pass-through traffic\n\
             # TYPE akpc_degraded_shed_cost_total counter\nakpc_degraded_shed_cost_total {}\n",
            c.shed_cost
        ));
        out.push_str(&format!(
            "# HELP akpc_serve_epochs Coordinator epochs (1 + completed hot-reload swaps)\n\
             # TYPE akpc_serve_epochs gauge\nakpc_serve_epochs {epochs}\n"
        ));
        Ok(out)
    }
}

fn to_serve_req(r: &Request) -> ServeRequest {
    ServeRequest {
        items: r.items.clone(),
        server: r.server,
        time: Some(r.time),
    }
}

/// Shed one admitted chunk under overload (DESIGN.md §14.4): every item
/// is charged NoPacking pass-through (`transfer_packed(1)` each — the
/// cache and packer are bypassed entirely), and the chunk never reaches
/// the coordinator. Drain accounting treats shed requests as handled:
/// `admitted == served + shed_requests`.
fn shed_chunk(state: &DaemonState, cfg: &ServeConfig, buf: &[Request]) {
    let model = CostModel::from_config(&cfg.akpc);
    let mut items = 0u64;
    for r in buf {
        items += r.items.len() as u64;
    }
    let cost = items as f64 * model.transfer_packed(1);
    state.with_counters(|c| {
        c.shed_requests += buf.len() as u64;
        c.shed_items += items;
        c.shed_cost += cost;
    });
}

/// Capture per-shard `(stats, live copies)` shadows from the live
/// coordinator. Called at chunk boundaries by the replay thread (which
/// already holds the client mutex, so no serve is in flight — the
/// boundary shadow is exact).
fn capture_shadows(
    state: &DaemonState,
    n_shards: usize,
) -> anyhow::Result<Vec<(ShardStats, Vec<CopyRecord>)>> {
    let slot = state
        .coordinator
        .lock()
        .unwrap_or_else(PoisonError::into_inner);
    let coord = slot
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("coordinator is shut down"))?;
    let m = coord.metrics()?;
    let mut out = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let stats = m
            .per_shard
            .iter()
            .find(|p| p.shard == s)
            .cloned()
            .unwrap_or_else(|| ShardStats {
                shard: s,
                ..ShardStats::default()
            });
        out.push((stats, coord.export_shard_copies(s)?));
    }
    Ok(out)
}

/// Rebuild the fleet after losing `lost` (DESIGN.md §14.3): retire the
/// current coordinator epoch through `Coordinator::recover` (which
/// charges re-transfer for the copies that died with the shard), swap
/// the replay thread's client in place, and record the recharge.
fn recover_in_place(
    state: &DaemonState,
    client: &mut CoordinatorClient,
    lost: usize,
    shadow: (ShardStats, Vec<CopyRecord>),
) -> anyhow::Result<()> {
    let mut slot = state
        .coordinator
        .lock()
        .unwrap_or_else(PoisonError::into_inner);
    let coord = slot
        .take()
        .ok_or_else(|| anyhow::anyhow!("coordinator is shut down"))?;
    let (stats, copies) = shadow;
    let (next, retired, recharge) = coord.recover(lost, copies, stats)?;
    *client = next.client();
    *slot = Some(next);
    drop(slot);
    state
        .prior
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .push(retired.into_handoff_epoch());
    state.with_counters(|c| {
        c.recoveries += 1;
        c.recharge_cost += recharge;
    });
    eprintln!("akpc-serve: recovered shard {lost} (recharge {recharge:.3})");
    Ok(())
}

/// Serve one admitted chunk, recovering in place if a shard is lost
/// mid-chunk. Shadows are captured at the chunk boundary; when shard
/// `s` dies, every request this chunk routed to `s` since the boundary
/// is replayed onto the rebuilt fleet (their effects died with the
/// shard), then the failed request itself is retried. Unlike the
/// offline supervisor (`fault::supervisor`), the replays here can
/// re-enter the window batcher, so the live path is *accounted* but
/// not pinned exact — `admitted == served + shed` still holds.
fn serve_chunk(state: &DaemonState, n_shards: usize, buf: &[Request]) -> anyhow::Result<()> {
    let mut client = state.client.lock().unwrap_or_else(PoisonError::into_inner);
    let mut shadows = capture_shadows(state, n_shards)?;
    let mut since_shadow: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
    let mut i = 0usize;
    while i < buf.len() {
        let r = &buf[i];
        let route = client.placement().shard_of(r.server);
        match client.serve(to_serve_req(r)) {
            Ok(_) => {
                since_shadow[route].push(i);
                i += 1;
            }
            Err(e) => {
                let lost = e
                    .downcast_ref::<ShardLost>()
                    .and_then(|l| l.shard)
                    .or_else(|| {
                        state
                            .coordinator
                            .lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .as_ref()
                            .and_then(Coordinator::lost_shard)
                    });
                let Some(lost) = lost else {
                    return Err(e);
                };
                anyhow::ensure!(lost < n_shards, "lost unknown shard {lost}");
                recover_in_place(state, &mut client, lost, shadows[lost].clone())?;
                let replay: Vec<usize> = std::mem::take(&mut since_shadow[lost]);
                for j in replay {
                    client.serve(to_serve_req(&buf[j]))?;
                }
                shadows = capture_shadows(state, n_shards)?;
                for v in &mut since_shadow {
                    v.clear();
                }
                // `i` is not advanced: the failed request is retried
                // against the rebuilt fleet on the next iteration.
            }
        }
    }
    Ok(())
}

/// Write one checkpoint (DESIGN.md §14.5). Lock order matches reload
/// and drain: client first (parks the replay thread at a chunk
/// boundary, so no serve is in flight), then the coordinator slot. The
/// persisted watermark is the coordinator clock — the largest *served*
/// time — so admitted-but-unserved frames stay above the restore floor
/// and a resending client replays exactly them.
fn checkpoint_now(state: &DaemonState, dir: &Path) {
    let result = (|| -> anyhow::Result<()> {
        let _client = state.client.lock().unwrap_or_else(PoisonError::into_inner);
        let slot = state
            .coordinator
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let coord = slot
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("coordinator is shut down"))?;
        let hs = coord.checkpoint_state()?;
        let live = coord.metrics()?;
        let merged = {
            let prior = state.prior.lock().unwrap_or_else(PoisonError::into_inner);
            merge_epochs(&prior, live).into_handoff_epoch()
        };
        let ck = Checkpoint {
            watermark: hs.clock(),
            state: hs,
            prior: Some(merged),
        };
        crate::fault::write_to_dir(dir, &ck)
    })();
    match result {
        Ok(()) => state.with_counters(|c| c.checkpoints_written += 1),
        Err(e) => {
            state.with_counters(|c| c.checkpoint_failures += 1);
            eprintln!("akpc-serve: checkpoint failed: {e:#}");
        }
    }
}

/// Listener/endpoint addresses and the optional reloadable config file.
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    /// Ingest listen address, e.g. `127.0.0.1:4780` (`:0` = ephemeral).
    pub listen: String,
    /// Status-endpoint listen address; `None` disables HTTP.
    pub http: Option<String>,
    /// TOML config path re-read on `POST /reload` / `reload()`.
    pub config_path: Option<String>,
    /// Checkpoint directory (DESIGN.md §14.5). When set, the daemon
    /// restores from the slot file if one exists, snapshots
    /// periodically, and writes a final checkpoint during drain.
    pub checkpoint_dir: Option<String>,
    /// Seconds between periodic checkpoints; `<= 0` means the default
    /// (5s). Ignored without `checkpoint_dir`.
    pub checkpoint_secs: f64,
    /// Per-reply stall timeout for coordinator rendezvous, in ms
    /// (`0` = wait forever). Setting it lets the daemon convert a
    /// wedged shard into a typed `ShardLost` and recover; it is
    /// process-global (see `coordinator::set_reply_timeout_ms`).
    pub reply_timeout_ms: u64,
}

/// What a drained daemon hands back.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Final metrics, merged across all coordinator epochs.
    pub metrics: MetricsSnapshot,
    /// Coordinator epochs run (1 + hot-reload restarts).
    pub epochs: usize,
    /// Final admission counters.
    pub admission: AdmissionStats,
    /// Wall-clock seconds from start to drain completion.
    pub wall_secs: f64,
    /// Served requests per wall-clock second.
    pub requests_per_sec: f64,
    /// Robustness counters: recoveries, shedding, checkpoints.
    pub counters: DaemonCounters,
}

/// A running `akpc serve` daemon. Dropping it drains gracefully.
pub struct ServeDaemon {
    state: Arc<DaemonState>,
    ctl_tx: mpsc::SyncSender<ControlMsg>,
    ingest_addr: SocketAddr,
    http_addr: Option<SocketAddr>,
    control_join: Option<JoinHandle<anyhow::Result<ServeReport>>>,
    stop: Arc<AtomicBool>,
}

/// Bounded control-channel depth: drains, scrapes, and reloads are rare
/// and each sender blocks on its reply anyway.
const CONTROL_QUEUE_DEPTH: usize = 8;

impl ServeDaemon {
    /// Validate `cfg`, bind the listeners, start the coordinator and
    /// all daemon threads. Returns once the daemon is accepting.
    pub fn start(cfg: ServeConfig, opts: ServeOptions) -> anyhow::Result<Self> {
        cfg.validate(&PolicyRegistry::builtin())?;

        let ingest = TcpListener::bind(&opts.listen)
            .map_err(|e| anyhow::anyhow!("bind ingest {}: {e}", opts.listen))?;
        let ingest_addr = ingest.local_addr()?;
        let http_listener = match &opts.http {
            Some(addr) => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("bind http {addr}: {e}"))?;
                Some(l)
            }
            None => None,
        };
        let http_addr = match &http_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        };

        let meta = TraceMeta {
            n_items: cfg.akpc.n_items,
            n_servers: cfg.akpc.n_servers,
            est_len: None,
            name: "live-ingest".into(),
        };
        let (admission, source) = Admission::new(
            meta,
            cfg.slack,
            cfg.reorder_capacity,
            cfg.chunk,
            cfg.queue_depth,
        );
        admission.set_max_items(cfg.max_items);
        let admission = Arc::new(admission);
        if opts.reply_timeout_ms > 0 {
            crate::coordinator::set_reply_timeout_ms(opts.reply_timeout_ms);
        }

        // Crash-restart (DESIGN.md §14.5): if the checkpoint dir holds a
        // slot, resume the coordinator from it, seed the prior-epoch
        // list with the checkpointed metrics, and raise the admission
        // floor to the persisted served watermark so a client resending
        // from before the crash cannot double-serve anything.
        let ckpt_dir = opts.checkpoint_dir.as_ref().map(PathBuf::from);
        let mut restored_prior: Vec<MetricsSnapshot> = Vec::new();
        let slot = match &ckpt_dir {
            Some(dir) => crate::fault::read_from_dir(dir)?,
            None => None,
        };
        let coordinator = match slot {
            Some(ck) => {
                anyhow::ensure!(
                    ck.state.cfg == cfg.akpc,
                    "checkpoint in {} was written under a different [akpc] config; \
                     refusing to restore",
                    opts.checkpoint_dir.as_deref().unwrap_or("<none>"),
                );
                admission.resume_floor(ck.watermark);
                restored_prior.extend(ck.prior);
                Coordinator::resume(ck.state, cfg.shards)?
            }
            None => Coordinator::start_with(
                cfg.akpc.clone(),
                cfg.engine.to_engine(),
                cfg.shards,
                TickMode::Sync,
            )?,
        };
        let state = Arc::new(DaemonState {
            client: Mutex::new(coordinator.client()),
            coordinator: Mutex::new(Some(coordinator)),
            prior: Mutex::new(restored_prior),
            admission: Arc::clone(&admission),
            cfg: Mutex::new(cfg),
            counters: Mutex::new(DaemonCounters::default()),
            config_path: opts.config_path.clone(),
        });

        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(ConnRegistry::default());
        let accept_join = super::listener::spawn_ingest(
            ingest,
            Arc::clone(&admission),
            Arc::clone(&conns),
            Arc::clone(&stop),
        )?;
        let (ctl_tx, ctl_rx) = mpsc::sync_channel(CONTROL_QUEUE_DEPTH);
        let http_join = match http_listener {
            Some(l) => Some(super::http::spawn_http(l, ctl_tx.clone(), Arc::clone(&stop))?),
            None => None,
        };

        sig::install_sigterm_hook();

        let replay_state = Arc::clone(&state);
        let replay_join = std::thread::Builder::new()
            .name("akpc-serve-replay".into())
            .spawn(move || -> anyhow::Result<()> {
                let mut source = source;
                let mut buf = Vec::new();
                while source.next_chunk(&mut buf)? {
                    let cfg = replay_state.config();
                    // Overload degradation (§14.4): when the bounded
                    // admission→replay queue is this deep, the packer is
                    // the bottleneck — shed the whole chunk at NoPacking
                    // pass-through cost instead of falling further
                    // behind.
                    if cfg.shed_depth > 0
                        && replay_state.admission.queue_depth() >= cfg.shed_depth
                    {
                        shed_chunk(&replay_state, &cfg, &buf);
                        buf.clear();
                        continue;
                    }
                    serve_chunk(&replay_state, cfg.shards, &buf)?;
                    buf.clear();
                }
                Ok(())
            })?;

        let ctl_state = Arc::clone(&state);
        let ctl_stop = Arc::clone(&stop);
        let ctl_ckpt_dir = ckpt_dir;
        let ckpt_period = if opts.checkpoint_secs > 0.0 {
            opts.checkpoint_secs
        } else {
            5.0
        };
        let started = Instant::now();
        let control_join = std::thread::Builder::new()
            .name("akpc-serve-control".into())
            .spawn(move || -> anyhow::Result<ServeReport> {
                // Built here, not passed in: the registry's boxed
                // factories are not Send.
                let registry = PolicyRegistry::builtin();
                let mut last_ckpt = Instant::now();
                loop {
                    if sig::take_sigterm() {
                        break;
                    }
                    if let Some(dir) = &ctl_ckpt_dir {
                        if last_ckpt.elapsed().as_secs_f64() >= ckpt_period {
                            checkpoint_now(&ctl_state, dir);
                            last_ckpt = Instant::now();
                        }
                    }
                    match ctl_rx.recv_timeout(Duration::from_millis(200)) {
                        Ok(ControlMsg::Drain) => break,
                        Ok(ControlMsg::Scrape(tx)) => {
                            let body = ctl_state
                                .render_metrics()
                                .unwrap_or_else(|e| format!("# scrape failed: {e}\n"));
                            let _ = tx.send(body);
                        }
                        Ok(ControlMsg::Reload(tx)) => {
                            let outcome = match &ctl_state.config_path {
                                None => Err("no --serve-config file to reload".to_string()),
                                Some(path) => apply_reload(&ctl_state, &registry, path)
                                    .map(|o| o.summary)
                                    .map_err(|e| format!("{e:#}")),
                            };
                            let _ = tx.send(outcome);
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        // Every control sender gone: drain rather than
                        // spin forever with no way to be told to stop.
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }

                // ---- drain sequence (see module docs for ordering) ----
                ctl_stop.store(true, Ordering::SeqCst);
                if let Err(p) = accept_join.join() {
                    std::panic::resume_unwind(p);
                }
                conns.shutdown_all();
                // Close the stream; an error here means replay already
                // stopped, which the join below will surface.
                let _ = ctl_state.admission.finish();
                let replay_result = match replay_join.join() {
                    Ok(r) => r,
                    Err(p) => std::panic::resume_unwind(p),
                };
                // Final checkpoint before shutdown: a daemon restarted
                // from it resumes with every served request on record.
                if let Some(dir) = &ctl_ckpt_dir {
                    checkpoint_now(&ctl_state, dir);
                }
                let last = {
                    let mut slot = ctl_state
                        .coordinator
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    match slot.take() {
                        Some(c) => c.shutdown(),
                        None => anyhow::bail!("coordinator already shut down"),
                    }
                };
                // Shutdown was clean either way; only now surface a
                // replay failure so the ledger above stays exact.
                replay_result?;
                let prior = {
                    let mut g = ctl_state
                        .prior
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    std::mem::take(&mut *g)
                };
                let epochs = prior.len() + 1;
                let metrics = merge_epochs(&prior, last);
                if let Some(h) = http_join {
                    if let Err(p) = h.join() {
                        std::panic::resume_unwind(p);
                    }
                }
                let wall_secs = started.elapsed().as_secs_f64();
                let served = metrics.served;
                Ok(ServeReport {
                    metrics,
                    epochs,
                    admission: ctl_state.admission.stats(),
                    wall_secs,
                    requests_per_sec: if wall_secs > 0.0 {
                        served as f64 / wall_secs
                    } else {
                        0.0
                    },
                    counters: ctl_state.counters(),
                })
            })?;

        Ok(Self {
            state,
            ctl_tx,
            ingest_addr,
            http_addr,
            control_join: Some(control_join),
            stop,
        })
    }

    /// The bound ingest address (resolved, so `:0` shows the real port).
    pub fn ingest_addr(&self) -> SocketAddr {
        self.ingest_addr
    }

    /// The bound status-endpoint address, if HTTP was enabled.
    pub fn http_addr(&self) -> Option<SocketAddr> {
        self.http_addr
    }

    /// Live admission counters.
    pub fn admission_stats(&self) -> AdmissionStats {
        self.state.admission.stats()
    }

    /// Scrape the live Prometheus text in-process (what `GET /metrics`
    /// returns over HTTP).
    pub fn metrics_text(&self) -> anyhow::Result<String> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.ctl_tx
            .send(ControlMsg::Scrape(tx))
            .map_err(|_| anyhow::anyhow!("daemon control loop is gone"))?;
        rx.recv_timeout(Duration::from_secs(5))
            .map_err(|_| anyhow::anyhow!("scrape timed out"))
    }

    /// Re-read the config file (same path `POST /reload` takes).
    pub fn reload(&self) -> anyhow::Result<String> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.ctl_tx
            .send(ControlMsg::Reload(tx))
            .map_err(|_| anyhow::anyhow!("daemon control loop is gone"))?;
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Ok(summary)) => Ok(summary),
            Ok(Err(e)) => anyhow::bail!("reload rejected: {e}"),
            Err(_) => anyhow::bail!("reload timed out"),
        }
    }

    /// Gracefully drain: stop accepting, flush admission, serve every
    /// admitted request, quiesce the coordinator, return the exact
    /// final report.
    pub fn drain(mut self) -> anyhow::Result<ServeReport> {
        let _ = self.ctl_tx.send(ControlMsg::Drain);
        self.join_inner()
    }

    /// Wait for the daemon to drain on its own (SIGTERM or
    /// `POST /drain`).
    pub fn join(mut self) -> anyhow::Result<ServeReport> {
        self.join_inner()
    }

    fn join_inner(&mut self) -> anyhow::Result<ServeReport> {
        let Some(handle) = self.control_join.take() else {
            anyhow::bail!("daemon already joined");
        };
        match handle.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        if self.control_join.is_some() {
            let _ = self.ctl_tx.send(ControlMsg::Drain);
            self.stop.store(true, Ordering::SeqCst);
            let _ = self.join_inner();
        }
    }
}

/// SIGTERM → drain, without a signal-handling dependency: the handler
/// only flips an atomic the control loop polls (async-signal-safe).
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SIGTERM_PENDING: AtomicBool = AtomicBool::new(false);
    static HOOK_INSTALLED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigterm(_signum: i32) {
        SIGTERM_PENDING.store(true, Ordering::SeqCst);
    }

    pub(super) fn install_sigterm_hook() {
        if HOOK_INSTALLED.swap(true, Ordering::SeqCst) {
            return;
        }
        const SIGTERM: i32 = 15;
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let handler = on_sigterm as extern "C" fn(i32) as usize;
        // akpc-lint has no rule against unsafe; this is the only unsafe
        // block in the crate and it wraps one libc call.
        unsafe {
            signal(SIGTERM, handler);
        }
    }

    pub(super) fn take_sigterm() -> bool {
        SIGTERM_PENDING.swap(false, Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub(super) fn install_sigterm_hook() {}

    pub(super) fn take_sigterm() -> bool {
        false
    }
}
