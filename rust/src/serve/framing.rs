//! Ingest wire formats (DESIGN.md §12.1).
//!
//! A connection speaks one of two formats, sniffed from its first four
//! bytes:
//!
//! * **Text frames** — newline-delimited `ts server item [item...]`
//!   (whitespace-separated; `ts` is the logical request time as an `f64`,
//!   `server` the requesting user's edge server id, then 1..=max_items
//!   item ids). Blank lines and `#` comments are skipped, so a trace
//!   exported as CSV-ish text can be piped in with minimal massaging.
//! * **Binary frames** — the leading bytes `AKPT` select the binary
//!   trace format of [`crate::trace::io`], header included: the v2
//!   chunk-framed layout streamed by
//!   [`BinaryStreamSource`](crate::trace::stream::BinaryStreamSource)
//!   (the flat v1 layout is accepted too). `akpc ingest --binary` can
//!   therefore pipe a `.akpt` file's bytes straight into the socket.
//!
//! Either way, every record lands in [`Admission::offer`] where the
//! universe bounds and the timestamp-slack contract are enforced; a
//! malformed *text* line only bumps the `rejected_malformed` counter
//! (live peers keep streaming), while a corrupt *binary* stream kills
//! its connection — once length-delimited framing is lost there is no
//! way to resynchronize.

use std::io::{BufRead, Write};

use crate::trace::io as trace_io;
use crate::trace::model::Request;
use crate::trace::stream::TraceMeta;

use super::admission::Admission;

/// Text-mode ack cadence: one `ack <submitted> <watermark>` line per
/// this many submitted frames (plus a final one at EOF), so a retrying
/// client can log progress without the daemon flooding the back channel.
pub(crate) const ACK_EVERY: u64 = 256;

/// The binary-format sniff bytes (the `AKPT` trace-file magic).
pub(crate) const MAGIC: &[u8] = b"AKPT";

/// Parse one text frame: `ts server item [item...]`.
///
/// Pure syntax — universe bounds and item-count limits are admission
/// concerns ([`validate_frame`]), so binary records (which skip this
/// parser) face the same checks. `Request::new` sorts and deduplicates
/// the item set, exactly like every other ingest path.
pub fn parse_text_frame(line: &str) -> anyhow::Result<Request> {
    let mut parts = line.split_whitespace();
    let ts = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty frame"))?
        .parse::<f64>()
        .map_err(|e| anyhow::anyhow!("bad timestamp: {e}"))?;
    anyhow::ensure!(ts.is_finite(), "timestamp must be finite, got {ts}");
    let server = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("frame needs `ts server item [item...]`"))?
        .parse::<u32>()
        .map_err(|e| anyhow::anyhow!("bad server id: {e}"))?;
    let mut items = Vec::new();
    for p in parts {
        items.push(
            p.parse::<u32>()
                .map_err(|e| anyhow::anyhow!("bad item id `{p}`: {e}"))?,
        );
    }
    anyhow::ensure!(!items.is_empty(), "frame has no items");
    Ok(Request::new(items, server, ts))
}

/// The per-record admission checks shared by both wire formats: finite
/// time, universe bounds from `meta`, and the `max_items` request-size
/// cap (a d_max-style guard so one hostile frame cannot allocate an
/// unbounded item set downstream).
pub(crate) fn validate_frame(
    req: &Request,
    meta: &TraceMeta,
    max_items: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(req.time.is_finite(), "non-finite timestamp");
    anyhow::ensure!(!req.items.is_empty(), "empty item set");
    anyhow::ensure!(
        req.items.len() <= max_items,
        "{} items exceeds max_items={max_items}",
        req.items.len()
    );
    anyhow::ensure!(
        req.server < meta.n_servers,
        "server {} out of range (n_servers={})",
        req.server,
        meta.n_servers
    );
    if meta.n_items > 0 {
        if let Some(&last) = req.items.last() {
            // Items are sorted (Request::new), so the last is the max.
            anyhow::ensure!(
                last < meta.n_items,
                "item {last} out of range (n_items={})",
                meta.n_items
            );
        }
    }
    Ok(())
}

/// Write one back-channel line, best-effort: the first failed write
/// disables the channel (a client that hung up mid-ack is not an ingest
/// error — its frames already landed).
fn back_channel(ack: &mut Option<&mut dyn Write>, line: std::fmt::Arguments<'_>) {
    if let Some(w) = ack.as_deref_mut() {
        if w.write_fmt(format_args!("{line}\n")).is_err() || w.flush().is_err() {
            *ack = None;
        }
    }
}

/// Pump a text-mode connection into admission until EOF. Returns the
/// number of frames submitted (admitted or rejected); errors only on
/// I/O failure, a stopped daemon (admission channel closed), or an
/// injected `ingest-frame` connection drop.
///
/// Two control lines ride the same framing:
///
/// * `resume` — the client asks where to restart; the daemon answers
///   `resume <watermark>` on the back channel (`-inf` before any
///   admit). A reconnecting client skips every frame at or below the
///   reply — combined with the admission floor this is exactly-once
///   across connection drops *and* checkpoint restarts.
/// * periodic `ack <submitted> <watermark>` lines (every
///   [`ACK_EVERY`] frames, plus one at EOF) let the client track
///   durable progress.
pub(crate) fn pump_text(
    rdr: &mut impl BufRead,
    admission: &Admission,
    mut ack: Option<&mut dyn Write>,
) -> anyhow::Result<u64> {
    let mut submitted = 0u64;
    let mut line = String::new();
    loop {
        line.clear();
        if rdr.read_line(&mut line)? == 0 {
            back_channel(
                &mut ack,
                format_args!("ack {submitted} {}", admission.watermark()),
            );
            return Ok(submitted);
        }
        let text = line.trim();
        if text.is_empty() || text.starts_with('#') {
            continue;
        }
        if text == "resume" {
            back_channel(&mut ack, format_args!("resume {}", admission.watermark()));
            continue;
        }
        anyhow::ensure!(
            !crate::fault::should_fail("ingest-frame", None),
            "injected fault: ingest connection drop"
        );
        match parse_text_frame(text) {
            Ok(req) => {
                admission.offer(req)?;
                submitted += 1;
                if submitted % ACK_EVERY == 0 {
                    back_channel(
                        &mut ack,
                        format_args!("ack {submitted} {}", admission.watermark()),
                    );
                }
            }
            Err(_) => admission.note_malformed(),
        }
    }
}

/// Pump a binary-mode connection (full `AKPT` header + records, v1 or
/// v2 framing) into admission. Returns the number of records submitted;
/// errors on corrupt framing — the caller drops the connection.
///
/// v2 chunks are all-or-nothing: every record of a chunk is decoded
/// into a side buffer *before* any of them is offered, so a stream cut
/// off mid-chunk (EOF, injected drop) discards the partial batch whole
/// — counted in `truncated_chunks` — instead of delivering a truncated
/// prefix downstream.
pub(crate) fn pump_binary(rdr: &mut impl BufRead, admission: &Admission) -> anyhow::Result<u64> {
    let hdr = trace_io::read_binary_header(rdr)?;
    let mut submitted = 0u64;
    match hdr.version {
        trace_io::VERSION_FLAT => {
            // v1 records are individually framed; each complete record
            // is a complete frame, so EOF between records loses nothing.
            for _ in 0..hdr.n_reqs {
                anyhow::ensure!(
                    !crate::fault::should_fail("ingest-frame", None),
                    "injected fault: ingest connection drop"
                );
                admission.offer(trace_io::read_binary_record(rdr)?)?;
                submitted += 1;
            }
        }
        _ => {
            // v2: length-delimited frames, each its own record count.
            let mut remaining = hdr.n_reqs;
            let mut batch: Vec<Request> = Vec::new();
            while remaining > 0 {
                let n = u64::from(trace_io::read_frame_header(rdr)?);
                anyhow::ensure!(
                    n >= 1 && n <= remaining,
                    "corrupt chunk frame: {n} records framed, {remaining} remaining"
                );
                batch.clear();
                for _ in 0..n {
                    match trace_io::read_binary_record(rdr) {
                        Ok(r) => batch.push(r),
                        Err(e) => {
                            admission.note_truncated();
                            return Err(e.context(format!(
                                "binary chunk truncated mid-frame ({} of {n} records); \
                                 partial batch discarded",
                                batch.len()
                            )));
                        }
                    }
                }
                for r in batch.drain(..) {
                    anyhow::ensure!(
                        !crate::fault::should_fail("ingest-frame", None),
                        "injected fault: ingest connection drop"
                    );
                    admission.offer(r)?;
                }
                remaining -= n;
                submitted += n;
            }
        }
    }
    Ok(submitted)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TraceMeta {
        TraceMeta {
            n_items: 10,
            n_servers: 4,
            est_len: None,
            name: "t".into(),
        }
    }

    #[test]
    fn parses_well_formed_frames() {
        let r = parse_text_frame("1.5 2 7 3 7").unwrap();
        assert_eq!(r.time, 1.5);
        assert_eq!(r.server, 2);
        assert_eq!(r.items, vec![3, 7], "sorted + deduped");
        // Arbitrary whitespace runs are fine.
        let r = parse_text_frame("  0.0\t1   9 ").unwrap();
        assert_eq!(r.items, vec![9]);
    }

    #[test]
    fn rejects_malformed_lines() {
        for bad in [
            "",
            "1.0",
            "1.0 2",
            "abc 0 1",
            "nan 0 1",
            "inf 0 1",
            "1.0 -2 1",
            "1.0 0 x",
            "1.0 0 1.5",
        ] {
            assert!(parse_text_frame(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn text_pump_acks_and_answers_resume() {
        let (adm, mut src) = Admission::new(meta(), 0.0, 16, 4, 16);
        let input = "resume\n1.0 0 1\n2.0 1 2\n";
        let mut back = Vec::new();
        let n = pump_text(
            &mut std::io::Cursor::new(input),
            &adm,
            Some(&mut back as &mut dyn Write),
        )
        .unwrap();
        assert_eq!(n, 2);
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 2);
        let back = String::from_utf8(back).unwrap();
        let lines: Vec<&str> = back.lines().collect();
        assert_eq!(lines[0], "resume -inf", "no admits before the handshake");
        assert_eq!(lines.last().unwrap(), &"ack 2 2", "final ack at EOF");
    }

    #[test]
    fn binary_truncation_discards_partial_chunk() {
        use crate::trace::model::Trace;
        use crate::util::tempdir::TempDir;
        let trace = Trace {
            requests: (0..8)
                .map(|i| Request::new(vec![i % 10], i % 4, f64::from(i)))
                .collect(),
            n_items: 10,
            n_servers: 4,
            name: "t".into(),
        };
        let dir = TempDir::new("akpc-frame-trunc").unwrap();
        let path = dir.path().join("t.akpt");
        trace_io::write_binary_chunked(&trace, &path, 4).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        // Intact stream: all 8 records land.
        let (adm, mut src) = Admission::new(meta(), 0.0, 64, 4, 16);
        assert_eq!(
            pump_binary(&mut std::io::Cursor::new(&bytes), &adm).unwrap(),
            8
        );
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 8);
        assert_eq!(adm.stats().truncated_chunks, 0);

        // Cut nine bytes off the tail: EOF lands mid-record inside the
        // second chunk. The whole partial chunk must be discarded —
        // exactly the first chunk's 4 records are delivered.
        let (adm, mut src) = Admission::new(meta(), 0.0, 64, 4, 16);
        let cut = &bytes[..bytes.len() - 9];
        let err = pump_binary(&mut std::io::Cursor::new(cut), &adm).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err:#}");
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 4, "no partial batch");
        assert_eq!(adm.stats().admitted, 4);
        assert_eq!(adm.stats().truncated_chunks, 1);
    }

    #[test]
    fn validate_enforces_universe_and_size() {
        let m = meta();
        validate_frame(&Request::new(vec![0, 9], 3, 1.0), &m, 8).unwrap();
        let oversize = Request::new((0..9).collect(), 0, 1.0);
        let err = validate_frame(&oversize, &m, 8).unwrap_err().to_string();
        assert!(err.contains("max_items"), "{err}");
        let bad_item = Request::new(vec![10], 0, 1.0);
        assert!(validate_frame(&bad_item, &m, 8).is_err());
        let bad_server = Request::new(vec![1], 4, 1.0);
        assert!(validate_frame(&bad_server, &m, 8).is_err());
    }
}
