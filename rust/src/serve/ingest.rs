//! The retrying ingest client (DESIGN.md §14.6): what `akpc ingest
//! --retries` runs, and the reference implementation of the text-mode
//! resume protocol ([`framing`](super::framing)).
//!
//! Exactly-once across connection drops *and* daemon restarts, with the
//! daemon as the single source of truth:
//!
//! 1. On every (re)connect the client sends `resume` and reads back
//!    `resume <watermark>` — the daemon's inclusive admitted watermark
//!    (`-inf` before the first admit; after a crash-restart it is the
//!    checkpoint's *served* watermark, see `Admission::resume_floor`).
//! 2. The client then streams only the frames with `time > watermark`.
//!    Trace times are nondecreasing, so everything at or below the
//!    watermark is already admitted (or already served, post-restart)
//!    and is skipped, not resent.
//! 3. Periodic `ack <submitted> <watermark>` lines flow back on the
//!    same socket; the client drains them after `shutdown(Write)` so a
//!    clean attempt ends with the daemon's final word on what landed.
//!
//! Any failure — connect refused, mid-stream reset, ack timeout —
//! retries the whole attempt after exponential backoff with
//! deterministic jitter. Retrying is always safe: step 1 re-asks the
//! daemon what it has, so nothing is duplicated and nothing is lost.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

use crate::trace::model::Request;
use crate::util::Rng;

/// Knobs for [`ingest_trace`].
#[derive(Debug, Clone)]
pub struct IngestOptions {
    /// Daemon ingest address, e.g. `127.0.0.1:4780`.
    pub addr: String,
    /// Reconnect attempts after the first failure (`0` = fail fast).
    pub retries: usize,
    /// Base backoff before the first retry, in ms; doubles per attempt,
    /// capped at [`MAX_BACKOFF_MS`].
    pub backoff_ms: u64,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
}

impl IngestOptions {
    /// Defaults: 5 retries, 100ms base backoff.
    pub fn new(addr: impl Into<String>) -> Self {
        Self {
            addr: addr.into(),
            retries: 5,
            backoff_ms: 100,
            seed: 0x1463_E571,
        }
    }
}

/// What a completed ingest hands back.
#[derive(Debug, Clone, Copy)]
pub struct IngestReport {
    /// Frames written to the socket across all attempts.
    pub sent: u64,
    /// Frames skipped because the daemon already held them (resume
    /// dedup); nonzero exactly when a retry or restart happened.
    pub skipped: u64,
    /// Connection attempts made (`1` = no retries needed).
    pub attempts: u64,
    /// The daemon's final acked watermark (`-inf` if it never admitted).
    pub watermark: f64,
}

/// Backoff ceiling: retries never sleep longer than this.
const MAX_BACKOFF_MS: u64 = 5_000;

/// Per-read socket timeout while waiting for the resume reply / acks; a
/// wedged daemon turns into a retryable error, not a hang.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// One connection attempt: handshake, stream, drain acks. Returns
/// `(sent, skipped, final watermark)` on a fully-acked run.
fn attempt(addr: &str, requests: &[Request]) -> anyhow::Result<(u64, u64, f64)> {
    let stream = TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut rdr = BufReader::new(stream.try_clone()?);
    let mut out = std::io::BufWriter::new(&stream);

    writeln!(out, "resume")?;
    out.flush()?;
    let mut line = String::new();
    anyhow::ensure!(rdr.read_line(&mut line)? > 0, "daemon closed before resume reply");
    let watermark = line
        .trim()
        .strip_prefix("resume ")
        .and_then(|w| w.parse::<f64>().ok())
        .ok_or_else(|| anyhow::anyhow!("bad resume reply: {line:?}"))?;

    let mut sent = 0u64;
    let mut skipped = 0u64;
    for r in requests {
        if r.time <= watermark {
            skipped += 1;
            continue;
        }
        // `{}` on f64 prints the shortest round-tripping decimal, so
        // the daemon parses back the identical timestamp.
        write!(out, "{} {}", r.time, r.server)?;
        for it in &r.items {
            write!(out, " {it}")?;
        }
        writeln!(out)?;
        sent += 1;
    }
    out.flush()?;
    drop(out);
    stream.shutdown(Shutdown::Write)?;

    // Drain acks to EOF; the last one is the daemon's final word.
    let mut final_wm = watermark;
    loop {
        line.clear();
        if rdr.read_line(&mut line)? == 0 {
            break;
        }
        let mut parts = line.split_whitespace();
        if parts.next() == Some("ack") {
            let _submitted = parts.next();
            if let Some(wm) = parts.next().and_then(|w| w.parse::<f64>().ok()) {
                final_wm = wm;
            }
        }
    }
    Ok((sent, skipped, final_wm))
}

/// Stream `requests` (time-sorted) into the daemon at `opts.addr`,
/// retrying with exponential backoff + deterministic jitter until the
/// stream is fully acked or the retry budget is spent.
pub fn ingest_trace(requests: &[Request], opts: &IngestOptions) -> anyhow::Result<IngestReport> {
    let mut rng = Rng::new(opts.seed);
    let mut report = IngestReport {
        sent: 0,
        skipped: 0,
        attempts: 0,
        watermark: f64::NEG_INFINITY,
    };
    let mut last_err = None;
    for try_no in 0..=opts.retries {
        report.attempts += 1;
        match attempt(&opts.addr, requests) {
            Ok((sent, skipped, wm)) => {
                report.sent += sent;
                report.skipped += skipped;
                report.watermark = wm;
                return Ok(report);
            }
            Err(e) => {
                if try_no < opts.retries {
                    let base = (opts.backoff_ms << try_no.min(16)).min(MAX_BACKOFF_MS);
                    let jitter = rng.next_u64() % (base / 2 + 1);
                    eprintln!(
                        "ingest: attempt {} failed ({e:#}); retrying in {}ms",
                        report.attempts,
                        base + jitter
                    );
                    std::thread::sleep(Duration::from_millis(base + jitter));
                }
                last_err = Some(e);
            }
        }
    }
    Err(last_err
        .unwrap_or_else(|| anyhow::anyhow!("ingest: no attempts made"))
        .context(format!("ingest to {} failed after {} attempts", opts.addr, report.attempts)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn req(time: f64, server: u32, item: u32) -> Request {
        Request::new(vec![item], server, time)
    }

    /// A tiny in-test daemon stand-in speaking the resume/ack protocol.
    fn fake_daemon(listener: TcpListener, watermark: f64) -> std::thread::JoinHandle<Vec<String>> {
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().expect("accept");
            let mut rdr = BufReader::new(stream.try_clone().expect("clone"));
            let mut wtr = stream;
            let mut lines = Vec::new();
            let mut submitted = 0u64;
            let mut max_t = watermark;
            let mut line = String::new();
            loop {
                line.clear();
                if rdr.read_line(&mut line).unwrap_or(0) == 0 {
                    break;
                }
                let t = line.trim().to_string();
                if t == "resume" {
                    writeln!(wtr, "resume {watermark}").expect("reply");
                } else if !t.is_empty() {
                    submitted += 1;
                    if let Some(first) = t.split_whitespace().next() {
                        if let Ok(v) = first.parse::<f64>() {
                            max_t = max_t.max(v);
                        }
                    }
                }
                lines.push(t);
            }
            writeln!(wtr, "ack {submitted} {max_t}").expect("final ack");
            lines
        })
    }

    #[test]
    fn resume_skips_frames_at_or_below_watermark() {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let daemon = fake_daemon(listener, 2.0);
        let requests = vec![req(1.0, 0, 1), req(2.0, 1, 2), req(3.0, 0, 3), req(4.0, 1, 4)];
        let mut opts = IngestOptions::new(addr);
        opts.retries = 0;
        let report = ingest_trace(&requests, &opts).expect("ingest");
        assert_eq!((report.sent, report.skipped, report.attempts), (2, 2, 1));
        assert_eq!(report.watermark, 4.0);
        let lines = daemon.join().expect("daemon");
        assert_eq!(lines[0], "resume");
        assert!(lines[1].starts_with("3 "), "first resent frame: {:?}", lines[1]);
    }

    #[test]
    fn retries_until_a_daemon_appears_then_gives_up_cleanly() {
        // Nothing listening: the bounded budget must be spent, not hung.
        let mut opts = IngestOptions::new("127.0.0.1:1"); // reserved port
        opts.retries = 2;
        opts.backoff_ms = 1;
        let err = ingest_trace(&[req(1.0, 0, 1)], &opts).expect_err("no daemon");
        assert!(format!("{err:#}").contains("after 3 attempts"), "{err:#}");
    }
}
