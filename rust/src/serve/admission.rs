//! The admission layer (DESIGN.md §12.2): validation plus a bounded
//! timestamp-reorder buffer between the socket handlers and the replay
//! thread.
//!
//! The downstream contract is strict — every
//! [`TraceSource`](crate::trace::stream::TraceSource) chunk must be
//! time-ordered within and across chunks — but live arrivals from many
//! connections interleave with bounded skew. Admission squares the two
//! with a *slack window*: a min-heap holds arrivals until the watermark
//! `w` (the largest admitted timestamp) has moved `slack` past them;
//! anything older than `w - slack` on arrival (or older than the floor
//! already released downstream) is deterministically rejected as late.
//! Releases therefore leave the heap in nondecreasing time order, which
//! is exactly what [`ChannelSource`](crate::trace::stream::ChannelSource)
//! re-validates on the consumer side.
//!
//! Boundedness (akpc-lint L4 spirit): the heap is capped at
//! `reorder_capacity` (overflow force-releases the oldest entries —
//! counted, never dropped), released requests ship in `chunk_len`
//! batches over the bounded channel behind `ChannelSource`, and a full
//! channel blocks the offering connection — backpressure, not buffering.
//!
//! Locking: one mutex serializes offers from all connections; the
//! channel send happens **under** it, because two racing offers must not
//! reorder their released chunks. A slow replay thread therefore stalls
//! ingest (and momentarily the stats scrape) — the intended behavior for
//! an ingest server at capacity.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};

use crate::trace::model::Request;
use crate::trace::stream::{ChannelSource, TraceMeta};

use super::framing::validate_frame;

/// What [`Admission::offer`] decided about one frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Accepted into the reorder buffer.
    Admitted,
    /// Timestamp regressed beyond the slack window (or behind the
    /// already-released floor).
    RejectedLate,
    /// Failed validation (universe bounds, size cap, non-finite time).
    RejectedMalformed,
}

/// Monotone counters exported at `/metrics` and in the final report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Frames accepted into the reorder buffer.
    pub admitted: u64,
    /// Frames rejected for regressing beyond the slack window.
    pub rejected_late: u64,
    /// Frames rejected by validation (parse errors included).
    pub rejected_malformed: u64,
    /// Entries released early because the reorder buffer hit capacity.
    pub forced_releases: u64,
    /// Binary v2 chunks whose framing promised more records than the
    /// stream delivered (EOF mid-chunk). The partial chunk is discarded
    /// whole — a truncated batch never reaches the replay thread.
    pub truncated_chunks: u64,
}

/// Min-heap entry ordered by `(time, seq)`. `total_cmp` keeps the order
/// total (L1: no partial_cmp-unwrap on floats); the admission sequence
/// number breaks ties so equal-time arrivals release in arrival order.
struct HeapEntry {
    seq: u64,
    req: Request,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.req
            .time
            .total_cmp(&other.req.time)
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for HeapEntry {}

struct Inner {
    slack: f64,
    chunk_len: usize,
    max_items: usize,
    capacity: usize,
    heap: BinaryHeap<Reverse<HeapEntry>>,
    /// Released, not yet shipped (always time-ordered).
    pending: Vec<Request>,
    /// Largest admitted timestamp.
    watermark: f64,
    /// Largest timestamp released downstream; arrivals below it would
    /// break the stream contract and are rejected as late.
    floor: f64,
    seq: u64,
    stats: AdmissionStats,
    /// `None` after [`Admission::finish`]: the stream is closed.
    tx: Option<mpsc::SyncSender<Vec<Request>>>,
    /// Chunks currently queued in the channel behind the replay thread
    /// (shared with [`ChannelSource`], which decrements per consumed
    /// chunk) — the overload signal degradation thresholds key on.
    depth: Arc<AtomicUsize>,
}

/// The shared admission front door. One instance per daemon, shared by
/// every connection handler; the paired [`ChannelSource`] is the replay
/// thread's [`TraceSource`](crate::trace::stream::TraceSource).
pub struct Admission {
    meta: TraceMeta,
    inner: Mutex<Inner>,
}

impl Admission {
    /// Build the admission layer and its paired consumer source.
    /// `queue_depth` chunks may be in flight before offers block.
    pub fn new(
        meta: TraceMeta,
        slack: f64,
        capacity: usize,
        chunk_len: usize,
        queue_depth: usize,
    ) -> (Self, ChannelSource) {
        let (tx, source) = ChannelSource::bounded(meta.clone(), queue_depth);
        let depth = source.depth_gauge();
        let admission = Self {
            meta,
            inner: Mutex::new(Inner {
                slack: slack.max(0.0),
                chunk_len: chunk_len.max(1),
                max_items: usize::MAX,
                capacity: capacity.max(1),
                heap: BinaryHeap::new(),
                pending: Vec::new(),
                watermark: f64::NEG_INFINITY,
                floor: f64::NEG_INFINITY,
                seq: 0,
                stats: AdmissionStats::default(),
                tx: Some(tx),
                depth,
            }),
        };
        (admission, source)
    }

    /// Cap the per-request item count (frames above it are malformed).
    pub fn set_max_items(&self, max_items: usize) {
        self.lock().max_items = max_items.max(1);
    }

    /// The universe the daemon validates frames against.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Offer one validated-or-not frame. `Ok(verdict)` for the normal
    /// admit/reject outcomes; `Err` only when the daemon is draining
    /// (stream closed) or the replay side is gone — the connection
    /// handler should hang up.
    pub fn offer(&self, req: Request) -> anyhow::Result<Verdict> {
        let mut g = self.lock();
        anyhow::ensure!(g.tx.is_some(), "admission closed (daemon draining)");
        if validate_frame(&req, &self.meta, g.max_items).is_err() {
            g.stats.rejected_malformed += 1;
            return Ok(Verdict::RejectedMalformed);
        }
        let t = req.time;
        if t < g.floor || t < g.watermark - g.slack {
            g.stats.rejected_late += 1;
            return Ok(Verdict::RejectedLate);
        }
        if t > g.watermark {
            g.watermark = t;
        }
        g.seq += 1;
        let seq = g.seq;
        g.heap.push(Reverse(HeapEntry { seq, req }));
        g.stats.admitted += 1;

        // Overflow: force-release the oldest entries. They pop in time
        // order, so the stream stays sorted — the cost is only that a
        // straggler older than them now counts as late.
        while g.heap.len() > g.capacity {
            if let Some(Reverse(e)) = g.heap.pop() {
                g.floor = g.floor.max(e.req.time);
                g.pending.push(e.req);
                g.stats.forced_releases += 1;
            }
        }
        Self::release_ready(&mut g);
        Self::ship(&mut g, false)?;
        Ok(Verdict::Admitted)
    }

    /// Count a frame that failed before reaching [`offer`](Self::offer)
    /// (text parse errors at the framing layer).
    pub fn note_malformed(&self) {
        self.lock().stats.rejected_malformed += 1;
    }

    /// Count a binary v2 chunk cut off by EOF mid-frame. The framing
    /// layer discards the partial chunk whole before calling this, so
    /// the counter is also the number of batches provably *not*
    /// delivered truncated.
    pub fn note_truncated(&self) {
        self.lock().stats.truncated_chunks += 1;
    }

    /// The largest admitted timestamp (`-inf` before the first admit).
    /// This is what the ingest `resume` handshake reports: a
    /// reconnecting client may safely skip every frame at or below it —
    /// each such frame is in the reorder buffer or beyond, never lost.
    pub fn watermark(&self) -> f64 {
        self.lock().watermark
    }

    /// Chunks queued between admission and the replay thread right now.
    pub fn queue_depth(&self) -> usize {
        self.lock().depth.load(Ordering::Relaxed)
    }

    /// Restore the admission floor from a checkpoint: every arrival at
    /// or below `watermark` (the checkpointed coordinator clock) is
    /// rejected as a duplicate (`rejected_late`). Called once, before
    /// the daemon starts accepting, so a client resending from its last
    /// ack can never double-serve a request the restored state already
    /// contains.
    pub fn resume_floor(&self, watermark: f64) {
        if !watermark.is_finite() {
            return;
        }
        // Floor semantics are strict (`t < floor` rejects); bump one ulp
        // so `t == watermark` is rejected too.
        let exclusive = if watermark >= 0.0 {
            f64::from_bits(watermark.to_bits() + 1)
        } else {
            f64::from_bits(watermark.to_bits() - 1)
        };
        let mut g = self.lock();
        g.floor = g.floor.max(exclusive);
        g.watermark = g.watermark.max(watermark);
    }

    /// Snapshot of the admission counters.
    pub fn stats(&self) -> AdmissionStats {
        self.lock().stats
    }

    /// Entries currently held in the reorder buffer (tests, status).
    pub fn buffered(&self) -> usize {
        let g = self.lock();
        g.heap.len() + g.pending.len()
    }

    /// Update the slack window (hot-reload). Shrinking it releases the
    /// newly eligible entries immediately.
    pub fn set_slack(&self, slack: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            slack.is_finite() && slack >= 0.0,
            "admission slack must be finite and >= 0, got {slack}"
        );
        let mut g = self.lock();
        g.slack = slack;
        Self::release_ready(&mut g);
        Self::ship(&mut g, false)
    }

    /// Update the shipping chunk length (hot-reload).
    pub fn set_chunk_len(&self, chunk_len: usize) {
        self.lock().chunk_len = chunk_len.max(1);
    }

    /// Release everything buffered and ship it, keeping the stream open
    /// (idle flush).
    pub fn flush(&self) -> anyhow::Result<()> {
        let mut g = self.lock();
        Self::drain_heap(&mut g);
        Self::ship(&mut g, true)
    }

    /// Final flush + close: ships every buffered request and drops the
    /// sender so the paired [`ChannelSource`] ends its stream. Offers
    /// after this fail. Idempotent.
    pub fn finish(&self) -> anyhow::Result<()> {
        let mut g = self.lock();
        Self::drain_heap(&mut g);
        let res = Self::ship(&mut g, true);
        g.tx = None;
        res
    }

    /// Pop every heap entry whose release the watermark justifies.
    fn release_ready(g: &mut Inner) {
        let cutoff = g.watermark - g.slack;
        while let Some(Reverse(e)) = g.heap.peek() {
            if e.req.time > cutoff {
                break;
            }
            if let Some(Reverse(e)) = g.heap.pop() {
                g.floor = g.floor.max(e.req.time);
                g.pending.push(e.req);
            }
        }
    }

    /// Pop everything regardless of slack (drain path).
    fn drain_heap(g: &mut Inner) {
        while let Some(Reverse(e)) = g.heap.pop() {
            g.floor = g.floor.max(e.req.time);
            g.pending.push(e.req);
        }
    }

    /// Ship pending requests downstream in `chunk_len` batches; with
    /// `all`, ship the trailing partial batch too.
    fn ship(g: &mut Inner, all: bool) -> anyhow::Result<()> {
        while g.pending.len() >= g.chunk_len || (all && !g.pending.is_empty()) {
            let take = g.chunk_len.min(g.pending.len());
            let rest = g.pending.split_off(take);
            let chunk = std::mem::replace(&mut g.pending, rest);
            let Some(tx) = &g.tx else {
                anyhow::bail!("admission closed (daemon draining)");
            };
            tx.send(chunk)
                .map_err(|_| anyhow::anyhow!("live replay stopped; closing ingest"))?;
            // Gauge after a successful send; the consumer decrements.
            g.depth.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stream::TraceSource;

    fn meta() -> TraceMeta {
        TraceMeta {
            n_items: 100,
            n_servers: 8,
            est_len: None,
            name: "live".into(),
        }
    }

    fn req(t: f64, server: u32, item: u32) -> Request {
        Request::new(vec![item], server, t)
    }

    #[test]
    fn in_slack_reorder_is_repaired() {
        let (adm, mut src) = Admission::new(meta(), 1.0, 1024, 4, 16);
        // 0.9 arrives after 1.0 but within slack 1.0 — admitted and
        // re-sorted ahead of 1.0 on release.
        for (t, it) in [(1.0, 1), (0.9, 2), (2.5, 3), (2.6, 4)] {
            assert_eq!(adm.offer(req(t, 0, it)).unwrap(), Verdict::Admitted);
        }
        adm.finish().unwrap();
        let out = src.collect().unwrap();
        let times: Vec<f64> = out.requests.iter().map(|r| r.time).collect();
        assert_eq!(times, vec![0.9, 1.0, 2.5, 2.6]);
        assert_eq!(adm.stats().admitted, 4);
        assert_eq!(adm.stats().rejected_late, 0);
    }

    #[test]
    fn regression_beyond_slack_rejected() {
        let (adm, mut src) = Admission::new(meta(), 0.5, 1024, 4, 16);
        assert_eq!(adm.offer(req(5.0, 0, 1)).unwrap(), Verdict::Admitted);
        // 4.2 < 5.0 - 0.5: deterministic rejection.
        assert_eq!(adm.offer(req(4.2, 0, 2)).unwrap(), Verdict::RejectedLate);
        // 4.6 is within slack.
        assert_eq!(adm.offer(req(4.6, 0, 3)).unwrap(), Verdict::Admitted);
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 2);
        let s = adm.stats();
        assert_eq!((s.admitted, s.rejected_late), (2, 1));
    }

    #[test]
    fn malformed_frames_counted_not_shipped() {
        let (adm, mut src) = Admission::new(meta(), 1.0, 1024, 4, 16);
        adm.set_max_items(3);
        assert_eq!(
            adm.offer(req(0.0, 99, 1)).unwrap(), // server out of range
            Verdict::RejectedMalformed
        );
        assert_eq!(
            adm.offer(Request::new((0..5).collect(), 0, 0.0)).unwrap(),
            Verdict::RejectedMalformed
        );
        adm.note_malformed();
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 0);
        assert_eq!(adm.stats().rejected_malformed, 3);
    }

    #[test]
    fn capacity_overflow_force_releases_in_order() {
        let (adm, mut src) = Admission::new(meta(), 1e9, 4, 2, 16);
        // Slack is huge, so nothing releases voluntarily; capacity 4
        // forces the oldest out once a fifth arrives.
        for i in 0..6u32 {
            adm.offer(req(f64::from(i), 0, i)).unwrap();
        }
        assert!(adm.stats().forced_releases >= 2);
        adm.finish().unwrap();
        let out = src.collect().unwrap();
        assert_eq!(out.len(), 6, "forced releases are not drops");
        assert!(out.requests.windows(2).all(|w| w[0].time <= w[1].time));
    }

    #[test]
    fn shrinking_slack_releases_immediately() {
        let (adm, mut src) = Admission::new(meta(), 100.0, 1024, 1, 16);
        adm.offer(req(1.0, 0, 1)).unwrap();
        adm.offer(req(5.0, 0, 2)).unwrap();
        assert_eq!(adm.buffered(), 2);
        adm.set_slack(1.0).unwrap();
        assert_eq!(adm.buffered(), 1, "1.0 <= 5.0 - 1.0 released");
        assert!(adm.set_slack(-1.0).is_err());
        assert!(adm.set_slack(f64::NAN).is_err());
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 2);
    }

    #[test]
    fn resume_floor_rejects_replayed_frames_exactly() {
        let (adm, mut src) = Admission::new(meta(), 0.5, 1024, 4, 16);
        adm.resume_floor(3.0);
        assert_eq!(adm.watermark(), 3.0);
        // At or below the checkpointed watermark: duplicate.
        assert_eq!(adm.offer(req(3.0, 0, 1)).unwrap(), Verdict::RejectedLate);
        assert_eq!(adm.offer(req(2.0, 0, 1)).unwrap(), Verdict::RejectedLate);
        // Strictly above: fresh work.
        assert_eq!(adm.offer(req(3.0001, 0, 1)).unwrap(), Verdict::Admitted);
        adm.finish().unwrap();
        assert_eq!(src.collect().unwrap().len(), 1);
        assert_eq!(adm.stats().rejected_late, 2);
    }

    #[test]
    fn truncation_and_depth_counters() {
        let (adm, mut src) = Admission::new(meta(), 0.0, 1024, 1, 16);
        assert_eq!(adm.queue_depth(), 0);
        adm.note_truncated();
        assert_eq!(adm.stats().truncated_chunks, 1);
        adm.offer(req(1.0, 0, 1)).unwrap();
        adm.offer(req(2.0, 0, 2)).unwrap();
        // chunk_len 1, slack 0: both released and queued, none consumed.
        assert_eq!(adm.queue_depth(), 2);
        let mut buf = Vec::new();
        assert!(src.next_chunk(&mut buf).unwrap());
        assert_eq!(adm.queue_depth(), 1);
        adm.finish().unwrap();
    }

    #[test]
    fn offers_after_finish_fail() {
        let (adm, src) = Admission::new(meta(), 1.0, 1024, 4, 16);
        adm.finish().unwrap();
        let err = adm.offer(req(0.0, 0, 1)).unwrap_err().to_string();
        assert!(err.contains("draining"), "{err}");
        drop(src);
        // Idempotent.
        adm.finish().unwrap();
    }
}
