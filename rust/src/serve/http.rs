//! The plain-text status endpoint (DESIGN.md §12.4): a deliberately
//! tiny HTTP/1.0 server — request line + headers in, fixed response
//! out, `Connection: close` always — because the daemon's operational
//! surface is four routes and none of them justify a dependency:
//!
//! | route | effect |
//! |---|---|
//! | `GET /healthz` | `200 ok` while the daemon is up |
//! | `GET /metrics` | Prometheus text rendered from a live scrape |
//! | `POST /drain` | `202` and the drain sequence starts |
//! | `POST /reload` | re-read config; `200` applied / `409` rejected |
//!
//! The endpoint thread never touches daemon state directly: every
//! effectful route is a [`ControlMsg`] over the bounded control channel
//! with a rendezvous reply channel, so HTTP stays responsive (returning
//! 503 on timeout) even while the control loop is mid-reload.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::daemon::ControlMsg;

/// Largest request head (request line + headers) we accept.
const MAX_HEAD: usize = 8 * 1024;

fn respond(stream: &mut TcpStream, status: &str, content_type: &str, body: &str) {
    let msg = format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(msg.as_bytes());
    let _ = stream.flush();
}

/// Read until the blank line ending the head (we ignore bodies: the
/// control routes are argumentless POSTs).
fn read_head(stream: &mut TcpStream) -> Option<String> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while head.len() < MAX_HEAD {
        match stream.read(&mut byte) {
            Ok(0) => break,
            Ok(_) => {
                head.push(byte[0]);
                if head.ends_with(b"\r\n\r\n") || head.ends_with(b"\n\n") {
                    break;
                }
            }
            Err(_) => return None,
        }
    }
    String::from_utf8(head).ok()
}

fn handle_conn(mut stream: TcpStream, ctl: &mpsc::SyncSender<ControlMsg>) {
    let Some(head) = read_head(&mut stream) else {
        return;
    };
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    match (method, path) {
        ("GET", "/healthz") => respond(&mut stream, "200 OK", "text/plain", "ok\n"),
        ("GET", "/metrics") => {
            let (tx, rx) = mpsc::sync_channel(1);
            if ctl.send(ControlMsg::Scrape(tx)).is_ok() {
                match rx.recv_timeout(Duration::from_secs(2)) {
                    Ok(body) => {
                        respond(&mut stream, "200 OK", "text/plain; version=0.0.4", &body);
                    }
                    Err(_) => respond(
                        &mut stream,
                        "503 Service Unavailable",
                        "text/plain",
                        "scrape timed out\n",
                    ),
                }
            } else {
                respond(&mut stream, "503 Service Unavailable", "text/plain", "draining\n");
            }
        }
        ("POST", "/drain") => {
            let _ = ctl.send(ControlMsg::Drain);
            respond(&mut stream, "202 Accepted", "text/plain", "draining\n");
        }
        ("POST", "/reload") => {
            let (tx, rx) = mpsc::sync_channel(1);
            if ctl.send(ControlMsg::Reload(tx)).is_err() {
                respond(&mut stream, "503 Service Unavailable", "text/plain", "draining\n");
                return;
            }
            match rx.recv_timeout(Duration::from_secs(5)) {
                Ok(Ok(summary)) => {
                    respond(&mut stream, "200 OK", "text/plain", &format!("{summary}\n"));
                }
                Ok(Err(e)) => {
                    respond(&mut stream, "409 Conflict", "text/plain", &format!("{e}\n"));
                }
                Err(_) => respond(
                    &mut stream,
                    "503 Service Unavailable",
                    "text/plain",
                    "reload timed out\n",
                ),
            }
        }
        _ => respond(&mut stream, "404 Not Found", "text/plain", "not found\n"),
    }
}

/// Spawn the endpoint thread on an already-bound listener. Polls `stop`
/// between accepts so drain can retire it without a wakeup connection.
pub(crate) fn spawn_http(
    listener: TcpListener,
    ctl: mpsc::SyncSender<ControlMsg>,
    stop: Arc<AtomicBool>,
) -> anyhow::Result<JoinHandle<()>> {
    listener.set_nonblocking(true)?;
    let handle = std::thread::Builder::new()
        .name("akpc-serve-http".into())
        .spawn(move || loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(false);
                    handle_conn(stream, &ctl);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        })?;
    Ok(handle)
}
