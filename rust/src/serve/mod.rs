//! The live serving daemon (DESIGN.md §12): `akpc serve --listen` turns
//! the sharded coordinator into a real ingest server with admission,
//! live metrics, hot-reload, and graceful drain.
//!
//! Topology (one process, all threads bounded-channel actors):
//!
//! ```text
//!   TCP clients ──► acceptor ──► conn handlers (text / AKPT binary frames)
//!                                   │ Admission::offer
//!                                   ▼
//!                     admission reorder buffer (slack window)
//!                                   │ time-ordered chunks
//!                                   ▼
//!        ChannelSource ──► replay thread ──► CoordinatorClient::serve
//!                                                (PR-5 sharded stack)
//!   HTTP /metrics /healthz /drain /reload ──► control loop (drain,
//!                                             scrape, hot-reload)
//! ```
//!
//! Design contract: the daemon reuses the streaming replay stack
//! *unchanged* — live arrivals become the same time-ordered chunks a
//! [`TraceSource`](crate::trace::stream::TraceSource) produces, the
//! replay thread issues the exact per-request `serve` loop of
//! [`replay_sharded_stream`](crate::sim::replay_sharded_stream), and
//! drain goes through the coordinator's quiesce barrier. A trace
//! streamed through the socket into a drained daemon therefore lands on
//! the same total-cost ledger as the offline sharded replay of that
//! trace (pinned within 1e-9 in `tests/serve.rs`).
//!
//! Module map:
//!
//! | module | role |
//! |---|---|
//! | [`config`] | [`ServeConfig`]: TOML `[akpc]`-embedding daemon config |
//! | [`framing`] | wire formats: text lines + v2 `AKPT` binary frames |
//! | [`admission`] | validation + bounded timestamp-reorder buffer |
//! | [`listener`] | ingest acceptor + per-connection pump threads |
//! | [`http`] | plain-text HTTP/1.0 status endpoint |
//! | [`reload`] | hot-reload validation + coordinator epoch swap |
//! | [`daemon`] | [`ServeDaemon`]: lifecycle, control loop, drain |
//! | [`ingest`] | retrying client: resume handshake, backoff, acks |
//!
//! This module is inside the akpc-lint L3/L4 scope (DESIGN.md §11): no
//! panicking constructs outside tests, bounded `sync_channel`s only.

pub mod admission;
pub mod config;
pub mod daemon;
pub mod framing;
mod http;
pub mod ingest;
mod listener;
pub mod reload;

pub use admission::{Admission, AdmissionStats, Verdict};
pub use config::ServeConfig;
pub use daemon::{DaemonCounters, ServeDaemon, ServeOptions, ServeReport};
pub use framing::parse_text_frame;
pub use ingest::{ingest_trace, IngestOptions, IngestReport};
