//! Hot-reload (DESIGN.md §12.3): re-read the daemon's TOML config,
//! re-validate it through the same [`ServeConfig::validate`] →
//! `RunSpec::validate()` path used at startup, and only then apply it.
//! An invalid file is rejected with the validation error and the
//! running daemon keeps its current config — reload can never take the
//! service down.
//!
//! Two application tiers:
//!
//! * **Live knobs** (`slack`, `chunk`, `max_items`) apply in place via
//!   the admission layer's setters — no interruption at all.
//! * **Coordinator knobs** (`policy`, `engine`, `shards`, anything in
//!   `[akpc]`) need a new shard topology, so the old coordinator is
//!   drained through its quiesce path and a fresh one is started — an
//!   *epoch swap*. The swap happens while holding the replay thread's
//!   client mutex, i.e. at a chunk boundary: no in-flight request ever
//!   sees a half-torn-down coordinator. The retired epoch's final
//!   snapshot is kept and folded into every later scrape and the final
//!   report by [`merge_epochs`], so counters stay monotone across
//!   reloads (a Prometheus contract).
//!
//! `reorder_capacity` and `queue_depth` size buffers threaded through
//! channel construction; changing them takes a restart of the daemon,
//! not just an epoch swap, and reload reports them as ignored.

use std::sync::PoisonError;

use crate::coordinator::{Coordinator, MetricsSnapshot, TickMode};
use crate::run::PolicyRegistry;

use super::config::ServeConfig;
use super::daemon::DaemonState;

/// What a successful reload did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReloadOutcome {
    /// Human-readable summary (returned on the `POST /reload` body).
    pub summary: String,
    /// Whether the coordinator was swapped for a new epoch.
    pub restarted: bool,
}

/// Parse + validate `path`, then apply it to the running daemon.
/// Errors leave the daemon exactly as it was.
pub(crate) fn apply_reload(
    state: &DaemonState,
    registry: &PolicyRegistry,
    path: &str,
) -> anyhow::Result<ReloadOutcome> {
    let new = ServeConfig::from_toml_file(path)?;
    new.validate(registry)?;

    let old = state.config();
    anyhow::ensure!(
        new.akpc.n_items == old.akpc.n_items && new.akpc.n_servers == old.akpc.n_servers,
        "reload cannot change the universe (n_items {} -> {}, n_servers {} -> {}); \
         restart the daemon instead",
        old.akpc.n_items,
        new.akpc.n_items,
        old.akpc.n_servers,
        new.akpc.n_servers
    );

    // Live knobs first: these can never fail once validated.
    state.admission.set_slack(new.slack)?;
    state.admission.set_chunk_len(new.chunk);
    state.admission.set_max_items(new.max_items);

    let restart = new.policy != old.policy
        || new.engine != old.engine
        || new.shards != old.shards
        || new.akpc != old.akpc;
    let mut notes = Vec::new();
    if new.reorder_capacity != old.reorder_capacity || new.queue_depth != old.queue_depth {
        notes.push("reorder_capacity/queue_depth change ignored (needs restart)");
    }

    if restart {
        // Lock order: replay client first, coordinator second — the
        // same order drain uses. Holding the client mutex parks the
        // replay thread at a chunk boundary for the whole swap.
        let mut client = state
            .client
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let mut coord_slot = state
            .coordinator
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let next = Coordinator::start_with(
            new.akpc.clone(),
            new.engine.to_engine(),
            new.shards,
            TickMode::Sync,
        )?;
        if let Some(old_coord) = coord_slot.take() {
            old_coord.quiesce();
            let final_snapshot = old_coord.shutdown();
            state
                .prior
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(final_snapshot);
        }
        *client = next.client();
        *coord_slot = Some(next);
    }

    let summary = format!(
        "reloaded: policy={} engine={:?} shards={} slack={}{}{}",
        new.policy,
        new.engine,
        new.shards,
        new.slack,
        if restart { " (new coordinator epoch)" } else { " (live)" },
        if notes.is_empty() {
            String::new()
        } else {
            format!("; {}", notes.join("; "))
        }
    );
    state.set_config(new);
    Ok(ReloadOutcome {
        summary,
        restarted: restart,
    })
}

/// Fold the final snapshots of retired coordinator epochs into the
/// current one, so scrape counters are monotone across hot-reloads.
/// Gauges (`live_cliques`, shard count) keep the current epoch's value;
/// counters and histograms accumulate.
pub fn merge_epochs(prior: &[MetricsSnapshot], mut last: MetricsSnapshot) -> MetricsSnapshot {
    for p in prior {
        last.ledger.merge(&p.ledger);
        last.served += p.served;
        last.windows += p.windows;
        last.clique_gen_secs += p.clique_gen_secs;
        last.clique_hist.merge(&p.clique_hist);
        last.latency_us.merge(&p.latency_us);
        for ps in &p.per_shard {
            if let Some(cur) = last.per_shard.iter_mut().find(|c| c.shard == ps.shard) {
                cur.ledger.merge(&ps.ledger);
                cur.served += ps.served;
                cur.retentions += ps.retentions;
                cur.latency_us.merge(&ps.latency_us);
            } else {
                last.per_shard.push(ps.clone());
            }
        }
    }
    last.per_shard.sort_by_key(|s| s.shard);
    last
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenStats, ShardStats};

    fn snap(shards: &[(usize, u64, f64)], windows: u64) -> MetricsSnapshot {
        let per_shard = shards
            .iter()
            .map(|&(i, served, c_t)| {
                let mut s = ShardStats {
                    shard: i,
                    served,
                    ..Default::default()
                };
                s.ledger.c_t = c_t;
                s.ledger.requests = served;
                s.latency_us.record(5);
                s
            })
            .collect();
        MetricsSnapshot::aggregate(
            GenStats {
                windows,
                ..Default::default()
            },
            per_shard,
        )
    }

    #[test]
    fn merge_epochs_accumulates_counters() {
        let prior = vec![snap(&[(0, 10, 1.0), (1, 5, 0.5)], 3)];
        let last = snap(&[(0, 7, 0.25)], 2);
        let m = merge_epochs(&prior, last);
        assert_eq!(m.served, 22);
        assert_eq!(m.windows, 5);
        assert!((m.ledger.c_t - 1.75).abs() < 1e-12);
        // Shard 1 existed only in the retired epoch; its counters survive.
        assert_eq!(m.per_shard.len(), 2);
        assert_eq!(m.per_shard[1].shard, 1);
        assert_eq!(m.per_shard[1].served, 5);
        assert_eq!(m.latency_us.count(), 4);
    }

    #[test]
    fn merge_epochs_identity_without_priors() {
        let last = snap(&[(0, 7, 0.25)], 2);
        let served = last.served;
        let m = merge_epochs(&[], last);
        assert_eq!(m.served, served);
    }
}
