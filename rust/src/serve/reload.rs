//! Hot-reload (DESIGN.md §12.3): re-read the daemon's TOML config,
//! re-validate it through the same [`ServeConfig::validate`] →
//! `RunSpec::validate()` path used at startup, and only then apply it.
//! An invalid file is rejected with the validation error and the
//! running daemon keeps its current config — reload can never take the
//! service down.
//!
//! Two application tiers:
//!
//! * **Live knobs** (`slack`, `chunk`, `max_items`) apply in place via
//!   the admission layer's setters — no interruption at all.
//! * **Shard count alone** (`shards` changed, everything else equal)
//!   routes through the elastic handoff
//!   ([`Coordinator::resize`], DESIGN.md §13): cache contents, cost
//!   ledgers-as-epochs, clique-gen state, and the open window all carry
//!   over, so items cached before the reload still hit after it. The
//!   retired epoch's snapshot is normalized with
//!   [`MetricsSnapshot::into_handoff_epoch`] before it is folded into
//!   later scrapes (gen counters travel inside the handoff).
//! * **Coordinator knobs** (`policy`, `engine`, anything in `[akpc]`)
//!   genuinely invalidate the cached decisions, so the old coordinator
//!   is drained through its quiesce path and a fresh one is started —
//!   an *epoch swap* with fresh state. Either way the swap happens
//!   while holding the replay thread's client mutex, i.e. at a chunk
//!   boundary: no in-flight request ever sees a half-torn-down
//!   coordinator. Retired epochs are folded into every later scrape and
//!   the final report by [`merge_epochs`], so counters stay monotone
//!   across reloads (a Prometheus contract).
//!
//! `reorder_capacity` and `queue_depth` size buffers threaded through
//! channel construction; changing them takes a restart of the daemon,
//! not just an epoch swap, and reload reports them as ignored.

use std::sync::PoisonError;

use crate::coordinator::{Coordinator, MetricsSnapshot, TickMode};
use crate::run::PolicyRegistry;

use super::config::ServeConfig;
use super::daemon::DaemonState;

/// What a successful reload did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReloadOutcome {
    /// Human-readable summary (returned on the `POST /reload` body).
    pub summary: String,
    /// Whether the coordinator was swapped for a new epoch.
    pub restarted: bool,
}

/// Parse + validate `path`, then apply it to the running daemon.
/// Errors leave the daemon exactly as it was.
pub(crate) fn apply_reload(
    state: &DaemonState,
    registry: &PolicyRegistry,
    path: &str,
) -> anyhow::Result<ReloadOutcome> {
    let new = ServeConfig::from_toml_file(path)?;
    new.validate(registry)?;

    let old = state.config();
    anyhow::ensure!(
        new.akpc.n_items == old.akpc.n_items && new.akpc.n_servers == old.akpc.n_servers,
        "reload cannot change the universe (n_items {} -> {}, n_servers {} -> {}); \
         restart the daemon instead",
        old.akpc.n_items,
        new.akpc.n_items,
        old.akpc.n_servers,
        new.akpc.n_servers
    );

    // Live knobs first: these can never fail once validated.
    state.admission.set_slack(new.slack)?;
    state.admission.set_chunk_len(new.chunk);
    state.admission.set_max_items(new.max_items);

    // A shard-count change with identical policy/engine/[akpc] keeps
    // every cached decision valid — route it through the stateful
    // elastic handoff instead of dropping warm state on the floor.
    let fresh_swap = new.policy != old.policy || new.engine != old.engine || new.akpc != old.akpc;
    let resize_only = !fresh_swap && new.shards != old.shards;
    let restart = fresh_swap || resize_only;
    let mut notes = Vec::new();
    if new.reorder_capacity != old.reorder_capacity || new.queue_depth != old.queue_depth {
        notes.push("reorder_capacity/queue_depth change ignored (needs restart)");
    }

    if restart {
        // Lock order: replay client first, coordinator second — the
        // same order drain uses. Holding the client mutex parks the
        // replay thread at a chunk boundary for the whole swap.
        let mut client = state
            .client
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let mut coord_slot = state
            .coordinator
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let (next, retired) = match coord_slot.take() {
            Some(old_coord) if resize_only => {
                let (next, retired) = old_coord.resize(new.shards)?;
                (next, Some(retired.into_handoff_epoch()))
            }
            old_coord => {
                let next = Coordinator::start_with(
                    new.akpc.clone(),
                    new.engine.to_engine(),
                    new.shards,
                    TickMode::Sync,
                )?;
                let retired = old_coord.map(|c| {
                    c.quiesce();
                    c.shutdown()
                });
                (next, retired)
            }
        };
        if let Some(final_snapshot) = retired {
            state
                .prior
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(final_snapshot);
        }
        *client = next.client();
        *coord_slot = Some(next);
    }

    let summary = format!(
        "reloaded: policy={} engine={:?} shards={} slack={}{}{}",
        new.policy,
        new.engine,
        new.shards,
        new.slack,
        if resize_only {
            " (stateful resize: cache carried over)"
        } else if restart {
            " (new coordinator epoch)"
        } else {
            " (live)"
        },
        if notes.is_empty() {
            String::new()
        } else {
            format!("; {}", notes.join("; "))
        }
    );
    state.set_config(new);
    Ok(ReloadOutcome {
        summary,
        restarted: restart,
    })
}

/// Fold the final snapshots of retired coordinator epochs into the
/// current one, so scrape counters are monotone across hot-reloads.
/// Kept as a re-exportable alias of
/// [`MetricsSnapshot::merge_epochs`] — the elastic replay driver uses
/// the same fold, so the logic lives on the snapshot type.
pub fn merge_epochs(prior: &[MetricsSnapshot], last: MetricsSnapshot) -> MetricsSnapshot {
    MetricsSnapshot::merge_epochs(prior, last)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenStats, ShardStats};

    fn snap(shards: &[(usize, u64, f64)], windows: u64) -> MetricsSnapshot {
        let per_shard = shards
            .iter()
            .map(|&(i, served, c_t)| {
                let mut s = ShardStats {
                    shard: i,
                    served,
                    ..Default::default()
                };
                s.ledger.c_t = c_t;
                s.ledger.requests = served;
                s.latency_us.record(5);
                s
            })
            .collect();
        MetricsSnapshot::aggregate(
            GenStats {
                windows,
                ..Default::default()
            },
            per_shard,
        )
    }

    #[test]
    fn merge_epochs_accumulates_counters() {
        let prior = vec![snap(&[(0, 10, 1.0), (1, 5, 0.5)], 3)];
        let last = snap(&[(0, 7, 0.25)], 2);
        let m = merge_epochs(&prior, last);
        assert_eq!(m.served, 22);
        assert_eq!(m.windows, 5);
        assert!((m.ledger.c_t - 1.75).abs() < 1e-12);
        // Shard 1 existed only in the retired epoch; its counters survive.
        assert_eq!(m.per_shard.len(), 2);
        assert_eq!(m.per_shard[1].shard, 1);
        assert_eq!(m.per_shard[1].served, 5);
        assert_eq!(m.latency_us.count(), 4);
    }

    #[test]
    fn merge_epochs_identity_without_priors() {
        let last = snap(&[(0, 7, 0.25)], 2);
        let served = last.served;
        let m = merge_epochs(&[], last);
        assert_eq!(m.served, served);
    }
}
