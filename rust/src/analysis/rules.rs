//! The akpc-lint rule catalog (DESIGN.md §11).
//!
//! Six repo-specific invariants, each born from a class of bug this
//! codebase actually hit or structurally risks:
//!
//! | id | name | scope |
//! |---|---|---|
//! | L1 | no-float-partial-unwrap | all of `src/` |
//! | L2 | no-hash-iter-decision | `algo/ clique/ crm/ cache/ policy/` |
//! | L3 | no-panic-hot-path | `coordinator/ serve/ elastic/` |
//! | L4 | bounded-channels-only | `coordinator/ serve/ elastic/` |
//! | L5 | no-stream-collect | all of `src/` |
//! | L6 | no-unbounded-recv | `coordinator/ serve/ elastic/` |
//!
//! Every check is a token scan over [`PreparedSource::masked`] — comments
//! and literals can never trip a rule — and every check skips
//! `#[cfg(test)]` regions: unit tests may unwrap, iterate hashes, and
//! collect streams freely. Rules report candidates; the engine in
//! [`super`] applies `akpc-lint: allow(...)` suppressions afterwards.

use super::scanner::PreparedSource;

/// A catalog entry.
pub struct Rule {
    pub id: &'static str,
    pub name: &'static str,
    pub summary: &'static str,
}

/// The enforced invariants, in severity order.
pub const RULES: [Rule; 6] = [
    Rule {
        id: "L1",
        name: "no-float-partial-unwrap",
        summary: "float comparisons must use total_cmp (util::order), not \
                  partial_cmp + unwrap/expect/unwrap_or: NaN either panics \
                  or silently breaks strict weak ordering",
    },
    Rule {
        id: "L2",
        name: "no-hash-iter-decision",
        summary: "algorithmic code must not iterate HashMap/HashSet where \
                  order can leak into decisions; sort first, use a BTree \
                  map, or reduce commutatively",
    },
    Rule {
        id: "L3",
        name: "no-panic-hot-path",
        summary: "coordinator, serving-daemon, and elastic-driver code \
                  must not unwrap/expect/panic: a poisoned shard or dead \
                  daemon thread deadlocks every client blocked on its \
                  mailbox",
    },
    Rule {
        id: "L4",
        name: "bounded-channels-only",
        summary: "coordinator, serving-daemon, and elastic-driver \
                  mailboxes must be bounded sync_channels so a slow actor \
                  exerts backpressure instead of buffering without limit",
    },
    Rule {
        id: "L5",
        name: "no-stream-collect",
        summary: "TraceSource::collect defeats bounded-memory replay; only \
                  needs_offline_trace-gated code may materialize a stream",
    },
    Rule {
        id: "L6",
        name: "no-unbounded-recv",
        summary: "coordinator, serving-daemon, and elastic-driver code must \
                  not block forever on a peer that may never answer: use \
                  recv_timeout instead of bare recv, and signal shutdown \
                  before joining a thread",
    },
];

/// A candidate violation (pre-allow-filtering).
pub struct RawDiag {
    pub rule: &'static str,
    pub line: usize,
    pub message: String,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets of every occurrence of `pat` in `hay`.
fn find_all(hay: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = hay[from..].find(pat) {
        out.push(from + rel);
        from += rel + pat.len();
    }
    out
}

/// Run every rule whose scope covers `rel_path` over one prepared file.
pub fn check_file(rel_path: &str, src: &PreparedSource) -> Vec<RawDiag> {
    let path = rel_path.replace('\\', "/");
    let mut out = Vec::new();
    l1_no_float_partial_unwrap(src, &mut out);
    if ["algo/", "clique/", "crm/", "cache/", "policy/"]
        .iter()
        .any(|d| path.contains(d))
    {
        l2_no_hash_iter_decision(src, &mut out);
    }
    if path.contains("coordinator/") || path.contains("serve/") || path.contains("elastic/")
    {
        l3_no_panic_hot_path(src, &mut out);
        l4_bounded_channels_only(src, &mut out);
        l6_no_unbounded_recv(src, &mut out);
    }
    l5_no_stream_collect(src, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// L1 — `.partial_cmp(..)` followed (in the same statement) by
/// `.unwrap()` / `.expect(` / `.unwrap_or`. The leading dot keeps
/// `fn partial_cmp` trait impls out; `Option`-aware uses (`match`,
/// `is_none`, `?`) pass.
fn l1_no_float_partial_unwrap(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    for at in find_all(m, ".partial_cmp(") {
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        let (_, end) = src.statement_window(at);
        let tail = &m[at..end];
        if [".unwrap()", ".expect(", ".unwrap_or"]
            .iter()
            .any(|t| tail.contains(t))
        {
            out.push(RawDiag {
                rule: "L1",
                line,
                message: "partial_cmp unwrapped on a float comparison; use \
                          total_cmp or util::order::total_f64"
                    .into(),
            });
        }
    }
}

/// Iteration-order-sensitive hash accesses L2 looks for.
const HASH_ITER_TOKENS: [&str; 9] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".into_keys()",
    ".into_values()",
    ".drain(",
];

/// Order-insensitive reductions that exonerate a hash iteration when they
/// terminate the same statement.
const COMMUTATIVE_SINKS: [&str; 10] = [
    ".sum()",
    ".sum::",
    ".count()",
    ".len()",
    ".min(",
    ".max(",
    ".all(",
    ".any(",
    ".contains",
    ".product()",
];

/// Loop-body assignments that make a `for` over a hash map harmless.
const COMMUTATIVE_BODY_OPS: [&str; 6] = [".max(", ".min(", "+=", "*=", "|=", "&="];

/// L2 — order-sensitive iteration over `HashMap`/`HashSet` in algorithmic
/// code. Two passes: collect the identifiers bound with a hash type in
/// this file (let bindings, params, struct fields), then flag iteration
/// tokens whose receiver is one of them — unless the statement reduces
/// commutatively, collects back into a hash/ordered container, or sorts
/// the collected buffer within the next few lines.
fn l2_no_hash_iter_decision(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    let hash_bound = hash_bound_idents(m);
    if hash_bound.is_empty() {
        return;
    }

    // Method-token sites.
    for tok in HASH_ITER_TOKENS {
        for at in find_all(m, tok) {
            let line = src.line_of(at);
            if src.in_test_region(line) {
                continue;
            }
            let recv = match src.receiver_ident(at) {
                Some(r) => r.to_string(),
                None => continue,
            };
            if !hash_bound.contains(&recv) {
                continue;
            }
            let (start, end) = src.statement_window(at);
            let stmt = &m[start..end];
            if COMMUTATIVE_SINKS.iter().any(|s| stmt.contains(s)) {
                continue;
            }
            if stmt.contains(".collect") {
                // Collecting into another hash (order re-scrambled, not
                // consumed) or an ordered map is fine; so is collecting a
                // buffer that is sorted immediately after.
                if ["HashMap", "HashSet", "BTreeMap", "BTreeSet"]
                    .iter()
                    .any(|t| stmt.contains(t))
                {
                    continue;
                }
                let stmt_end_line = src.line_of(end.min(m.len().saturating_sub(1)));
                if (stmt_end_line..=stmt_end_line + 6)
                    .any(|l| src.line_text(l).contains(".sort"))
                {
                    continue;
                }
            }
            // Inside a `for` header the loop body is the statement's
            // continuation: allow commutative accumulation bodies.
            if stmt.trim_start().starts_with("for ")
                && body_is_commutative(src, end)
            {
                continue;
            }
            out.push(RawDiag {
                rule: "L2",
                line,
                message: format!(
                    "hash-order iteration over `{recv}` can leak bucket \
                     order into decisions; sort first or reduce \
                     commutatively"
                ),
            });
        }
    }

    // Bare `for pat in [&[mut ]]name {` loops (no method token).
    for at in find_all(m, "for ") {
        if at > 0 && is_ident(m.as_bytes()[at - 1]) {
            continue;
        }
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        let (_, end) = src.statement_window(at);
        let header = &m[at..end];
        let Some(in_pos) = header.find(" in ") else {
            continue;
        };
        let expr = header[in_pos + 4..].trim();
        let expr = expr
            .trim_start_matches('&')
            .trim_start_matches("mut ")
            .trim_start_matches("self.")
            .trim();
        if expr.bytes().all(is_ident)
            && !expr.is_empty()
            && hash_bound.contains(expr)
            && !body_is_commutative(src, end)
        {
            out.push(RawDiag {
                rule: "L2",
                line,
                message: format!(
                    "hash-order `for` loop over `{expr}`; iterate a sorted \
                     view instead"
                ),
            });
        }
    }
}

/// Identifiers declared with a `HashMap`/`HashSet` type in this file:
/// `name: HashMap<..>` (params, fields, annotated lets, `&`/`&mut`
/// borrows) and `name = HashMap::new()` style initializers.
fn hash_bound_idents(masked: &str) -> std::collections::BTreeSet<String> {
    let mut found = std::collections::BTreeSet::new();
    let b = masked.as_bytes();
    for ty in ["HashMap", "HashSet"] {
        for at in find_all(masked, ty) {
            if at > 0 && is_ident(b[at - 1]) {
                continue; // part of a longer identifier
            }
            // Walk back over path prefixes (`std::collections::`),
            // borrows and whitespace to the `:` or `=` introducer.
            let mut i = at;
            loop {
                while i > 0 && (b[i - 1] as char).is_whitespace() {
                    i -= 1;
                }
                if i >= 2 && &masked[i - 2..i] == "::" {
                    i -= 2;
                    while i > 0 && is_ident(b[i - 1]) {
                        i -= 1;
                    }
                    continue;
                }
                if i > 0 && (b[i - 1] == b'&' || b[i - 1] == b'<') {
                    i -= 1;
                    continue;
                }
                if i >= 4 && &masked[i - 4..i] == "mut " {
                    i -= 4;
                    continue;
                }
                break;
            }
            if i == 0 || (b[i - 1] != b':' && b[i - 1] != b'=') {
                continue;
            }
            i -= 1;
            if b[i] == b':' && i > 0 && b[i - 1] == b':' {
                continue; // `::HashMap` with no binding — a bare path use
            }
            while i > 0 && (b[i - 1] as char).is_whitespace() {
                i -= 1;
            }
            let end = i;
            while i > 0 && is_ident(b[i - 1]) {
                i -= 1;
            }
            let name = &masked[i..end];
            if !name.is_empty() && !name.chars().next().is_some_and(|c| c.is_ascii_digit())
            {
                found.insert(name.to_string());
            }
        }
    }
    // `let name = HashMap::new()` binds through `=`; the backward walk
    // above lands on `=` and extracts `name` the same way, but strip the
    // keywords that can precede a pattern.
    found.remove("let");
    found.remove("mut");
    found.remove("in");
    found
}

/// True when the three lines after a `for` header's `{` only accumulate
/// commutatively (`+=`, `|=`, `.max(` ...).
fn body_is_commutative(src: &PreparedSource, header_end: usize) -> bool {
    let open_line = src.line_of(header_end.min(src.masked().len().saturating_sub(1)));
    (open_line..open_line + 3).any(|l| {
        let t = src.line_text(l);
        COMMUTATIVE_BODY_OPS.iter().any(|op| t.contains(op))
    })
}

/// L3 — panicking constructs in the coordinator's actor/hot path.
/// `.unwrap()` is matched exactly, so `unwrap_or_else` (the poison-safe
/// mutex idiom) passes; `std::panic::resume_unwind` (re-raising a worker
/// panic at the join) is deliberately not in the list.
fn l3_no_panic_hot_path(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    for tok in [
        ".unwrap()",
        ".expect(",
        "panic!(",
        "unreachable!(",
        "todo!(",
        "unimplemented!(",
    ] {
        for at in find_all(m, tok) {
            if at > 0 && !tok.starts_with('.') && is_ident(m.as_bytes()[at - 1]) {
                continue;
            }
            let line = src.line_of(at);
            if src.in_test_region(line) {
                continue;
            }
            out.push(RawDiag {
                rule: "L3",
                line,
                message: format!(
                    "`{}` in coordinator hot path; return a typed error or \
                     degrade (a panicked actor deadlocks its clients)",
                    tok.trim_end_matches('(')
                ),
            });
        }
    }
}

/// L4 — unbounded `mpsc::channel()` in the coordinator. Matches the bare
/// `channel` identifier in call position; `sync_channel` has an ident
/// byte before the token and never matches.
fn l4_bounded_channels_only(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    for at in find_all(m, "channel") {
        let b = m.as_bytes();
        if at > 0 && is_ident(b[at - 1]) {
            continue;
        }
        let after = &m[at + "channel".len()..];
        let call = after.starts_with('(') || after.starts_with("::<");
        if !call {
            continue;
        }
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        out.push(RawDiag {
            rule: "L4",
            line,
            message: "unbounded mpsc::channel() in the coordinator; use \
                      sync_channel with an explicit depth (backpressure, \
                      not unbounded buffering)"
                .into(),
        });
    }
}

/// L5 — materializing a streaming `TraceSource` outside the documented
/// offline gate. Receivers named `source`/`src`, or bound in this file
/// with a type mentioning `TraceSource`, calling `.collect()`, must have
/// a `needs_offline_trace` check within the preceding 25 lines.
fn l5_no_stream_collect(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    let mut stream_idents: std::collections::BTreeSet<String> =
        ["source", "src"].iter().map(|s| s.to_string()).collect();
    for at in find_all(m, "TraceSource") {
        // `name: &mut dyn TraceSource` / `name: impl TraceSource` /
        // `name: Box<dyn TraceSource>` — take the ident before the `:`.
        let head_start = m[..at]
            .rfind(&['\n', ';', '{', '(', ','][..])
            .map_or(0, |p| p + 1);
        let head = &m[head_start..at];
        if let Some(colon) = head.find(':') {
            let name: String = head[..colon]
                .trim()
                .trim_start_matches("mut ")
                .to_string();
            if !name.is_empty() && name.bytes().all(is_ident) {
                stream_idents.insert(name);
            }
        }
    }
    for at in find_all(m, ".collect()") {
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        let recv = match src.receiver_ident(at) {
            Some(r) => r.to_string(),
            None => continue,
        };
        if !stream_idents.contains(&recv) {
            continue;
        }
        let gated = (line.saturating_sub(25)..=line)
            .any(|l| src.line_text(l).contains("needs_offline_trace"));
        if gated {
            continue;
        }
        out.push(RawDiag {
            rule: "L5",
            line,
            message: format!(
                "`{recv}.collect()` materializes a TraceSource outside a \
                 needs_offline_trace gate; bounded-memory replay is the \
                 default contract (DESIGN.md §10)"
            ),
        });
    }
}

/// Shutdown evidence that exonerates a `.join()`: within the preceding
/// window the joined thread was told to stop (a shutdown/drain message,
/// a stop flag, a dropped sender closing its mailbox) or polled for
/// completion first.
const JOIN_EVIDENCE: [&str; 6] = [
    "shutdown",
    "Shutdown",
    "store(true",
    "is_finished",
    "Drain",
    "drop(",
];

/// L6 — blocking forever on a peer that may never answer (the bug class
/// behind DESIGN.md §14.1: a panicked shard leaves its rendezvous reply
/// channel dangling and a bare `recv` deadlocks the caller). Two forms:
///
/// * a bare `.recv()` outside the `while let` mailbox-drain idiom — the
///   drain loop *is* the shutdown protocol (it ends when every sender
///   hangs up), but a single rendezvous `recv` must use `recv_timeout`
///   so a dead peer becomes a typed `ShardLost` instead of a hang;
/// * a `.join()` with no shutdown evidence in the preceding 20 lines —
///   joining a thread nobody told to stop waits forever.
fn l6_no_unbounded_recv(src: &PreparedSource, out: &mut Vec<RawDiag>) {
    let m = src.masked();
    for at in find_all(m, ".recv()") {
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        let (start, _) = src.statement_window(at);
        if m[start..at].trim_start().starts_with("while let") {
            continue;
        }
        out.push(RawDiag {
            rule: "L6",
            line,
            message: "bare `.recv()` blocks forever on a dead peer; use \
                      recv_timeout and surface a typed loss (DESIGN.md \
                      §14.1)"
                .into(),
        });
    }
    for at in find_all(m, ".join()") {
        let line = src.line_of(at);
        if src.in_test_region(line) {
            continue;
        }
        let signaled = (line.saturating_sub(20)..=line).any(|l| {
            let t = src.line_text(l);
            JOIN_EVIDENCE.iter().any(|e| t.contains(e))
        });
        if signaled {
            continue;
        }
        out.push(RawDiag {
            rule: "L6",
            line,
            message: "`.join()` with no shutdown signal in the preceding \
                      lines waits forever on a thread nobody told to stop; \
                      send Shutdown / set the stop flag / drop the sender \
                      first"
                .into(),
        });
    }
}

/// True when `id` names a cataloged rule.
pub fn known_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}
