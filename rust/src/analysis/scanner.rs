//! Lexical source preparation for the lint rules (DESIGN.md §11).
//!
//! The rules in [`super::rules`] are token scans, not a parse: this module
//! gives them a view of the source where they cannot be fooled by
//! lookalike text. [`PreparedSource::prepare`] walks the file once with a
//! small state machine and produces
//!
//! * `masked` — the source with comment and string/char-literal *bytes*
//!   blanked to spaces (newlines preserved, so offsets and line numbers
//!   are identical to the original). A rule that greps `masked` for
//!   `.unwrap()` can never match a doc comment or a fixture string.
//! * test regions — the line spans of `#[cfg(test)]` / `#[test]` items,
//!   found by brace-matching on the masked text. Unit tests may unwrap.
//! * comments — the text of every `//` comment with its line number, for
//!   the `akpc-lint: allow(...)` escape-hatch parser.
//!
//! The same hand-rolled style as `tests/doc_refs.rs`: no `syn`, no regex —
//! the only crate dependency anywhere in `analysis/` is `anyhow`, which
//! the build already vendors.

/// A source file preprocessed for rule scans.
pub struct PreparedSource {
    text: String,
    masked: String,
    /// Byte offset of the start of each line (line 1 first).
    line_starts: Vec<usize>,
    /// 1-based inclusive line spans covered by test-only items.
    test_regions: Vec<(usize, usize)>,
    /// `(line, comment text after the `//` marker)`.
    comments: Vec<(usize, String)>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

impl PreparedSource {
    /// Run the masking pass and locate test regions.
    pub fn prepare(text: &str) -> PreparedSource {
        let bytes = text.as_bytes();
        let mut masked = bytes.to_vec();
        let mut comments = Vec::new();
        let mut line_starts = vec![0usize];
        let mut line = 1usize;

        let blank = |m: &mut [u8], range: std::ops::Range<usize>| {
            for b in &mut m[range] {
                if *b != b'\n' {
                    *b = b' ';
                }
            }
        };

        let mut i = 0usize;
        while i < bytes.len() {
            let b = bytes[i];
            if b == b'\n' {
                line += 1;
                line_starts.push(i + 1);
                i += 1;
            } else if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
                // Line comment (also doc comments). Record its text for
                // the allow-parser, then blank it.
                let start = i;
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
                comments.push((
                    line,
                    String::from_utf8_lossy(&bytes[start + 2..i]).into_owned(),
                ));
                blank(&mut masked, start..i);
            } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                // Block comment (nests in Rust).
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'\n' {
                        line += 1;
                        line_starts.push(i + 1);
                        i += 1;
                    } else if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                blank(&mut masked, start..i);
            } else if b == b'"' {
                // String literal: blank the contents, keep the quotes.
                let start = i;
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => {
                            // `\<newline>` continuation still ends a line.
                            if bytes.get(i + 1) == Some(&b'\n') {
                                line += 1;
                                line_starts.push(i + 2);
                            }
                            i += 2;
                        }
                        b'"' => break,
                        b'\n' => {
                            line += 1;
                            line_starts.push(i + 1);
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                i = (i + 1).min(bytes.len());
                blank(&mut masked, start + 1..i.saturating_sub(1));
            } else if b == b'r'
                && !matches!(i.checked_sub(1).map(|p| bytes[p]), Some(p) if is_ident(p))
                && matches!(bytes.get(i + 1), Some(&b'"') | Some(&b'#'))
            {
                // Raw string r"..." / r#"..."# (any hash count).
                let mut j = i + 1;
                let mut hashes = 0usize;
                while bytes.get(j) == Some(&b'#') {
                    hashes += 1;
                    j += 1;
                }
                if bytes.get(j) == Some(&b'"') {
                    let content_start = j + 1;
                    let mut k = content_start;
                    'raw: while k < bytes.len() {
                        if bytes[k] == b'\n' {
                            line += 1;
                            line_starts.push(k + 1);
                        } else if bytes[k] == b'"' {
                            let mut h = 0usize;
                            while bytes.get(k + 1 + h) == Some(&b'#') {
                                h += 1;
                            }
                            if h >= hashes {
                                blank(&mut masked, content_start..k);
                                i = k + 1 + hashes;
                                break 'raw;
                            }
                        }
                        k += 1;
                    }
                    if k >= bytes.len() {
                        blank(&mut masked, content_start..bytes.len());
                        i = bytes.len();
                    }
                } else {
                    i += 1; // plain identifier starting with `r`
                }
            } else if b == b'\'' {
                // Char literal vs lifetime. `'\...'` or `'X'` is a char;
                // anything else (`'a`, `'static`) is a lifetime label.
                if bytes.get(i + 1) == Some(&b'\\') {
                    let start = i;
                    i += 2;
                    while i < bytes.len() && bytes[i] != b'\'' {
                        i += 1;
                    }
                    i = (i + 1).min(bytes.len());
                    blank(&mut masked, start + 1..i.saturating_sub(1));
                } else if bytes.get(i + 2) == Some(&b'\'') {
                    blank(&mut masked, i + 1..i + 2);
                    i += 3;
                } else {
                    i += 1;
                }
            } else {
                i += 1;
            }
        }

        let masked = String::from_utf8_lossy(&masked).into_owned();
        let mut prepared = PreparedSource {
            text: text.to_string(),
            masked,
            line_starts,
            test_regions: Vec::new(),
            comments,
        };
        prepared.test_regions = prepared.find_test_regions();
        prepared
    }

    /// The masked text rules scan. Same byte length as the original.
    pub fn masked(&self) -> &str {
        &self.masked
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Original text of a 1-based line (no trailing newline).
    pub fn line_text(&self, line: usize) -> &str {
        let start = match self.line_starts.get(line - 1) {
            Some(&s) => s,
            None => return "",
        };
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.text.len());
        self.text.get(start..end).unwrap_or("")
    }

    /// Number of lines in the file.
    pub fn n_lines(&self) -> usize {
        self.line_starts.len()
    }

    /// True when the line falls inside a `#[cfg(test)]` / `#[test]` item.
    pub fn in_test_region(&self, line: usize) -> bool {
        self.test_regions
            .iter()
            .any(|&(lo, hi)| lo <= line && line <= hi)
    }

    /// Every `//` comment with its 1-based line.
    pub fn comments(&self) -> &[(usize, String)] {
        &self.comments
    }

    /// Line spans of test-only items: each `#[cfg(test)]`/`#[test]`
    /// attribute, through the matching `}` of its item's body.
    fn find_test_regions(&self) -> Vec<(usize, usize)> {
        let m = self.masked.as_bytes();
        let mut regions = Vec::new();
        for pat in ["#[cfg(test)]", "#[test]"] {
            let mut from = 0usize;
            while let Some(rel) = self.masked[from..].find(pat) {
                let at = from + rel;
                from = at + pat.len();
                // Skip any further attributes/whitespace to the item's
                // opening brace, then brace-match in masked text.
                let mut j = at + pat.len();
                let mut depth = 0usize;
                let mut opened = false;
                while j < m.len() {
                    match m[j] {
                        b'{' => {
                            depth += 1;
                            opened = true;
                        }
                        b'}' => {
                            depth = depth.saturating_sub(1);
                            if opened && depth == 0 {
                                break;
                            }
                        }
                        b';' if !opened => break, // e.g. `#[cfg(test)] use ...;`
                        _ => {}
                    }
                    j += 1;
                }
                regions.push((self.line_of(at), self.line_of(j.min(m.len() - 1))));
            }
        }
        regions
    }

    /// Logical-statement window around `offset` in the masked text:
    /// backward to just past the previous `;`/`{`/`}`, forward to the
    /// next `;`/`{`/`}` (inclusive of neither). Heuristic — good enough
    /// for "does this call chain end in an unwrap / a collect".
    pub fn statement_window(&self, offset: usize) -> (usize, usize) {
        let m = self.masked.as_bytes();
        let mut start = offset;
        while start > 0 && !matches!(m[start - 1], b';' | b'{' | b'}') {
            start -= 1;
        }
        let mut end = offset;
        while end < m.len() && !matches!(m[end], b';' | b'{' | b'}') {
            end += 1;
        }
        (start, end)
    }

    /// The identifier a method call at `dot_offset` is invoked on: scans
    /// backward over whitespace (method chains may break the line before
    /// the dot), then reads one identifier. `self.copies.iter()` yields
    /// `copies` — the final path segment. Returns `None` for complex
    /// receivers (`)`/`]` — call results, index expressions).
    pub fn receiver_ident(&self, dot_offset: usize) -> Option<&str> {
        let m = self.masked.as_bytes();
        let mut i = dot_offset;
        while i > 0 && (m[i - 1] as char).is_whitespace() {
            i -= 1;
        }
        if i == 0 || !is_ident(m[i - 1]) {
            return None;
        }
        let end = i;
        while i > 0 && is_ident(m[i - 1]) {
            i -= 1;
        }
        self.masked.get(i..end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings() {
        let src = "let a = \"x.unwrap()\"; // .unwrap() here\nlet b = 1;\n";
        let p = PreparedSource::prepare(src);
        assert!(!p.masked().contains("unwrap"));
        assert_eq!(p.masked().len(), src.len());
        assert_eq!(p.comments().len(), 1);
        assert!(p.comments()[0].1.contains(".unwrap() here"));
    }

    #[test]
    fn masks_raw_strings_and_char_literals() {
        let src = "let s = r#\"a.expect(\"boom\")\"#;\nlet c = 'p'; let l: &'static str = \"\";\n";
        let p = PreparedSource::prepare(src);
        assert!(!p.masked().contains("expect"));
        assert!(!p.masked().contains('p'), "char literal content masked");
        assert!(p.masked().contains("static"), "lifetime left intact");
    }

    #[test]
    fn line_numbers_survive_masking() {
        let src = "/* a\nb\nc */\nlet x = 1;\n";
        let p = PreparedSource::prepare(src);
        let off = p.masked().find("let x").unwrap();
        assert_eq!(p.line_of(off), 4);
        assert_eq!(p.line_text(4), "let x = 1;");
    }

    #[test]
    fn test_regions_cover_test_mods() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let p = PreparedSource::prepare(src);
        assert!(!p.in_test_region(1));
        assert!(p.in_test_region(4));
        assert!(!p.in_test_region(6));
    }

    #[test]
    fn receiver_crosses_line_breaks() {
        let src = "let v = counts\n    .iter();\n";
        let p = PreparedSource::prepare(src);
        let dot = p.masked().find(".iter").unwrap();
        assert_eq!(p.receiver_ident(dot), Some("counts"));
    }

    #[test]
    fn statement_window_stops_at_separators() {
        let src = "a.b(); c.partial_cmp(&d).unwrap(); e.f();\n";
        let p = PreparedSource::prepare(src);
        let at = p.masked().find("partial_cmp").unwrap();
        let (s, e) = p.statement_window(at);
        let w = &p.masked()[s..e];
        assert!(w.contains("partial_cmp") && w.contains(".unwrap()"));
        assert!(!w.contains("a.b") && !w.contains("e.f"));
    }
}
