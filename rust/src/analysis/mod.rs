//! akpc-lint — the repo's own invariant checker (DESIGN.md §11).
//!
//! A dependency-free static-analysis pass over `rust/src/**` that enforces
//! the determinism, panic-freedom and backpressure invariants the
//! equivalence suites rely on. The paper's claims are replayed as exact
//! cost equalities (1e-9 tolerance across single-leader / sharded /
//! streamed drivers), which makes the codebase unusually sensitive to a
//! specific set of Rust footguns: NaN-unsound float sorts, hash-order
//! iteration in decision paths, panics inside coordinator actors,
//! unbounded mailboxes, and accidental materialization of streaming
//! traces. Those are exactly the five rules in [`rules::RULES`].
//!
//! Run it as `akpc lint` (CI blocks on it) or through `cargo test -q
//! --test lint`. Suppress a finding with a justified escape hatch:
//!
//! ```text
//! // akpc-lint: allow(L2) -- bucket drain order is immaterial here
//! for (k, v) in map { ... }
//! ```
//!
//! The justification after `--` is mandatory; an allow without one is
//! itself a diagnostic. Every suppression is counted in the report so
//! reviewers see the full escape-hatch surface.

pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

use scanner::PreparedSource;

/// One confirmed violation.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Rule id (`L1`..`L5`, or `A0` for a malformed allow comment).
    pub rule: String,
    /// Path relative to the scanned root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

/// One justified suppression that matched a finding.
#[derive(Debug, Clone)]
pub struct AllowRecord {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub justification: String,
}

/// Aggregated result of a lint run.
#[derive(Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<AllowRecord>,
}

impl LintReport {
    /// No violations (suppressions are fine — they are justified).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable report, one diagnostic per block, then the
    /// suppression inventory and a PASS/FAIL trailer.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "akpc-lint: {} file(s) scanned, {} violation(s), {} justified allow(s)\n",
            self.files_scanned,
            self.diagnostics.len(),
            self.allows.len()
        ));
        for d in &self.diagnostics {
            s.push_str(&format!(
                "{}:{} [{}] {}\n    {}\n",
                d.file, d.line, d.rule, d.message, d.excerpt
            ));
        }
        if !self.allows.is_empty() {
            s.push_str("suppressions:\n");
            for a in &self.allows {
                s.push_str(&format!(
                    "{}:{} [{}] -- {}\n",
                    a.file, a.line, a.rule, a.justification
                ));
            }
        }
        s.push_str(if self.is_clean() {
            "akpc-lint: PASS\n"
        } else {
            "akpc-lint: FAIL\n"
        });
        s
    }
}

/// A parsed `akpc-lint: allow(<rule>) -- <justification>` comment.
struct Allow {
    rule: String,
    /// Line the allowance covers: its own line (trailing form) and the
    /// next line (standalone-comment-above form).
    line: usize,
    justification: String,
}

const ALLOW_MARK: &str = "akpc-lint:";

/// Parse the allow comments of one file. Malformed markers (unknown rule,
/// missing `--` justification) become `A0` diagnostics — a suppression
/// that cannot be audited is itself a violation.
fn parse_allows(
    rel_path: &str,
    src: &PreparedSource,
) -> (Vec<Allow>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for (line, text) in src.comments() {
        // A directive must *lead* the comment (after doc-comment markers
        // `/`/`!`); rustdoc prose that merely mentions `akpc-lint:` is
        // not an allow attempt and must not be diagnosed as one.
        let head = text.trim_start_matches(['/', '!', ' ', '\t']);
        let Some(rest) = head.strip_prefix(ALLOW_MARK) else {
            continue;
        };
        let rest = rest.trim_start();
        let mut fail = |why: &str| {
            bad.push(Diagnostic {
                rule: "A0".into(),
                file: rel_path.into(),
                line: *line,
                message: format!("malformed akpc-lint allow: {why}"),
                excerpt: src.line_text(*line).trim().to_string(),
            });
        };
        let Some(args) = rest.strip_prefix("allow(") else {
            fail("expected `allow(<rule>)`");
            continue;
        };
        let Some(close) = args.find(')') else {
            fail("unclosed `allow(`");
            continue;
        };
        let rule = args[..close].trim().to_string();
        if !rules::known_rule(&rule) {
            fail(&format!("unknown rule `{rule}`"));
            continue;
        }
        let tail = args[close + 1..].trim_start();
        let Some(justification) = tail.strip_prefix("--") else {
            fail("missing ` -- <justification>`");
            continue;
        };
        let justification = justification.trim().to_string();
        if justification.is_empty() {
            fail("empty justification");
            continue;
        }
        allows.push(Allow {
            rule,
            line: *line,
            justification,
        });
    }
    (allows, bad)
}

/// Lint one file's text. Returns the surviving diagnostics and the
/// suppressions that actually matched a finding.
pub fn lint_source(rel_path: &str, text: &str) -> (Vec<Diagnostic>, Vec<AllowRecord>) {
    let src = PreparedSource::prepare(text);
    let (allows, mut diags) = parse_allows(rel_path, &src);
    let mut used = Vec::new();
    for raw in rules::check_file(rel_path, &src) {
        let covering = allows.iter().find(|a| {
            a.rule == raw.rule && (a.line == raw.line || a.line + 1 == raw.line)
        });
        match covering {
            Some(a) => used.push(AllowRecord {
                rule: a.rule.clone(),
                file: rel_path.into(),
                line: raw.line,
                justification: a.justification.clone(),
            }),
            None => diags.push(Diagnostic {
                rule: raw.rule.into(),
                file: rel_path.into(),
                line: raw.line,
                message: raw.message,
                excerpt: src.line_text(raw.line).trim().to_string(),
            }),
        }
    }
    diags.sort_by(|a, b| (a.line, a.rule.clone()).cmp(&(b.line, b.rule.clone())));
    (diags, used)
}

/// Recursively collect `.rs` files under `root`, sorted for determinism.
fn rust_files(root: &Path) -> anyhow::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)
            .map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?
        {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `src_root` and aggregate.
pub fn lint_tree(src_root: &Path) -> anyhow::Result<LintReport> {
    let mut report = LintReport::default();
    for path in rust_files(src_root)? {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let (diags, allows) = lint_source(&rel, &text);
        report.files_scanned += 1;
        report.diagnostics.extend(diags);
        report.allows.extend(allows);
    }
    Ok(report)
}

// ---------------------------------------------------------------------
// Fixture self-tests: every rule must trip on its bad fixture and stay
// quiet on the near-miss. These fixtures are the rule's spec.
// ---------------------------------------------------------------------
#[cfg(test)]
mod tests {
    use super::*;

    fn diags(path: &str, text: &str) -> Vec<Diagnostic> {
        lint_source(path, text).0
    }

    fn rules_of(ds: &[Diagnostic]) -> Vec<&str> {
        ds.iter().map(|d| d.rule.as_str()).collect()
    }

    // ---- L1 ----

    #[test]
    fn l1_trips_on_partial_cmp_unwrap() {
        let bad = "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let ds = diags("algo/x.rs", bad);
        assert_eq!(rules_of(&ds), vec!["L1"], "{ds:?}");
        assert_eq!(ds[0].line, 2);
        let expect = "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).expect(\"nan\"));\n}\n";
        assert_eq!(rules_of(&diags("algo/x.rs", expect)), vec!["L1"]);
        let or = "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n}\n";
        assert_eq!(rules_of(&diags("algo/x.rs", or)), vec!["L1"]);
    }

    #[test]
    fn l1_near_misses_pass() {
        // total_cmp, Option-aware partial_cmp, and a partial_cmp trait
        // impl are all fine; so is an unwrap inside #[cfg(test)].
        let ok = "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.total_cmp(b));\n    let c = 1.0f64.partial_cmp(&2.0);\n    if c.is_none() { return; }\n}\nimpl PartialOrd for X {\n    fn partial_cmp(&self, o: &X) -> Option<std::cmp::Ordering> { None }\n}\n#[cfg(test)]\nmod tests {\n    fn t(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n}\n";
        assert!(diags("algo/x.rs", ok).is_empty());
    }

    #[test]
    fn l1_masked_text_never_trips() {
        let ok = "// a.partial_cmp(b).unwrap() in prose\nconst S: &str = \"a.partial_cmp(b).unwrap()\";\n";
        assert!(diags("algo/x.rs", ok).is_empty());
    }

    // ---- L2 ----

    #[test]
    fn l2_trips_on_hash_iteration_in_scoped_dirs() {
        let bad = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f32>) -> Vec<u32> {\n    m.keys().copied().collect::<Vec<_>>()\n}\n";
        let ds = diags("crm/x.rs", bad);
        assert_eq!(rules_of(&ds), vec!["L2"], "{ds:?}");
        // The extended policy families (policy/, DESIGN.md §15) carry
        // learned state; order leaks there are packing-decision bugs too.
        assert_eq!(rules_of(&diags("policy/x.rs", bad)), vec!["L2"]);
        // Same text outside the scoped dirs: no finding.
        assert!(diags("run/x.rs", bad).is_empty());
    }

    #[test]
    fn l2_trips_on_for_loop_over_hash() {
        let bad = "use std::collections::HashMap;\nfn f(m: HashMap<u32, u32>, out: &mut Vec<u32>) {\n    for (k, _) in &m {\n        out.push(*k);\n    }\n}\n";
        assert_eq!(rules_of(&diags("cache/x.rs", bad)), vec!["L2"]);
    }

    #[test]
    fn l2_near_misses_pass() {
        // Commutative reductions, sorted collects, hash-to-hash rebuilds
        // and BTreeMap iteration are all order-safe.
        let ok = concat!(
            "use std::collections::{BTreeMap, HashMap};\n",
            "fn f(m: &HashMap<u32, f32>, b: &BTreeMap<u32, u32>) -> f32 {\n",
            "    let mut hi = 0.0f32;\n",
            "    for &v in m.values() {\n",
            "        hi = hi.max(v);\n",
            "    }\n",
            "    let total: f32 = m.values().sum();\n",
            "    let mut ks: Vec<u32> = m.keys().copied().collect();\n",
            "    ks.sort_unstable();\n",
            "    let rebuilt: HashMap<u32, f32> = m.iter().map(|(k, v)| (*k, *v)).collect();\n",
            "    for (_k, _v) in b {\n",
            "    }\n",
            "    hi + total + ks.len() as f32 + rebuilt.len() as f32\n",
            "}\n",
        );
        let ds = diags("clique/x.rs", ok);
        assert!(ds.is_empty(), "{ds:?}");
    }

    // ---- L3 ----

    #[test]
    fn l3_trips_on_panics_in_coordinator() {
        let bad = "fn f(x: Option<u32>) -> u32 {\n    let v = x.unwrap();\n    if v > 9 { panic!(\"big\"); }\n    v\n}\n";
        let ds = diags("coordinator/x.rs", bad);
        assert_eq!(rules_of(&ds), vec!["L3", "L3"], "{ds:?}");
        // The serving daemon is hot-path too (live clients block on it).
        assert_eq!(rules_of(&diags("serve/x.rs", bad)), vec!["L3", "L3"]);
        // So is the elastic autoscaler (it owns live resize handoffs).
        assert_eq!(rules_of(&diags("elastic/x.rs", bad)), vec!["L3", "L3"]);
        // The same file outside coordinator//serve//elastic/ is out of scope.
        assert!(diags("bench/x.rs", bad).is_empty());
    }

    #[test]
    fn l3_near_misses_pass() {
        let ok = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n    let d = Some(3).unwrap_or(7);\n    *g + d\n}\n#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        let ds = diags("coordinator/x.rs", ok);
        assert!(ds.is_empty(), "{ds:?}");
    }

    // ---- L4 ----

    #[test]
    fn l4_trips_on_unbounded_channel() {
        let bad = "use std::sync::mpsc;\nfn f() {\n    let (tx, rx) = mpsc::channel::<u32>();\n    let (a, b) = mpsc::channel();\n    drop((tx, rx, a, b));\n}\n";
        assert_eq!(rules_of(&diags("coordinator/x.rs", bad)), vec!["L4", "L4"]);
        assert_eq!(rules_of(&diags("serve/x.rs", bad)), vec!["L4", "L4"]);
        assert_eq!(rules_of(&diags("elastic/x.rs", bad)), vec!["L4", "L4"]);
    }

    #[test]
    fn l4_sync_channel_passes() {
        let ok = "use std::sync::mpsc;\nfn f() {\n    let (tx, rx) = mpsc::sync_channel::<u32>(8);\n    drop((tx, rx));\n}\n";
        assert!(diags("coordinator/x.rs", ok).is_empty());
    }

    // ---- L5 ----

    #[test]
    fn l5_trips_on_ungated_collect() {
        let bad = "fn f(source: &mut dyn TraceSource) -> anyhow::Result<Trace> {\n    let t = source.collect()?;\n    Ok(t)\n}\n";
        assert_eq!(rules_of(&diags("run/x.rs", bad)), vec!["L5"]);
    }

    #[test]
    fn l5_gated_collect_passes() {
        let ok = "fn f(policy: &P, source: &mut dyn TraceSource) -> anyhow::Result<Trace> {\n    if policy.needs_offline_trace() {\n        let t = source.collect()?;\n        return Ok(t);\n    }\n    anyhow::bail!(\"streaming\")\n}\n";
        assert!(diags("run/x.rs", ok).is_empty());
        // An iterator collect on a non-stream receiver never trips.
        let iter = "fn g(v: &[u32]) -> Vec<u32> {\n    let out: Vec<u32> = v.iter().copied().collect();\n    out\n}\n";
        assert!(diags("run/x.rs", iter).is_empty());
    }

    // ---- L6 ----

    #[test]
    fn l6_trips_on_bare_recv_and_unsignaled_join() {
        let bad = concat!(
            "fn f(rx: &std::sync::mpsc::Receiver<u32>, h: std::thread::JoinHandle<()>) -> u32 {\n",
            "    let v = match rx.recv() {\n",
            "        Ok(v) => v,\n",
            "        Err(_) => 0,\n",
            "    };\n",
            "    let _ = h.join();\n",
            "    v\n",
            "}\n",
        );
        let ds = diags("coordinator/x.rs", bad);
        assert_eq!(rules_of(&ds), vec!["L6", "L6"], "{ds:?}");
        assert_eq!((ds[0].line, ds[1].line), (2, 6));
        // Same text outside coordinator//serve//elastic/: out of scope.
        assert!(diags("run/x.rs", bad).is_empty());
    }

    #[test]
    fn l6_near_misses_pass() {
        // The mailbox drain loop, recv_timeout, a signaled join, and
        // anything under #[cfg(test)] are all sanctioned.
        let ok = concat!(
            "fn pump(rx: std::sync::mpsc::Receiver<u32>, out: &mut Vec<u32>) {\n",
            "    while let Ok(v) = rx.recv() {\n",
            "        out.push(v);\n",
            "    }\n",
            "}\n",
            "fn stop(tx: std::sync::mpsc::SyncSender<Msg>, h: std::thread::JoinHandle<()>) {\n",
            "    let _ = tx.send(Msg::Shutdown);\n",
            "    let _ = h.join();\n",
            "}\n",
            "fn wait(rx: &std::sync::mpsc::Receiver<u32>) -> Option<u32> {\n",
            "    rx.recv_timeout(std::time::Duration::from_millis(50)).ok()\n",
            "}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t(rx: std::sync::mpsc::Receiver<u32>, h: std::thread::JoinHandle<()>) {\n",
            "        let _ = rx.recv();\n",
            "        let _ = h.join();\n",
            "    }\n",
            "}\n",
        );
        let ds = diags("serve/x.rs", ok);
        assert!(ds.is_empty(), "{ds:?}");
    }

    // ---- allow escape hatch ----

    #[test]
    fn allow_with_justification_suppresses_and_is_counted() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, u32>, out: &mut Vec<u32>) {\n    // akpc-lint: allow(L2) -- order is re-sorted downstream\n    for (k, _) in m {\n        out.push(*k);\n    }\n}\n";
        let (ds, allows) = lint_source("cache/x.rs", src);
        assert!(ds.is_empty(), "{ds:?}");
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].rule, "L2");
        assert_eq!(allows[0].justification, "order is re-sorted downstream");
    }

    #[test]
    fn trailing_allow_form_works() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() // akpc-lint: allow(L3) -- prototype; see #42\n}\n";
        let (ds, allows) = lint_source("coordinator/x.rs", src);
        assert!(ds.is_empty(), "{ds:?}");
        assert_eq!(allows.len(), 1);
    }

    #[test]
    fn allow_without_justification_is_an_error() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // akpc-lint: allow(L3)\n    x.unwrap()\n}\n";
        let ds = diags("coordinator/x.rs", src);
        // The malformed allow is A0 AND the violation still stands.
        assert_eq!(rules_of(&ds), vec!["A0", "L3"], "{ds:?}");
    }

    #[test]
    fn allow_with_unknown_rule_is_an_error() {
        let src = "fn f() {\n    // akpc-lint: allow(L9) -- wishful\n}\n";
        assert_eq!(rules_of(&diags("run/x.rs", src)), vec!["A0"]);
    }

    #[test]
    fn prose_mention_of_the_marker_is_not_a_directive() {
        // Rustdoc that *talks about* the escape hatch (this module's own
        // docs do) must not be diagnosed as a malformed allow.
        let src = "//! Suppress with `akpc-lint: allow(<rule>) -- <why>`.\n//! | `analysis` | akpc-lint: the invariant checker |\nfn f() {}\n";
        let (ds, allows) = lint_source("trace/doc.rs", src);
        assert!(ds.is_empty(), "{ds:?}");
        assert!(allows.is_empty());
    }

    #[test]
    fn allow_for_wrong_rule_does_not_suppress() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // akpc-lint: allow(L4) -- wrong rule\n    x.unwrap()\n}\n";
        assert_eq!(rules_of(&diags("coordinator/x.rs", src)), vec!["L3"]);
    }

    #[test]
    fn report_renders_and_counts() {
        let mut rep = LintReport::default();
        rep.files_scanned = 2;
        assert!(rep.is_clean());
        assert!(rep.render().contains("PASS"));
        rep.diagnostics.push(Diagnostic {
            rule: "L1".into(),
            file: "algo/x.rs".into(),
            line: 3,
            message: "m".into(),
            excerpt: "e".into(),
        });
        assert!(!rep.is_clean());
        let r = rep.render();
        assert!(r.contains("algo/x.rs:3 [L1]") && r.contains("FAIL"));
    }
}
