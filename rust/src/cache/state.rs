//! Distributed cache state: which packed copies live on which ESS, their
//! expiries `E[c][j]`, the global alive-copy counters `G[c]`, and the
//! expiry event loop of Algorithm 6.
//!
//! Copies are keyed by the *content hash* of the packed clique
//! ([`crate::util::clique_key`]), so copies of a clique survive window
//! ticks in which the clique set is regenerated with identical content,
//! and stale packings age out naturally.

use std::cmp::Reverse;
use std::collections::hash_map::Entry;
use std::collections::{BinaryHeap, HashMap, HashSet};
use std::sync::Arc;

use super::board::CopyBoard;

/// Expiry event: `(time, key, server)` with a NaN-safe total order on time
/// (`f64::total_cmp`; a NaN expiry can never be produced by the cost model,
/// but a heap with an inconsistent order would corrupt silently, so the
/// comparator must not pretend NaN equals everything).
#[derive(Debug, Clone, Copy)]
struct ExpEvent {
    time: f64,
    key: u64,
    server: u32,
}

impl PartialEq for ExpEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for ExpEvent {}

impl PartialOrd for ExpEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ExpEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.key.cmp(&other.key))
            .then(self.server.cmp(&other.server))
    }
}

/// One live cache copy in portable form — the unit of elastic handoff
/// (DESIGN.md §13). `export_live` emits these and `import_live` replays
/// them into a fresh state, so a resize moves copies between shards
/// without touching the retention bookkeeping by hand.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CopyRecord {
    /// Content hash of the packed clique ([`crate::util::clique_key`]).
    pub key: u64,
    /// Packed size |c| (retention-rent weight).
    pub size: u32,
    /// ESS holding the copy.
    pub server: u32,
    /// Absolute expiry `E[c][j]`.
    pub expiry: f64,
}

/// Cache bookkeeping across all ESSs for one policy run.
#[derive(Debug)]
pub struct CacheState {
    /// `E[c][j]`: expiry of clique copy `c` on server `j` (absent = not
    /// cached).
    expiry: HashMap<(u64, u32), f64>,
    /// `G[c]`: number of alive copies of clique `c` across all ESSs.
    copies: HashMap<u64, u32>,
    /// Packed size |c| per key (for retention bookkeeping / stats).
    sizes: HashMap<u64, u32>,
    /// Pending expiry events (lazy deletion: stale events are re-checked
    /// against `expiry` when popped).
    events: BinaryHeap<Reverse<ExpEvent>>,
    /// Total forced retentions performed (Alg. 6 line 3) — statistic.
    pub retentions: u64,
    /// Accumulated item·time units of forced retention (size × Δt per
    /// retention event). Algorithm 6 shows no charge, but storage rent is
    /// real (§III-C: "cost paid by the CDN to ESSs for renting storage");
    /// the policy core bills this at μ per unit (DESIGN.md §6).
    pub retained_units: f64,
    /// Cross-shard copy board. `None` (the default) means this state is the
    /// global one and the retention rule uses the local `G[c]`; `Some`
    /// means this state covers only one shard's ESSs and retention defers
    /// to the board's global latest-copy predicate (DESIGN.md §2.3).
    board: Option<Arc<CopyBoard>>,
    /// Sweep clock: the largest `now` ever passed to
    /// [`process_expirations`](Self::process_expirations). Inserts mirror
    /// it to the board as the copy's creation time (callers sweep to `now`
    /// before mutating, so at insert time `clock == now`).
    clock: f64,
}

impl Default for CacheState {
    fn default() -> Self {
        Self {
            expiry: HashMap::new(),
            copies: HashMap::new(),
            sizes: HashMap::new(),
            events: BinaryHeap::new(),
            retentions: 0,
            retained_units: 0.0,
            board: None,
            clock: f64::NEG_INFINITY,
        }
    }
}

impl CacheState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the cross-shard copy board. Must happen before the first
    /// insert, so the board mirrors every copy this state ever tracks.
    pub fn attach_board(&mut self, board: Arc<CopyBoard>) {
        debug_assert!(
            self.expiry.is_empty(),
            "attach_board after inserts would desynchronize the board"
        );
        self.board = Some(board);
    }

    /// Is copy `key` alive on `server` at time `now`?
    #[inline]
    pub fn is_cached(&self, key: u64, server: u32, now: f64) -> bool {
        self.expiry
            .get(&(key, server))
            .is_some_and(|&e| e > now)
    }

    /// Current expiry `E[c][j]`, if the copy exists.
    #[inline]
    pub fn expiry_of(&self, key: u64, server: u32) -> Option<f64> {
        self.expiry.get(&(key, server)).copied()
    }

    /// `G[c]`.
    #[inline]
    pub fn copy_count(&self, key: u64) -> u32 {
        self.copies.get(&key).copied().unwrap_or(0)
    }

    /// Number of live (key, server) entries.
    pub fn live_entries(&self) -> usize {
        self.expiry.len()
    }

    /// Insert a copy on `server` expiring at `expires`
    /// (Algorithm 1 line 5 / Algorithm 5 lines 7-8: `G[c]+=1`).
    ///
    /// Lazy deletion means an expired-but-unswept entry may still sit in
    /// `expiry` — callers that track time themselves (`is_cached` returned
    /// false) legitimately re-insert over it. That case *replaces* the
    /// stale entry in place: `G[c]` already counts this `(key, server)`
    /// copy, so bumping it again would corrupt the counter (and the old
    /// `debug_assert` made the whole situation a crash). A live copy is
    /// never shortened: the stored expiry only moves forward.
    pub fn insert(&mut self, key: u64, size: u32, server: u32, expires: f64) {
        self.sizes.insert(key, size);
        match self.expiry.entry((key, server)) {
            Entry::Occupied(mut stale) => {
                if expires <= *stale.get() {
                    return; // existing (later) expiry wins; event already queued
                }
                *stale.get_mut() = expires;
            }
            Entry::Vacant(slot) => {
                slot.insert(expires);
                *self.copies.entry(key).or_insert(0) += 1;
            }
        }
        if let Some(b) = &self.board {
            // A fresh (or reincarnated) copy: its lifetime starts at the
            // sweep clock, which equals the caller's `now`.
            b.note_insert(key, server, self.clock, expires);
        }
        self.events.push(Reverse(ExpEvent {
            time: expires,
            key,
            server,
        }));
    }

    /// Extend a live copy's expiry to `expires` (Algorithm 5 line 6).
    /// Returns the previous expiry.
    pub fn extend(&mut self, key: u64, server: u32, expires: f64) -> f64 {
        let e = self
            .expiry
            .get_mut(&(key, server))
            .expect("extend of a non-cached copy");
        let prev = *e;
        if expires > prev {
            *e = expires;
            if let Some(b) = &self.board {
                b.note_extend(key, server, expires);
            }
            self.events.push(Reverse(ExpEvent {
                time: expires,
                key,
                server,
            }));
        }
        prev
    }

    /// Process all expiry events up to `now` (Algorithm 6).
    ///
    /// `current_keys` is the key set of `Clique(W)`: the last alive copy of
    /// a *current* clique is retained (its expiry extended by `delta_t`)
    /// instead of dropped, so the packed copy never disappears from every
    /// ESS while it is still being served (Observation 3).
    pub fn process_expirations(
        &mut self,
        now: f64,
        current_keys: &HashSet<u64>,
        delta_t: f64,
    ) {
        if now > self.clock {
            self.clock = now;
        }
        while let Some(&Reverse(ev)) = self.events.peek() {
            if ev.time > now {
                break;
            }
            self.events.pop();
            let Some(&stored) = self.expiry.get(&(ev.key, ev.server)) else {
                continue; // already dropped
            };
            if stored > ev.time {
                continue; // stale event; a newer one is queued
            }
            // The copy genuinely expires now. "Last alive copy" is judged
            // locally via G[c] for the global (unsharded) state, or via the
            // cross-shard board when this state covers one shard only —
            // the two predicates decide identically (see cache/board.rs).
            let last_copy = match &self.board {
                None => self.copy_count(ev.key) == 1,
                Some(b) => b.is_latest(ev.key, ev.server, ev.time),
            };
            if last_copy && current_keys.contains(&ev.key) {
                // Alg. 6 line 3: last copy of a live clique — extend.
                let new_exp = ev.time + delta_t;
                *self.expiry.get_mut(&(ev.key, ev.server)).unwrap() = new_exp;
                if let Some(b) = &self.board {
                    // The same incarnation lives on with a later expiry.
                    b.note_extend(ev.key, ev.server, new_exp);
                }
                self.events.push(Reverse(ExpEvent {
                    time: new_exp,
                    key: ev.key,
                    server: ev.server,
                }));
                self.retentions += 1;
                self.retained_units +=
                    self.sizes.get(&ev.key).copied().unwrap_or(1) as f64 * delta_t;
            } else {
                // Alg. 6 lines 5-6: drop the copy.
                self.expiry.remove(&(ev.key, ev.server));
                match self.copies.get_mut(&ev.key) {
                    Some(c) if *c > 1 => *c -= 1,
                    _ => {
                        self.copies.remove(&ev.key);
                        self.sizes.remove(&ev.key);
                    }
                }
            }
        }
    }

    /// The sweep clock: largest `now` ever swept to (`-∞` before any
    /// sweep). The elastic handoff exports it so the receiving shard
    /// resumes time exactly where the donor stopped.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Export every live copy in a deterministic (key, server) order.
    ///
    /// Callers must sweep to the handoff point first
    /// (`process_expirations(t_end, …)`): after that sweep every entry
    /// in `expiry` is genuinely alive (`E[c][j] > t_end` — the sweep
    /// loop re-processes retention-extended events until they clear
    /// `now`), so the export is exactly the live set and carries no
    /// stale lazy-deletion residue across the resize.
    pub fn export_live(&self) -> Vec<CopyRecord> {
        let mut out: Vec<CopyRecord> = self
            .expiry
            .iter()
            .map(|(&(key, server), &expiry)| CopyRecord {
                key,
                size: self.sizes.get(&key).copied().unwrap_or(1),
                server,
                expiry,
            })
            .collect();
        out.sort_by(|a, b| a.key.cmp(&b.key).then(a.server.cmp(&b.server)));
        out
    }

    /// Seed a *fresh* state (optionally board-attached) from an export.
    ///
    /// Sets the sweep clock to `clock` (the donor's quiesce point) and
    /// replays each record through [`insert`](Self::insert), so `G[c]`,
    /// the expiry heap, sizes, and the board mirror are rebuilt through
    /// the one audited mutation path. Board incarnations restart with
    /// `start = clock`; that is decision-equivalent to the donor's
    /// history because every post-handoff retention decision happens at
    /// an event time strictly greater than `clock` (see `export_live`),
    /// where the `start < at` blocker predicate holds for both the
    /// original and the reseeded start times, and incarnations already
    /// dead at `clock` can never block a later decision.
    pub fn import_live(&mut self, clock: f64, records: &[CopyRecord]) {
        debug_assert!(
            self.expiry.is_empty(),
            "import_live seeds a fresh state only"
        );
        if clock > self.clock {
            self.clock = clock;
        }
        for r in records {
            self.insert(r.key, r.size, r.server, r.expiry);
        }
    }

    /// Consistency check for tests: `G[c]` equals the number of live
    /// `(c, ·)` entries.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut counts: HashMap<u64, u32> = HashMap::new();
        for &(key, _server) in self.expiry.keys() {
            *counts.entry(key).or_insert(0) += 1;
        }
        // akpc-lint: allow(L2) -- order-independent conjunction of per-key checks; test-only helper
        for (key, &g) in &self.copies {
            anyhow::ensure!(
                counts.get(key) == Some(&g),
                "G[{key}]={g} but {} live entries",
                counts.get(key).copied().unwrap_or(0)
            );
        }
        anyhow::ensure!(
            counts.len() == self.copies.len(),
            "live entries without G counter"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(v: &[u64]) -> HashSet<u64> {
        v.iter().copied().collect()
    }

    #[test]
    fn insert_and_query() {
        let mut c = CacheState::new();
        c.insert(7, 3, 0, 1.0);
        assert!(c.is_cached(7, 0, 0.5));
        assert!(!c.is_cached(7, 0, 1.0)); // expiry is exclusive
        assert!(!c.is_cached(7, 1, 0.5)); // other server
        assert_eq!(c.copy_count(7), 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn extend_pushes_expiry() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        let prev = c.extend(7, 0, 1.9);
        assert_eq!(prev, 1.0);
        assert!(c.is_cached(7, 0, 1.5));
        // Old event at t=1.0 must be ignored (stale).
        c.process_expirations(1.0, &keys(&[]), 1.0);
        assert!(c.is_cached(7, 0, 1.5));
        c.check_invariants().unwrap();
    }

    #[test]
    fn expiry_drops_copy_when_not_last() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        c.insert(7, 2, 1, 2.0);
        assert_eq!(c.copy_count(7), 2);
        // Paper's example: expires at s_0 while G=2 -> dropped, G=1.
        c.process_expirations(1.0, &keys(&[7]), 1.0);
        assert!(!c.is_cached(7, 0, 1.0));
        assert_eq!(c.copy_count(7), 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn last_copy_of_current_clique_retained() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        c.process_expirations(1.5, &keys(&[7]), 1.0);
        // Retained and extended to 2.0 (= 1.0 + Δt).
        assert!(c.is_cached(7, 0, 1.9));
        assert_eq!(c.copy_count(7), 1);
        assert_eq!(c.retentions, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn last_copy_of_stale_clique_dropped() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        // 7 is no longer in Clique(W).
        c.process_expirations(1.5, &keys(&[]), 1.0);
        assert!(!c.is_cached(7, 0, 1.2));
        assert_eq!(c.copy_count(7), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn retention_chains_until_clique_retired() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        c.process_expirations(1.0, &keys(&[7]), 1.0); // retained to 2.0
        c.process_expirations(2.0, &keys(&[7]), 1.0); // retained to 3.0
        assert_eq!(c.retentions, 2);
        assert!(c.is_cached(7, 0, 2.5));
        c.process_expirations(3.0, &keys(&[]), 1.0); // retired -> drop
        assert_eq!(c.copy_count(7), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn multi_server_multi_key() {
        let mut c = CacheState::new();
        for s in 0..5u32 {
            c.insert(100, 3, s, 1.0 + s as f64);
        }
        c.insert(200, 1, 0, 10.0);
        c.process_expirations(3.0, &keys(&[100, 200]), 1.0);
        // Servers 0,1,2 expired (times 1,2,3), two copies remain.
        assert_eq!(c.copy_count(100), 2);
        assert_eq!(c.copy_count(200), 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn insert_over_expired_unswept_copy_replaces() {
        // Regression: lazy deletion leaves the (key, server) entry behind
        // after its expiry passes; re-inserting used to trip the
        // debug_assert and double-increment G[c].
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        // Time moves past 1.0 with no sweep in between (no request touched
        // this state), then the copy is re-fetched.
        c.insert(7, 2, 0, 3.0);
        assert_eq!(c.copy_count(7), 1, "G[c] must not double-count");
        c.check_invariants().unwrap();
        // The stale event at t=1.0 is a no-op against the newer expiry.
        c.process_expirations(1.0, &keys(&[]), 1.0);
        assert!(c.is_cached(7, 0, 2.0));
        c.process_expirations(3.0, &keys(&[]), 1.0);
        assert_eq!(c.copy_count(7), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn insert_never_shortens_live_copy() {
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 5.0);
        c.insert(7, 2, 0, 2.0); // stale-looking re-insert with earlier expiry
        assert!(c.is_cached(7, 0, 4.0));
        assert_eq!(c.copy_count(7), 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn board_backed_state_matches_g_rule() {
        use crate::cache::CopyBoard;
        use std::sync::Arc;
        // One global state vs one board-backed state fed the identical
        // sequence: retention decisions must agree event for event.
        let board = Arc::new(CopyBoard::new());
        let mut plain = CacheState::new();
        let mut sharded = CacheState::new();
        sharded.attach_board(board);
        let current = keys(&[7]);
        for c in [&mut plain, &mut sharded] {
            c.insert(7, 2, 0, 1.0);
            c.insert(7, 2, 1, 1.4);
            c.process_expirations(5.0, &current, 1.0);
        }
        assert_eq!(plain.retentions, sharded.retentions);
        assert_eq!(plain.retained_units, sharded.retained_units);
        assert_eq!(plain.copy_count(7), sharded.copy_count(7));
        assert_eq!(plain.expiry_of(7, 1), sharded.expiry_of(7, 1));
    }

    #[test]
    fn export_import_round_trip_preserves_decisions() {
        // Donor: two copies of key 7, one of key 9, swept to t=2.0.
        let mut donor = CacheState::new();
        donor.insert(7, 2, 0, 3.0);
        donor.insert(7, 2, 1, 4.0);
        donor.insert(9, 1, 2, 5.0);
        let current = keys(&[7, 9]);
        donor.process_expirations(2.0, &current, 1.0);
        let records = donor.export_live();
        assert_eq!(records.len(), 3);
        assert_eq!(donor.clock(), 2.0);

        // Receiver: fresh state seeded from the export.
        let mut recv = CacheState::new();
        recv.import_live(donor.clock(), &records);
        assert_eq!(recv.clock(), 2.0);
        assert_eq!(recv.copy_count(7), 2);
        assert_eq!(recv.copy_count(9), 1);
        recv.check_invariants().unwrap();

        // Run both forward: drops and retentions must agree exactly.
        donor.process_expirations(10.0, &current, 1.5);
        recv.process_expirations(10.0, &current, 1.5);
        assert_eq!(donor.copy_count(7), recv.copy_count(7));
        assert_eq!(donor.copy_count(9), recv.copy_count(9));
        assert_eq!(donor.expiry_of(7, 1), recv.expiry_of(7, 1));
        // Counters reset on the receiver — the donor's prefix counters
        // live in the retired metrics epoch, so only deltas must match.
        assert_eq!(donor.retentions, recv.retentions);
    }

    #[test]
    fn import_live_seeds_board_backed_state_equivalently() {
        use crate::cache::CopyBoard;
        use std::sync::Arc;
        // A board-backed receiver seeded at t=1.0 must make the same
        // retention decisions as an unsharded receiver of the export.
        let mut donor = CacheState::new();
        donor.insert(7, 2, 0, 2.0);
        donor.insert(7, 2, 1, 3.0);
        donor.process_expirations(1.0, &keys(&[7]), 1.0);
        let records = donor.export_live();
        let clock = donor.clock();

        let mut plain = CacheState::new();
        plain.import_live(clock, &records);
        let mut sharded = CacheState::new();
        sharded.attach_board(Arc::new(CopyBoard::new()));
        sharded.import_live(clock, &records);
        for c in [&mut plain, &mut sharded] {
            c.process_expirations(6.0, &keys(&[7]), 1.0);
        }
        assert_eq!(plain.retentions, sharded.retentions);
        assert_eq!(plain.copy_count(7), sharded.copy_count(7));
        assert_eq!(plain.expiry_of(7, 1), sharded.expiry_of(7, 1));
    }

    #[test]
    fn observation1_no_copy_outlives_dt_when_g_above_1() {
        // With G>1 no retention happens: every copy dies at its expiry.
        let mut c = CacheState::new();
        c.insert(7, 2, 0, 1.0);
        c.insert(7, 2, 1, 1.4);
        c.process_expirations(5.0, &keys(&[7]), 1.0);
        // Last copy (server 1) was retained at 1.4 (G had dropped to 1).
        assert_eq!(c.copy_count(7), 1);
        assert!(c.expiry_of(7, 1).unwrap() > 1.4);
        assert!(c.expiry_of(7, 0).is_none());
    }
}
