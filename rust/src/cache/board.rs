//! Cross-shard copy board: the shared view of *every* packed-copy lifetime
//! that makes Algorithm 6's retention rule exact when cache state is
//! sharded per ESS group (DESIGN.md §2.3).
//!
//! Algorithm 6 line 3 retains the **globally last** alive copy of a
//! current clique. `G[c]` is the only cross-server coupling in the whole
//! request path — `is_cached` / `extend` / `insert` are all per
//! `(key, server)` — so the sharded coordinator keeps per-shard
//! [`CacheState`](super::CacheState)s for the hot path and routes only the
//! retention decision through this board.
//!
//! ## Why lifetimes, not a shared counter
//!
//! Shards sweep their expiry heaps at their *own* request times, so a
//! naively shared `G[c]` counter would be decremented in sweep order, which
//! differs from the single leader's global time order. The decision the
//! single leader actually makes at a genuine expiry `(t, c, j)` is
//! order-independent once restated structurally:
//!
//! > retain iff no other server holds a copy that was **created before
//! > `t`** and is still alive at `t` — expiry `> t`, or `= t` with a
//! > larger server id (the leader's heap breaks expiry ties by server id,
//! > dropping all but the last).
//!
//! Both bounds of a copy's lifetime matter, because a shard may judge an
//! old event long after it happened (at its next request, a snapshot
//! install, or the shutdown quiesce):
//!
//! * **Creation**: a copy another shard fetched *after* `t` did not exist
//!   when the leader processed the event, so it must not block — each
//!   board entry records the sweep clock at insert time ([`Incarnation`]).
//! * **Expiry**: a copy that died at `e > t` was alive at `t` and must
//!   still block, so dropped incarnations are kept as tombstones rather
//!   than removed. They are pruned once every shard's sweep clock has
//!   passed them ([`CopyBoard::prune`]).
//!
//! ## Elastic resharding (DESIGN.md §13)
//!
//! A resize builds a **fresh** board for the new fleet and replays every
//! live copy through `CacheState::import_live` (which mirrors here via
//! `note_insert` with `start` = the handoff clock `t_end`). No history
//! migrates, and none is needed: post-handoff decisions all happen at
//! event times `> t_end` (every live copy was swept past `t_end` before
//! export), where a seeded incarnation with `start = t_end` blocks
//! exactly when the original — with its true, earlier start — would
//! have (`start < at` holds either way), and incarnations already dead
//! at `t_end` could never block again. That is what keeps the N→M
//! handoff decision-identical to a static-M run from genesis
//! (`tests/elastic.rs` pins it over ~50 seeds).

use std::collections::HashMap;
use std::sync::Mutex;

/// One lifetime `[start, expiry)` of a copy of some clique on one server.
/// A re-fetch after expiry starts a *new* incarnation; extensions (and
/// Algorithm-6 retentions) move `expiry` of the current one forward.
#[derive(Debug, Clone, Copy)]
struct Incarnation {
    server: u32,
    start: f64,
    expiry: f64,
}

/// Shared lifetime view `key -> [incarnations]`.
///
/// All mutation goes through [`CacheState`](super::CacheState) mirrors
/// (`insert` / `extend` / retention), so the board never disagrees with the
/// union of the per-shard states. Entries are small vectors: a clique copy
/// rarely lives on more than a handful of ESSs between prunes.
#[derive(Debug, Default)]
pub struct CopyBoard {
    inner: Mutex<HashMap<u64, Vec<Incarnation>>>,
}

impl CopyBoard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a fresh copy of `key` on `server`: created at sweep-clock
    /// `start`, expiring at `expiry`.
    pub fn note_insert(&self, key: u64, server: u32, start: f64, expiry: f64) {
        let mut map = self.inner.lock().expect("copy board poisoned");
        map.entry(key).or_default().push(Incarnation {
            server,
            start,
            expiry,
        });
    }

    /// Raise the expiry of the *current* (latest-started) incarnation of
    /// `key` on `server`. Expiries never move backwards.
    pub fn note_extend(&self, key: u64, server: u32, expiry: f64) {
        let mut map = self.inner.lock().expect("copy board poisoned");
        let incs = map.entry(key).or_default();
        let mut current: Option<usize> = None;
        for (i, inc) in incs.iter().enumerate() {
            let newer = match current {
                None => true,
                Some(c) => inc.start > incs[c].start,
            };
            if inc.server == server && newer {
                current = Some(i);
            }
        }
        match current {
            Some(i) => {
                if expiry > incs[i].expiry {
                    incs[i].expiry = expiry;
                }
            }
            // Extend without a recorded insert (direct CacheState use):
            // record a conservatively early start so it still blocks.
            None => incs.push(Incarnation {
                server,
                start: f64::NEG_INFINITY,
                expiry,
            }),
        }
    }

    /// The Algorithm-6 retention predicate for a genuine expiry event
    /// `(key, server)` at time `at`: true iff no other server has an
    /// incarnation that was alive at `at` and outlives this copy
    /// (`start < at` and `expiry > at`, ties by server id).
    pub fn is_latest(&self, key: u64, server: u32, at: f64) -> bool {
        let map = self.inner.lock().expect("copy board poisoned");
        match map.get(&key) {
            None => true,
            Some(incs) => !incs.iter().any(|i| {
                i.server != server
                    && i.start < at
                    && (i.expiry > at || (i.expiry == at && i.server > server))
            }),
        }
    }

    /// Drop incarnations whose expiry lies strictly before `watermark` —
    /// safe once `watermark = min` over all shards' sweep clocks, because
    /// every future retention decision happens at an event time
    /// `> watermark` and only incarnations with expiry `>` the event time
    /// can influence it.
    pub fn prune(&self, watermark: f64) {
        if !watermark.is_finite() {
            return;
        }
        let mut map = self.inner.lock().expect("copy board poisoned");
        map.retain(|_, incs| {
            incs.retain(|i| i.expiry >= watermark);
            !incs.is_empty()
        });
    }

    /// Number of tracked incarnations (observability/tests).
    pub fn entries(&self) -> usize {
        self.inner
            .lock()
            .expect("copy board poisoned")
            .values()
            .map(Vec::len)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latest_copy_wins() {
        let b = CopyBoard::new();
        b.note_insert(7, 0, 0.0, 1.0);
        b.note_insert(7, 1, 0.0, 2.0);
        // Server 0 expires at 1.0 while server 1 is alive until 2.0.
        assert!(!b.is_latest(7, 0, 1.0));
        // Server 1 at 2.0: server 0's tombstone (1.0) is dead by then.
        assert!(b.is_latest(7, 1, 2.0));
    }

    #[test]
    fn ties_break_by_server_id() {
        let b = CopyBoard::new();
        b.note_insert(7, 0, 0.0, 1.0);
        b.note_insert(7, 3, 0.0, 1.0);
        assert!(!b.is_latest(7, 0, 1.0), "lower id must defer");
        assert!(b.is_latest(7, 3, 1.0), "highest id is the survivor");
    }

    #[test]
    fn tombstones_block_earlier_decisions() {
        let b = CopyBoard::new();
        b.note_insert(7, 0, 0.0, 1.0);
        b.note_insert(7, 1, 0.0, 2.0);
        // Server 1's copy dies at 2.0 (tombstone stays). A late sweep of
        // server 0's event at t=1.0 must still see it as a blocker.
        assert!(!b.is_latest(7, 0, 1.0));
    }

    #[test]
    fn copies_created_after_the_event_do_not_block() {
        // The time-consistency case: server 0's copy expires at 11.2, a
        // lagging shard decides that event late — after server 2 re-fetched
        // the clique at t=20.5. The leader retained at 11.2 (nothing else
        // was alive *then*), so the board must too.
        let b = CopyBoard::new();
        b.note_insert(7, 0, 10.0, 11.2);
        b.note_insert(7, 2, 20.5, 21.5);
        assert!(b.is_latest(7, 0, 11.2), "future copy must not block");
        // But it does block decisions after its creation.
        assert!(!b.is_latest(7, 0, 21.0));
    }

    #[test]
    fn reincarnation_keeps_old_lifetime_as_blocker() {
        let b = CopyBoard::new();
        // First life [0, 5), re-fetched for a second life [8, 9).
        b.note_insert(7, 1, 0.0, 5.0);
        b.note_insert(7, 1, 8.0, 9.0);
        // Another server's event at t=3: the *first* life was alive.
        assert!(!b.is_latest(7, 0, 3.0));
        // At t=6 neither life covers the event.
        assert!(b.is_latest(7, 0, 6.0));
    }

    #[test]
    fn extend_raises_only_current_incarnation() {
        let b = CopyBoard::new();
        b.note_insert(7, 1, 0.0, 5.0);
        b.note_insert(7, 1, 8.0, 9.0);
        b.note_extend(7, 1, 9.5);
        assert!(!b.is_latest(7, 0, 9.2), "extension must block");
        assert!(b.is_latest(7, 0, 6.0), "old life must stay at 5.0");
        b.note_extend(7, 1, 9.0); // never lowers
        assert!(!b.is_latest(7, 0, 9.2));
    }

    #[test]
    fn prune_respects_watermark() {
        let b = CopyBoard::new();
        b.note_insert(7, 0, 0.0, 1.0);
        b.note_insert(7, 1, 0.0, 10.0);
        b.note_insert(8, 2, 0.0, 0.5);
        b.prune(2.0);
        assert_eq!(b.entries(), 1); // only (7, 1, ..10.0) survives
        b.prune(f64::NEG_INFINITY); // no-op guard
        assert_eq!(b.entries(), 1);
        assert!(b.is_latest(7, 1, 10.0));
    }

    #[test]
    fn unknown_key_is_latest() {
        let b = CopyBoard::new();
        assert!(b.is_latest(42, 0, 1.0));
    }

    #[test]
    fn handoff_seeded_board_decides_like_the_original() {
        // Original board with full history up to the handoff at t=3.0:
        // server 0's life [0,2) is already dead, servers 1 and 2 are
        // live past 3.0.
        let orig = CopyBoard::new();
        orig.note_insert(7, 0, 0.0, 2.0);
        orig.note_insert(7, 1, 0.5, 4.0);
        orig.note_insert(7, 2, 1.0, 5.0);
        // Seeded board: only the live copies, restarted at t_end=3.0
        // (exactly what import_live's insert mirror produces).
        let seeded = CopyBoard::new();
        seeded.note_insert(7, 1, 3.0, 4.0);
        seeded.note_insert(7, 2, 3.0, 5.0);
        // Every post-handoff decision time (> 3.0) agrees.
        for (server, at) in [(1, 4.0), (2, 4.5), (2, 5.0), (1, 3.5)] {
            assert_eq!(
                orig.is_latest(7, server, at),
                seeded.is_latest(7, server, at),
                "divergence at server {server}, t={at}"
            );
        }
    }
}
