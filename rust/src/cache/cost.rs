//! The paper's cost model (§III-C): caching cost `C_P` (Eq. 1-2) and
//! transfer cost `C_T` (Eq. 3-4, Table I), with the Δt = ρ·λ/μ expiry
//! window of Algorithm 6 line 1.

use crate::config::{AkpcConfig, TransferModel};
use crate::util::Json;

/// Immutable cost parameters for one run.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Caching cost per item per unit time (μ).
    pub mu: f64,
    /// Base transfer cost per item (λ).
    pub lambda: f64,
    /// Packed-transfer discount α ∈ [0, 1].
    pub alpha: f64,
    /// Δt = ρ·λ/μ.
    pub delta_t: f64,
    /// Which packed-transfer formula to apply (DESIGN.md §6).
    pub transfer_model: TransferModel,
}

impl CostModel {
    pub fn from_config(cfg: &AkpcConfig) -> Self {
        Self {
            mu: cfg.mu,
            lambda: cfg.lambda,
            alpha: cfg.alpha,
            delta_t: cfg.delta_t(),
            transfer_model: cfg.transfer_model,
        }
    }

    /// Transfer cost of one *packed* group of `size` items (Table I):
    /// `λ` for a singleton, `(1 + (size−1)·α)·λ` for a pack.
    #[inline]
    pub fn transfer_packed(&self, size: u32) -> f64 {
        if size <= 1 {
            self.lambda
        } else {
            match self.transfer_model {
                TransferModel::Eq3 => (1.0 + (size as f64 - 1.0) * self.alpha) * self.lambda,
                // Paper Alg. 5 line 12 literal variant (kept for the
                // ablation; inconsistent with Table I — see DESIGN.md §6).
                TransferModel::Alg5Line12 => self.alpha * self.mu * size as f64,
            }
        }
    }

    /// Transfer cost of `k` items sent individually (Table I, unpacked).
    #[inline]
    pub fn transfer_unpacked(&self, k: u32) -> f64 {
        k as f64 * self.lambda
    }

    /// Caching cost of holding `units` item-slots for `duration` time.
    #[inline]
    pub fn caching(&self, units: u32, duration: f64) -> f64 {
        units as f64 * self.mu * duration.max(0.0)
    }
}

/// Mutable cost/state counters accumulated over a run (Eq. 2, 4, 5 plus
/// operational statistics reported by the harness).
#[derive(Debug, Clone, Default)]
pub struct CostLedger {
    /// Total caching cost C_P.
    pub c_p: f64,
    /// Total transfer cost C_T.
    pub c_t: f64,
    /// Packed-group transfers performed.
    pub transfers: u64,
    /// Requests fully served from local cache.
    pub full_hits: u64,
    /// Requests that triggered at least one transfer.
    pub misses: u64,
    /// Total requests handled.
    pub requests: u64,
    /// Total items delivered (incl. unrequested clique members, Obs. 4).
    pub items_delivered: u64,
    /// Items delivered that were actually requested.
    pub items_requested: u64,
}

impl CostLedger {
    /// Total cost C = C_T + C_P (Eq. 5).
    pub fn total(&self) -> f64 {
        self.c_p + self.c_t
    }

    /// Fold another ledger into this one (cross-shard aggregation: shards
    /// serve disjoint ESS sets, so every counter is purely additive).
    pub fn merge(&mut self, other: &CostLedger) {
        self.c_p += other.c_p;
        self.c_t += other.c_t;
        self.transfers += other.transfers;
        self.full_hits += other.full_hits;
        self.misses += other.misses;
        self.requests += other.requests;
        self.items_delivered += other.items_delivered;
        self.items_requested += other.items_requested;
    }

    /// Counters accumulated since `earlier` was captured (`self` must be a
    /// later snapshot of the same ledger). The phased scenario drivers use
    /// this to attribute costs to individual workload phases
    /// (DESIGN.md §7.3); counters saturate at zero so a stale baseline
    /// cannot underflow.
    pub fn delta_from(&self, earlier: &CostLedger) -> CostLedger {
        CostLedger {
            c_p: (self.c_p - earlier.c_p).max(0.0),
            c_t: (self.c_t - earlier.c_t).max(0.0),
            transfers: self.transfers.saturating_sub(earlier.transfers),
            full_hits: self.full_hits.saturating_sub(earlier.full_hits),
            misses: self.misses.saturating_sub(earlier.misses),
            requests: self.requests.saturating_sub(earlier.requests),
            items_delivered: self.items_delivered.saturating_sub(earlier.items_delivered),
            items_requested: self.items_requested.saturating_sub(earlier.items_requested),
        }
    }

    /// Fraction of delivered items that were requested (packing utility).
    pub fn delivery_efficiency(&self) -> f64 {
        if self.items_delivered == 0 {
            1.0
        } else {
            self.items_requested as f64 / self.items_delivered as f64
        }
    }

    /// Request-level hit rate.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.full_hits as f64 / self.requests as f64
        }
    }

    /// JSON export.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("c_p", Json::Num(self.c_p)),
            ("c_t", Json::Num(self.c_t)),
            ("total", Json::Num(self.total())),
            ("transfers", Json::Num(self.transfers as f64)),
            ("full_hits", Json::Num(self.full_hits as f64)),
            ("misses", Json::Num(self.misses as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("items_delivered", Json::Num(self.items_delivered as f64)),
            ("items_requested", Json::Num(self.items_requested as f64)),
            ("hit_rate", Json::Num(self.hit_rate())),
            (
                "delivery_efficiency",
                Json::Num(self.delivery_efficiency()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(alpha: f64) -> CostModel {
        CostModel {
            mu: 1.0,
            lambda: 1.0,
            alpha,
            delta_t: 1.0,
            transfer_model: TransferModel::Eq3,
        }
    }

    /// Table I rows, λ = μ = Δt = 1.
    #[test]
    fn table1_transfer_costs() {
        let m = model(0.8);
        assert_eq!(m.transfer_packed(1), 1.0); // 1 packed = λ
        assert_eq!(m.transfer_unpacked(1), 1.0); // 1 unpacked = λ
        assert!((m.transfer_packed(2) - 1.8).abs() < 1e-12); // (1+α)λ
        assert_eq!(m.transfer_unpacked(2), 2.0); // 2λ
        let k = 5;
        assert!((m.transfer_packed(k) - (1.0 + 4.0 * 0.8)).abs() < 1e-12);
        assert_eq!(m.transfer_unpacked(k), 5.0);
    }

    #[test]
    fn table1_caching_costs() {
        let m = model(0.8);
        assert_eq!(m.caching(1, 1.0), 1.0); // μ·Δt
        assert_eq!(m.caching(5, 1.0), 5.0); // |D_i|·μ·Δt
        assert_eq!(m.caching(2, 0.5), 1.0);
        assert_eq!(m.caching(2, -1.0), 0.0); // clamped
    }

    #[test]
    fn packed_cheaper_than_unpacked_iff_alpha_below_one() {
        for k in 2..10u32 {
            let m = model(0.8);
            assert!(m.transfer_packed(k) < m.transfer_unpacked(k));
            let m1 = model(1.0);
            assert!((m1.transfer_packed(k) - m1.transfer_unpacked(k)).abs() < 1e-12);
        }
    }

    #[test]
    fn alg5_variant_formula() {
        let m = CostModel {
            transfer_model: TransferModel::Alg5Line12,
            ..model(0.8)
        };
        assert!((m.transfer_packed(5) - 0.8 * 5.0).abs() < 1e-12);
        assert_eq!(m.transfer_packed(1), 1.0); // singleton still λ
    }

    #[test]
    fn ledger_total_and_rates() {
        let mut l = CostLedger::default();
        l.c_p = 2.0;
        l.c_t = 3.0;
        l.requests = 10;
        l.full_hits = 4;
        l.items_delivered = 20;
        l.items_requested = 10;
        assert_eq!(l.total(), 5.0);
        assert_eq!(l.hit_rate(), 0.4);
        assert_eq!(l.delivery_efficiency(), 0.5);
    }

    #[test]
    fn ledger_merge_is_additive() {
        let mut a = CostLedger {
            c_p: 1.0,
            c_t: 2.0,
            transfers: 3,
            full_hits: 1,
            misses: 2,
            requests: 3,
            items_delivered: 10,
            items_requested: 6,
        };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.total(), 6.0);
        assert_eq!(a.requests, 6);
        assert_eq!(a.transfers, 6);
        assert_eq!(a.items_delivered, 20);
    }

    #[test]
    fn ledger_delta_inverts_merge() {
        let base = CostLedger {
            c_p: 1.0,
            c_t: 2.0,
            transfers: 3,
            full_hits: 1,
            misses: 2,
            requests: 3,
            items_delivered: 10,
            items_requested: 6,
        };
        let mut later = base.clone();
        later.merge(&base);
        let d = later.delta_from(&base);
        assert_eq!(d.requests, base.requests);
        assert_eq!(d.transfers, base.transfers);
        assert!((d.total() - base.total()).abs() < 1e-12);
        // Saturation: a stale baseline never underflows the counters,
        // and the float fields clamp at zero too.
        let d = base.delta_from(&later);
        assert_eq!(d.requests, 0);
        assert_eq!(d.items_delivered, 0);
        assert_eq!(d.c_p, 0.0);
        assert_eq!(d.c_t, 0.0);
        assert_eq!(d.total(), 0.0);
    }

    #[test]
    fn ledger_empty_rates() {
        let l = CostLedger::default();
        assert_eq!(l.hit_rate(), 0.0);
        assert_eq!(l.delivery_efficiency(), 1.0);
    }
}
