//! Per-ESS cache state, expiry handling (Algorithm 6) and the cost model
//! (paper §III-C, Table I, Eqs. 1-5).

pub mod board;
pub mod cost;
pub mod state;

pub use board::CopyBoard;
pub use cost::{CostLedger, CostModel};
pub use state::{CacheState, CopyRecord};
