//! `akpc` — CLI launcher for the Adaptive K-PackCache system.
//!
//! ```text
//! akpc <command> [flags]
//!
//! commands:
//!   run          simulate one policy over a trace, print the report;
//!                `--stream` replays through the bounded-memory streaming
//!                engine (DESIGN.md §10) instead of materializing
//!   exp <id>     regenerate a paper table/figure
//!                (table1 fig5 fig6a fig6b fig7a fig7b fig7c fig8a fig8b
//!                 fig8c fig9a fig9b policies elastic adversarial faults all)
//!   scenario     Scenario Lab: phased non-stationary workload replays
//!                (list | suite | <name> | <spec.toml>)
//!   bench        tracked hot-path perf baseline; `--json` writes the
//!                BENCH_*.json payload (EXPERIMENTS.md §Perf schema)
//!   policy       policy registry introspection (list)
//!   gen-trace    write a synthetic Netflix/Spotify-like trace to disk;
//!                `--chunked` streams straight to the chunk-framed v2
//!                binary layout (never holds the trace)
//!   trace-stats  analyze a trace file
//!   serve        live ingest daemon when `--listen` is given (admission,
//!                `/metrics`, hot-reload, graceful drain — DESIGN.md §12);
//!                otherwise the offline sharded-coordinator demo
//!   ingest       stream a trace (file or generated) into a running
//!                `akpc serve --listen` daemon over TCP
//!   lint         akpc-lint: scan src/ for invariant violations
//!                (determinism / panic-freedom / backpressure —
//!                DESIGN.md §11); nonzero exit on any violation
//!   config       show the effective configuration (Table II defaults)
//!
//! flags:
//!   --config <file.toml>      load configuration
//!   --requests <N>            trace length (default 200000)
//!   --engine <native|xla>     CRM engine for AKPC (default xla)
//!   --policy <name>           run/scenario: a registry name — see
//!                             `akpc policy list` (default akpc)
//!   --dataset <netflix|spotify>                          (default netflix)
//!   --trace <file>            run: load a trace file instead
//!   --out <file|dir>          gen-trace: output path (.bin or .csv);
//!                             exp/scenario: JSON report directory
//!   --seed <N>                RNG seed override
//!   --shards <N>              serve/scenario/run: shard actor count
//!   --mode <ordered|parallel> serve/scenario/run: replay scheduling
//!   --scale <F>               scenario: phase-length multiplier; exp
//!                             policies: request-budget multiplier (default 1)
//!   --progress <N>            run/scenario/serve: stderr progress (single-leader:
//!                             every N windows; sharded scenario: per phase;
//!                             sharded trace replay: completion only — DESIGN §8.4)
//!   --jsonl <file>            run/scenario/serve: stream the same events as JSONL
//!   --stream                  run: bounded-memory streaming replay
//!   --root <dir>              lint: source root to scan (default: this
//!                             crate's src/)
//!   --chunked                 gen-trace: write the chunk-framed v2 binary
//!   --chunk <N>               run --stream / gen-trace --chunked / ingest:
//!                             requests per chunk (default 8192)
//!   --listen <addr>           serve: bind the ingest daemon (`:0` = any port)
//!   --http <addr>             serve: bind the /metrics /healthz /drain
//!                             /reload endpoint
//!   --serve-config <file>     serve: TOML daemon config, re-read on reload
//!   --slack <F>               serve: admission reorder window override
//!   --to <addr>               ingest: daemon address to stream into
//!   --binary                  ingest: pipe the trace file's AKPT bytes
//!                             verbatim instead of text frames
//!   --retries <N>             ingest: reconnect attempts after a failure
//!                             (text mode; resume handshake dedups, default 5)
//!   --backoff-ms <N>          ingest: base retry backoff (doubles, jittered)
//!   --checkpoint-dir <dir>    serve: restore from + periodically write
//!                             checkpoints (DESIGN.md §14.5)
//!   --checkpoint-secs <F>     serve: seconds between checkpoints (default 5)
//!   --reply-timeout-ms <N>    serve: stall-detection rendezvous timeout
//!   --inject <spec>           serve: arm a fault before starting —
//!                             <site>:<action>[:<shard>[:<after>]], e.g.
//!                             shard-serve:panic:1:50000 (chaos drills)
//!   --plan <spec>             exp faults: comma-separated fault plan,
//!                             e.g. shard-panic@2:1,ingest-drop@4
//! ```
//!
//! (The offline build has no clap; flag parsing is in-tree. Every
//! subcommand that executes a policy goes through [`akpc::run::RunSpec`].)

use akpc::bench::experiments as exp;
use akpc::bench::scenarios::scenario_suite_names;
use akpc::bench::sweep::{shard_scaling, EngineChoice};
use akpc::config::AkpcConfig;
use akpc::run::{
    generated_source, generated_trace, parse_dataset, Driver, Fanout, JsonlSink, PolicyRegistry,
    ProgressPrinter, RunSpec, StreamInput, Workload,
};
use akpc::scenario::{self, ScenarioSpec};
use akpc::sim::ReplayMode;
use akpc::trace::{generator, io as trace_io, stats, TraceKind};

/// Parsed command line.
struct Cli {
    cmd: String,
    pos: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Cli {
    /// Valueless switches (probed via `flag(..).is_some()`); every other
    /// flag still requires a value and errors without one.
    const BOOL_FLAGS: &'static [&'static str] = &["json", "stream", "chunked", "binary"];

    fn parse(args: Vec<String>) -> anyhow::Result<Self> {
        let mut it = args.into_iter();
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut pos = Vec::new();
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if Self::BOOL_FLAGS.contains(&name) {
                    String::new()
                } else {
                    it.next()
                        .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?
                };
                flags.insert(name.to_string(), val);
            } else {
                pos.push(a);
            }
        }
        Ok(Self { cmd, pos, flags })
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Observer stack from `--progress` / `--jsonl`.
    fn observers(&self) -> anyhow::Result<Fanout> {
        let mut fan = Fanout::new();
        if let Some(n) = self.flag("progress") {
            fan.push(Box::new(ProgressPrinter::new(n.parse()?)));
        }
        if let Some(path) = self.flag("jsonl") {
            fan.push(Box::new(JsonlSink::create(path)?));
        }
        Ok(fan)
    }

    /// `--mode` parsed, with a per-command default.
    fn replay_mode(&self, default: ReplayMode) -> anyhow::Result<ReplayMode> {
        match self.flag("mode") {
            None => Ok(default),
            Some("ordered") => Ok(ReplayMode::Ordered),
            Some("parallel") => Ok(ReplayMode::Parallel),
            Some(m) => anyhow::bail!("unknown replay mode `{m}`"),
        }
    }

    /// `--chunk` parsed, defaulting to the streaming engine's chunk
    /// length.
    fn chunk_len(&self) -> anyhow::Result<usize> {
        match self.flag("chunk") {
            None => Ok(akpc::trace::stream::DEFAULT_CHUNK_LEN),
            Some(s) => {
                let n: usize = s.parse()?;
                anyhow::ensure!(n >= 1, "--chunk must be >= 1");
                Ok(n)
            }
        }
    }
}

fn usage() {
    // The module doc is the manual; print its code block.
    println!(
        "akpc — Adaptive K-PackCache (cost-centric clique-packed CDN caching)\n\n\
         usage: akpc <run|exp|scenario|bench|policy|gen-trace|trace-stats|serve|ingest|lint|config> [flags]\n\n\
         flags: --config <toml> --requests <N> --engine <native|xla> --seed <N> --out <dir>\n\
         \u{20}      --progress <N> --jsonl <file>\n\
         run:       --policy <name>   (see `akpc policy list`)\n\
         \u{20}          --dataset <netflix|spotify> | --trace <file>\n\
         \u{20}          [--shards N [--mode <ordered|parallel>]]\n\
         \u{20}          [--stream [--chunk N]]   (bounded-memory replay)\n\
         exp:       <table1|fig5|fig6a|fig6b|fig7a|fig7b|fig7c|fig8a|fig8b|fig8c|\n\
         \u{20}           fig9a|fig9b|policies|elastic|adversarial|ablations|shards|faults|all>\n\
         \u{20}          faults: [--plan <kind@window[:shard],...>] [--shards N]\n\
         \u{20}          policies: [--scale F]   (request-budget multiplier)\n\
         scenario:  <list|suite|name|spec.toml> [--policy P] [--scale F]\n\
         \u{20}          [--shards N [--mode <ordered|parallel>]] [--out <dir>]\n\
         bench:     [--json] [--scale F] [--out <file>]   (default BENCH_5.json)\n\
         policy:    list   (name + description + capabilities)\n\
         gen-trace: --dataset <netflix|spotify> --out <file.bin|file.csv>\n\
         \u{20}          [--chunked [--chunk N]]   (streamed v2 binary)\n\
         serve:     daemon: --listen <addr> [--http <addr>] [--serve-config <toml>]\n\
         \u{20}          [--slack F] [--shards N] [--policy P] [--engine E]\n\
         \u{20}          [--checkpoint-dir <dir> [--checkpoint-secs F]]\n\
         \u{20}          [--reply-timeout-ms N] [--inject <site>:<action>[:shard[:after]]]\n\
         \u{20}          demo:   --dataset <netflix|spotify> [--requests N] [--shards N]\n\
         \u{20}          [--mode <ordered|parallel>]\n\
         ingest:    --to <addr> [--trace <file> [--binary] | --dataset D --requests N]\n\
         \u{20}          [--retries N] [--backoff-ms N]   (resume handshake dedups)\n\
         lint:      [--root <dir>]   (invariant checker, DESIGN.md §11)"
    );
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::parse(std::env::args().skip(1).collect())?;
    if matches!(cli.cmd.as_str(), "help" | "--help" | "-h") {
        usage();
        return Ok(());
    }

    let mut cfg = match cli.flag("config") {
        Some(p) => AkpcConfig::from_toml_file(p)?,
        None => AkpcConfig::default(),
    };
    if let Some(s) = cli.flag("seed") {
        cfg.seed = s.parse()?;
    }
    cfg.validate()?;

    let n_requests: usize = cli
        .flag("requests")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(200_000);
    let engine = match cli.flag("engine").unwrap_or("xla") {
        "native" => EngineChoice::Native,
        "xla" => EngineChoice::Xla,
        e => anyhow::bail!("unknown engine `{e}`"),
    };
    let kind = parse_dataset(cli.flag("dataset").unwrap_or("netflix"))?;
    let registry = PolicyRegistry::builtin();

    match cli.cmd.as_str() {
        "run" => {
            // `--stream` swaps the materialized workload for the
            // bounded-memory streaming variant (DESIGN.md §10); the
            // rest of the spec — policy, engine, driver — is identical.
            let workload = match (cli.flag("stream").is_some(), cli.flag("trace")) {
                (true, Some(p)) => Workload::Streamed {
                    input: StreamInput::File(p.to_string()),
                    chunk: cli.chunk_len()?,
                },
                (true, None) => Workload::Streamed {
                    input: StreamInput::Generated { kind, n_requests },
                    chunk: cli.chunk_len()?,
                },
                (false, Some(p)) => Workload::TraceFile(p.to_string()),
                (false, None) => Workload::Generated { kind, n_requests },
            };
            let n_shards: usize = cli
                .flag("shards")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(0);
            let mut spec = RunSpec::new()
                .config(cfg.clone())
                .engine(engine)
                .policy(cli.flag("policy").unwrap_or("akpc"))
                .workload(workload);
            if n_shards > 0 {
                spec = spec.sharded(n_shards, cli.replay_mode(ReplayMode::Ordered)?);
            }
            let mut obs = cli.observers()?;
            let outcome = spec.run(&registry, &mut obs)?;
            println!("{}", outcome.row());
            println!("{}", outcome.to_json().to_string_pretty());
        }
        "exp" => {
            let id = cli
                .pos
                .first()
                .ok_or_else(|| anyhow::anyhow!("exp needs an id (or `all`)"))?;
            let opts = exp::ExpOptions {
                n_requests,
                engine,
                seed: cfg.seed,
            };
            let out_dir = cli.flag("out").map(|s| s.to_string());
            if let Some(d) = &out_dir {
                std::fs::create_dir_all(d)?;
            }
            run_experiment(id, &opts, &cfg, out_dir.as_deref(), &cli)?;
        }
        "scenario" => {
            let what = cli
                .pos
                .first()
                .ok_or_else(|| anyhow::anyhow!(
                    "scenario needs <list|suite|name|spec.toml>"
                ))?
                .as_str();
            let scale: f64 = cli
                .flag("scale")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(1.0);
            let out_dir = cli.flag("out").map(|s| s.to_string());
            if let Some(d) = &out_dir {
                std::fs::create_dir_all(d)?;
            }
            run_scenario_cmd(what, &cli, &registry, &cfg, engine, scale, out_dir.as_deref())?;
        }
        "policy" => {
            let sub = cli.pos.first().map(String::as_str).unwrap_or("list");
            anyhow::ensure!(sub == "list", "policy supports only `list` (got `{sub}`)");
            println!("{:<20} {:<16} description", "name", "capabilities");
            for e in registry.iter() {
                println!("{:<20} {:<16} {}", e.name(), e.caps().summary(), e.description());
            }
        }
        "gen-trace" => {
            let out = cli
                .flag("out")
                .ok_or_else(|| anyhow::anyhow!("gen-trace needs --out"))?;
            if cli.flag("chunked").is_some() {
                // Generator → chunk-framed v2 file, one chunk resident:
                // this path writes 10⁸-request traces on a laptop.
                anyhow::ensure!(
                    !out.ends_with(".csv"),
                    "--chunked writes the v2 binary layout; drop the .csv extension"
                );
                let mut source = generated_source(kind, &cfg, n_requests, cli.chunk_len()?)?;
                let written = trace_io::write_binary_chunked_from(&mut source, out)?;
                println!("wrote {written} requests to {out} (chunked v2)");
            } else {
                let trace = generated_trace(kind, &cfg, n_requests)?;
                if out.ends_with(".csv") {
                    trace_io::write_csv(&trace, out)?;
                } else {
                    trace_io::write_binary(&trace, out)?;
                }
                println!("wrote {} requests to {out}", trace.len());
            }
        }
        "trace-stats" => {
            let file = cli
                .pos
                .first()
                .ok_or_else(|| anyhow::anyhow!("trace-stats needs a file"))?;
            let trace = if file.ends_with(".csv") {
                trace_io::read_csv(file)?
            } else {
                trace_io::read_binary(file)?
            };
            println!("{}", stats::analyze(&trace).to_json().to_string_pretty());
        }
        "serve" if cli.flag("listen").is_some() => {
            serve_daemon_cmd(&cli, &cfg, engine)?;
        }
        "serve" => {
            let n = cli
                .flag("requests")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(20_000);
            let n_shards: usize = cli
                .flag("shards")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(1);
            let spec = RunSpec::new()
                .config(cfg.clone())
                .engine(engine)
                .policy("akpc")
                .workload(Workload::Generated {
                    kind,
                    n_requests: n,
                })
                .sharded(n_shards, cli.replay_mode(ReplayMode::Parallel)?);
            let mut obs = cli.observers()?;
            let outcome = spec.run(&registry, &mut obs)?;
            if let Some(m) = &outcome.metrics {
                println!("{}", m.summary());
            }
            println!("{}", outcome.row());
            println!("{}", outcome.to_json().to_string_pretty());
        }
        "bench" => {
            let scale: f64 = cli
                .flag("scale")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(1.0);
            anyhow::ensure!(scale > 0.0, "--scale must be positive");
            let opts = akpc::bench::perf::PerfOptions {
                scale,
                seed: cfg.seed,
                ..Default::default()
            };
            let report = akpc::bench::perf::run_perf(&opts)?;
            report.print();
            if cli.flag("json").is_some() {
                let out = match cli.flag("out") {
                    Some(p) if !p.is_empty() => p.to_string(),
                    _ => "BENCH_5.json".to_string(),
                };
                if let Some(dir) = std::path::Path::new(&out).parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)?;
                    }
                }
                std::fs::write(&out, report.to_json().to_string_pretty())?;
                println!("[wrote {out}]");
            }
        }
        "ingest" => {
            ingest_cmd(&cli, &cfg, kind, n_requests)?;
        }
        "lint" => {
            let default_root = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
            let root = cli.flag("root").unwrap_or(default_root);
            let report = akpc::analysis::lint_tree(std::path::Path::new(root))?;
            print!("{}", report.render());
            anyhow::ensure!(
                report.is_clean(),
                "akpc-lint found {} violation(s)",
                report.diagnostics.len()
            );
        }
        "config" => {
            println!("{}", cfg.to_toml());
        }
        c => {
            usage();
            anyhow::bail!("unknown command `{c}`");
        }
    }
    Ok(())
}

fn run_experiment(
    id: &str,
    opts: &exp::ExpOptions,
    cfg: &AkpcConfig,
    out_dir: Option<&str>,
    cli: &Cli,
) -> anyhow::Result<()> {
    let all = id == "all";
    let mut matched = false;
    // Write an experiment's JSON next to printing it, when --out is given.
    let dump = |name: &str, json: akpc::util::Json| -> anyhow::Result<()> {
        if let Some(d) = out_dir {
            let path = format!("{d}/{name}.json");
            std::fs::write(&path, json.to_string_pretty())?;
            println!("[wrote {path}]");
        }
        Ok(())
    };
    if all || id == "table1" {
        exp::table1(cfg);
        matched = true;
    }
    if all || id == "fig5" {
        let r = exp::fig5(opts, cfg);
        r.print();
        dump("fig5", r.to_json())?;
        matched = true;
    }
    if all || id == "fig6a" {
        let r = exp::fig6a(opts, cfg);
        r.print();
        dump("fig6a", r.to_json())?;
        matched = true;
    }
    if all || id == "fig6b" {
        let r = exp::fig6b(opts, cfg);
        r.print();
        dump("fig6b", r.to_json())?;
        matched = true;
    }
    if all || id == "fig7a" {
        let r = exp::fig7a(opts, cfg);
        r.print();
        dump("fig7a", r.to_json())?;
        matched = true;
    }
    if all || id == "fig7b" {
        let r = exp::fig7b(opts, cfg);
        r.print();
        dump("fig7b", r.to_json())?;
        matched = true;
    }
    if all || id == "fig7c" {
        let r = exp::fig7c(opts, cfg);
        r.print();
        dump("fig7c", r.to_json())?;
        matched = true;
    }
    if all || id == "fig8a" {
        let r = exp::fig8a(opts, cfg);
        r.print();
        dump("fig8a", r.to_json())?;
        matched = true;
    }
    if all || id == "fig8b" {
        let r = exp::fig8b(opts, cfg);
        r.print();
        dump("fig8b", r.to_json())?;
        matched = true;
    }
    if all || id == "fig8c" {
        let r = exp::fig8c(opts, cfg);
        r.print();
        dump("fig8c", r.to_json())?;
        matched = true;
    }
    if all || id == "fig9a" {
        exp::fig9a(opts, cfg).print();
        matched = true;
    }
    if all || id == "fig9b" {
        exp::fig9b(opts, cfg).print();
        matched = true;
    }
    if all || id == "policies" {
        // `--scale` shrinks the request budget (CI smoke runs 0.01).
        let mut popts = *opts;
        if let Some(s) = cli.flag("scale") {
            let f: f64 = s.parse()?;
            anyhow::ensure!(f > 0.0, "--scale must be positive");
            popts.n_requests = ((popts.n_requests as f64 * f) as usize).max(2_000);
        }
        let r = exp::policies(&popts, cfg)?;
        r.print();
        dump("policies", r.to_json())?;
        matched = true;
    }
    if all || id == "ablations" {
        for r in exp::ablations(opts, cfg) {
            r.print();
        }
        matched = true;
    }
    if all || id == "shards" {
        println!("== Serving-path shard scaling (multi-ESS coordinator) ==");
        let trace = generator::netflix_like(
            cfg.n_items,
            cfg.n_servers,
            opts.n_requests.min(50_000),
            opts.seed,
        );
        let rows = shard_scaling(cfg, &trace, &[1, 2, 4, 8], opts.engine)?;
        println!("{:<8}{:>12}{:>14}{:>10}", "shards", "req/s", "total", "p99(us)");
        for r in &rows {
            println!(
                "{:<8}{:>12.0}{:>14.1}{:>10}",
                r.n_shards, r.requests_per_sec, r.total_cost, r.p99_latency_us
            );
        }
        matched = true;
    }
    if all || id == "elastic" {
        // Autoscale sweep: elastic vs always-min vs always-max over the
        // three autoscale scenarios, rental at actual shard-seconds.
        let scale = (opts.n_requests as f64 / 200_000.0).clamp(0.01, 1.0);
        let sweep = akpc::bench::elastic_suite(
            cfg,
            &akpc::bench::AUTOSCALE_SCENARIOS,
            1,
            8,
            opts.engine,
            scale,
        )?;
        sweep.print();
        dump("elastic", sweep.to_json())?;
        matched = true;
    }
    if all || id == "faults" {
        run_faults_exp(opts, cfg, cli)?;
        matched = true;
    }
    if all || id == "adversarial" {
        println!("== Theorem 1/2 — adversarial competitive ratio ==");
        println!("{:<6}{:>14}{:>14}", "S", "measured", "bound");
        for s in 1..=cfg.omega {
            let (m, b) = exp::adversarial_ratio(cfg, s, 100);
            println!("{s:<6}{m:>14.4}{b:>14.4}");
        }
        matched = true;
    }
    anyhow::ensure!(matched, "unknown experiment id: {id}");
    Ok(())
}

/// `akpc exp faults` — supervised fault-recovery drills (DESIGN.md
/// §14): run a trace under fault plans (from `--plan` or seeded random
/// draws), compare each against the never-faulted oracle, and show the
/// gap is exactly the recovery recharge.
fn run_faults_exp(opts: &exp::ExpOptions, cfg: &AkpcConfig, cli: &Cli) -> anyhow::Result<()> {
    use akpc::fault::{run_fault_plan, FaultPlan, FaultRunOptions};

    let n = opts.n_requests.min(20_000);
    let n_shards: usize = cli
        .flag("shards")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(3);
    anyhow::ensure!(n_shards >= 1, "exp faults needs --shards >= 1");
    let trace = generator::netflix_like(cfg.n_items, cfg.n_servers, n, opts.seed);
    let n_windows = (n / cfg.batch_size.max(1)).max(1) as u64;
    let plans: Vec<(String, FaultPlan)> = match cli.flag("plan") {
        Some(spec) => vec![(spec.to_string(), FaultPlan::parse(spec)?)],
        None => (0..4)
            .map(|i| {
                let p = FaultPlan::random(opts.seed + i, 2, n_windows, n_shards);
                (p.spec(), p)
            })
            .collect(),
    };
    let engine = opts.engine.to_engine();

    let oracle = run_fault_plan(
        &FaultRunOptions::new(cfg.clone(), engine, n_shards, FaultPlan::new(Vec::new())),
        &trace.requests,
    )?;
    println!(
        "== Fault drills ({n} requests, {n_shards} shards; oracle total {:.3}) ==",
        oracle.total_cost
    );
    println!(
        "{:<44}{:>12}{:>5}{:>11}{:>7}{:>14}",
        "plan", "total", "rec", "recharge", "dupes", "total-rechg"
    );
    for (spec, plan) in plans {
        let r = run_fault_plan(
            &FaultRunOptions::new(cfg.clone(), engine, n_shards, plan),
            &trace.requests,
        )?;
        println!(
            "{:<44}{:>12.3}{:>5}{:>11.3}{:>7}{:>14.3}",
            spec,
            r.total_cost,
            r.recoveries,
            r.recharges,
            r.duplicates_rejected,
            r.total_cost - r.recharges
        );
    }
    println!("(total - recharge equals the oracle total for shard faults — DESIGN.md §14.2)");
    Ok(())
}

/// `--inject <site>:<action>[:<shard>[:<after>]]` — arm one
/// process-global fault before the daemon starts (chaos drills,
/// DESIGN.md §14.1). `shard` of `-` matches any shard; `after` skips
/// that many matching hits before firing. Example:
/// `shard-serve:panic:1:50000` panics shard 1 on its 50001st serve.
fn arm_injected_fault(spec: &str) -> anyhow::Result<()> {
    use akpc::fault::FaultAction;

    let parts: Vec<&str> = spec.split(':').collect();
    anyhow::ensure!(
        (2..=4).contains(&parts.len()),
        "--inject wants <site>:<action>[:<shard>[:<after>]], got `{spec}`"
    );
    let site: &'static str = match parts[0] {
        "shard-serve" => "shard-serve",
        "checkpoint-write" => "checkpoint-write",
        "ingest-frame" => "ingest-frame",
        other => anyhow::bail!("--inject: unknown site `{other}`"),
    };
    let action = match parts[1] {
        "panic" => FaultAction::Panic,
        "fail" => FaultAction::Fail,
        s => match s.strip_prefix("stall-") {
            Some(ms) => FaultAction::Stall(std::time::Duration::from_millis(ms.parse()?)),
            None => anyhow::bail!("--inject: unknown action `{s}` (panic|fail|stall-<ms>)"),
        },
    };
    let shard = match parts.get(2) {
        None => None,
        Some(&"-") => None,
        Some(s) => Some(s.parse()?),
    };
    let after: u64 = match parts.get(3) {
        None => 0,
        Some(s) => s.parse()?,
    };
    akpc::fault::arm(site, shard, action, after);
    eprintln!("akpc-serve: armed injected fault `{spec}`");
    Ok(())
}

/// `akpc serve --listen <addr>` — the live ingest daemon (DESIGN.md
/// §12). Config resolution: `--serve-config` file if given (also the
/// file `POST /reload` re-reads), else defaults seeded from the global
/// `--config`; explicit CLI flags override either.
fn serve_daemon_cmd(cli: &Cli, cfg: &AkpcConfig, engine: EngineChoice) -> anyhow::Result<()> {
    use akpc::serve::{ServeConfig, ServeDaemon, ServeOptions};

    let mut scfg = match cli.flag("serve-config") {
        Some(p) => ServeConfig::from_toml_file(p)?,
        None => ServeConfig {
            akpc: cfg.clone(),
            ..Default::default()
        },
    };
    if cli.flag("engine").is_some() {
        scfg.engine = engine;
    }
    if let Some(p) = cli.flag("policy") {
        scfg.policy = p.to_string();
    }
    if let Some(s) = cli.flag("shards") {
        scfg.shards = s.parse()?;
    }
    if let Some(s) = cli.flag("slack") {
        scfg.slack = s.parse()?;
    }
    if let Some(s) = cli.flag("chunk") {
        scfg.chunk = s.parse()?;
    }

    if let Some(spec) = cli.flag("inject") {
        arm_injected_fault(spec)?;
    }

    let listen = cli
        .flag("listen")
        .ok_or_else(|| anyhow::anyhow!("serve daemon mode needs --listen <addr>"))?;
    let daemon = ServeDaemon::start(
        scfg,
        ServeOptions {
            listen: listen.to_string(),
            http: cli.flag("http").map(str::to_string),
            config_path: cli.flag("serve-config").map(str::to_string),
            checkpoint_dir: cli.flag("checkpoint-dir").map(str::to_string),
            checkpoint_secs: cli
                .flag("checkpoint-secs")
                .map(str::parse)
                .transpose()?
                .unwrap_or(0.0),
            reply_timeout_ms: cli
                .flag("reply-timeout-ms")
                .map(str::parse)
                .transpose()?
                .unwrap_or(0),
        },
    )?;
    // Parseable ready lines (CI greps the ports out of these).
    println!("akpc-serve: ingest on {}", daemon.ingest_addr());
    if let Some(a) = daemon.http_addr() {
        println!("akpc-serve: http on {a}");
    }
    println!("akpc-serve: ready (drain with SIGTERM or POST /drain)");
    let report = daemon.join()?;
    println!("{}", report.metrics.summary());
    println!(
        "akpc-serve: drained: epochs={} admitted={} rejected_late={} \
         rejected_malformed={} forced_releases={} truncated_chunks={} req/s={:.0} wall={:.1}s",
        report.epochs,
        report.admission.admitted,
        report.admission.rejected_late,
        report.admission.rejected_malformed,
        report.admission.forced_releases,
        report.admission.truncated_chunks,
        report.requests_per_sec,
        report.wall_secs
    );
    println!(
        "akpc-serve: robustness: served={} recoveries={} recharge={:.3} \
         shed={} shed_items={} shed_cost={:.3} checkpoints={} ckpt_failures={}",
        report.metrics.served,
        report.counters.recoveries,
        report.counters.recharge_cost,
        report.counters.shed_requests,
        report.counters.shed_items,
        report.counters.shed_cost,
        report.counters.checkpoints_written,
        report.counters.checkpoint_failures
    );
    Ok(())
}

/// `akpc ingest --to <addr>` — stream a workload into a running daemon.
/// Text mode (the default) goes through the retrying client
/// ([`akpc::serve::ingest`]): resume handshake, bounded reconnects with
/// jittered backoff, exactly-once across daemon restarts.
/// `--binary --trace <file.akpt>` pipes the file's bytes verbatim so
/// the daemon exercises its binary wire path (no retry — the binary
/// protocol has no resume handshake).
fn ingest_cmd(
    cli: &Cli,
    cfg: &AkpcConfig,
    kind: TraceKind,
    n_requests: usize,
) -> anyhow::Result<()> {
    use akpc::serve::{ingest_trace, IngestOptions};
    use akpc::trace::stream::{BinaryStreamSource, CsvStreamSource, TraceSource};

    let to = cli
        .flag("to")
        .ok_or_else(|| anyhow::anyhow!("ingest needs --to <addr>"))?;

    if cli.flag("binary").is_some() {
        let path = cli
            .flag("trace")
            .ok_or_else(|| anyhow::anyhow!("--binary needs --trace <file.akpt>"))?;
        anyhow::ensure!(
            !path.ends_with(".csv"),
            "--binary pipes the AKPT binary layout; `{path}` is CSV"
        );
        let mut stream = std::net::TcpStream::connect(to)
            .map_err(|e| anyhow::anyhow!("connect {to}: {e}"))?;
        let mut f = std::fs::File::open(path)?;
        let n = std::io::copy(&mut f, &mut stream)?;
        stream.shutdown(std::net::Shutdown::Write)?;
        println!("ingest: piped {n} binary bytes from {path} to {to}");
        return Ok(());
    }

    // The retry client needs random access to resume from the daemon's
    // watermark after a reconnect, so the workload is materialized.
    let chunk = cli.chunk_len()?;
    let mut source: Box<dyn TraceSource> = match cli.flag("trace") {
        Some(p) if p.ends_with(".csv") => Box::new(CsvStreamSource::open(p, chunk)?),
        Some(p) => Box::new(BinaryStreamSource::open(p, chunk)?),
        None => Box::new(generated_source(kind, cfg, n_requests, chunk)?),
    };
    let mut requests = Vec::new();
    let mut buf = Vec::new();
    while source.next_chunk(&mut buf)? {
        requests.append(&mut buf);
    }

    let mut opts = IngestOptions::new(to);
    opts.seed = cfg.seed;
    if let Some(r) = cli.flag("retries") {
        opts.retries = r.parse()?;
    }
    if let Some(b) = cli.flag("backoff-ms") {
        opts.backoff_ms = b.parse()?;
    }
    let report = ingest_trace(&requests, &opts)?;
    println!(
        "ingest: sent {} text frames to {to} (skipped {} already-admitted, \
         attempts {}, daemon watermark {})",
        report.sent, report.skipped, report.attempts, report.watermark
    );
    Ok(())
}

/// `akpc scenario <list|suite|name|spec.toml>` — the Scenario Lab CLI,
/// routed through [`RunSpec`] (driver/policy conflicts surface from the
/// registry's capability flags, not hand-rolled checks).
fn run_scenario_cmd(
    what: &str,
    cli: &Cli,
    registry: &PolicyRegistry,
    cfg: &AkpcConfig,
    engine: EngineChoice,
    scale: f64,
    out_dir: Option<&str>,
) -> anyhow::Result<()> {
    match what {
        "list" => {
            println!("built-in scenarios:");
            for name in scenario::builtin_names() {
                println!(
                    "  {name:<18} {}",
                    scenario::describe(name).unwrap_or_default()
                );
            }
            return Ok(());
        }
        "suite" => {
            anyhow::ensure!(
                cli.flag("policy").is_none(),
                "scenario suite always sweeps its fixed policy set; drop --policy"
            );
            let names = scenario::suite_names();
            // The classic SWEEP ladder plus the DESIGN.md §15 extension
            // families, weakest-first down to OPT.
            let policies = [
                "no-packing",
                "packcache",
                "bundle-opt",
                "predictive",
                "akpc",
                "opt",
            ];
            let matrix = scenario_suite_names(cfg, &names, &policies, engine, scale)?;
            matrix.print();
            if let Some(d) = out_dir {
                let path = format!("{d}/scenario_suite.json");
                std::fs::write(&path, matrix.to_json().to_string_pretty())?;
                println!("[wrote {path}]");
            }
            return Ok(());
        }
        _ => {}
    }

    // A built-in name, or a spec file on disk.
    let spec = match scenario::builtin(what) {
        Some(spec) => spec,
        None if what.ends_with(".toml") || std::path::Path::new(what).exists() => {
            ScenarioSpec::from_toml_file(what)?
        }
        None => anyhow::bail!(
            "unknown scenario `{what}` (try `akpc scenario list`, or pass a spec.toml)"
        ),
    };

    let n_shards: usize = cli
        .flag("shards")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(0);
    let driver = if n_shards > 0 {
        Driver::Sharded {
            n_shards,
            mode: cli.replay_mode(ReplayMode::Ordered)?,
        }
    } else {
        Driver::SingleLeader
    };
    let mut rspec = RunSpec::new()
        .config(cfg.clone())
        .engine(engine)
        .policy(cli.flag("policy").unwrap_or("akpc"))
        .scenario(spec, scale)
        .driver(driver);
    if let Some(s) = cli.flag("seed") {
        rspec = rspec.seed(s.parse()?);
    }

    let prepared = rspec.validate(registry)?;
    println!("{}", prepared.describe());
    let mut obs = cli.observers()?;
    let outcome = prepared.run(registry, &mut obs)?;

    print!("{}", outcome.render());
    if let Some(d) = out_dir {
        let path = format!("{d}/scenario_{}.json", outcome.workload);
        std::fs::write(&path, outcome.to_json().to_string_pretty())?;
        println!("[wrote {path}]");
    } else {
        println!("{}", outcome.to_json().to_string_pretty());
    }
    Ok(())
}
