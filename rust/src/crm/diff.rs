//! Edge-level diff between the binary CRMs of consecutive windows —
//! the ΔE input of Algorithm 4 (Adjust Previous Cliques).
//!
//! Both windows expose sorted CSR neighbor rows, so ΔE is a **linear
//! merge**: walk the union of kept items, and for each item the union of
//! its two (sorted) binary-neighbor lists, emitting edges present on one
//! side only. O(k + k' + E + E') time, no edge set is ever materialized —
//! the HashSet-difference implementation this replaces built two full
//! `HashSet<(u32, u32)>`s per window tick.

use super::CrmWindow;

/// Set of changed edges between `CRM_bin(W-1)` and `CRM_bin(W)`.
#[derive(Debug, Clone, Default)]
pub struct EdgeDiff {
    /// Edges present in W-1 but not in W (item-id pairs, u < v, sorted).
    pub removed: Vec<(u32, u32)>,
    /// Edges present in W but not in W-1 (sorted).
    pub added: Vec<(u32, u32)>,
}

impl EdgeDiff {
    pub fn len(&self) -> usize {
        self.removed.len() + self.added.len()
    }

    pub fn is_empty(&self) -> bool {
        self.removed.is_empty() && self.added.is_empty()
    }
}

/// Append every binary edge `(u, v)` with `v > u` of `w`'s row `u` to
/// `out` (ascending — CSR rows are sorted by id).
fn push_upper_row(w: &CrmWindow, u: u32, out: &mut Vec<(u32, u32)>) {
    for (v, _, is_edge) in w.neighbors(u) {
        if is_edge && v > u {
            out.push((u, v));
        }
    }
}

/// Merge the upper (`v > u`) binary-neighbor lists of item `u` in both
/// windows, pushing one-sided edges to the matching output.
fn merge_rows(
    prev: &CrmWindow,
    curr: &CrmWindow,
    u: u32,
    removed: &mut Vec<(u32, u32)>,
    added: &mut Vec<(u32, u32)>,
) {
    let mut p = prev.neighbors(u).filter(|&(v, _, e)| e && v > u);
    let mut c = curr.neighbors(u).filter(|&(v, _, e)| e && v > u);
    let (mut pv, mut cv) = (p.next(), c.next());
    loop {
        match (pv, cv) {
            (Some((a, ..)), Some((b, ..))) => {
                if a == b {
                    pv = p.next();
                    cv = c.next();
                } else if a < b {
                    removed.push((u, a));
                    pv = p.next();
                } else {
                    added.push((u, b));
                    cv = c.next();
                }
            }
            (Some((a, ..)), None) => {
                removed.push((u, a));
                pv = p.next();
            }
            (None, Some((b, ..))) => {
                added.push((u, b));
                cv = c.next();
            }
            (None, None) => break,
        }
    }
}

/// Compute ΔE between two windows. Works on item-id space, so windows with
/// different kept sets compare correctly (an item leaving the kept set
/// removes all its edges). Outputs are sorted `(u, v)` pairs with `u < v`,
/// produced directly by the merge — no set difference, no re-sort.
pub fn diff_windows(prev: &CrmWindow, curr: &CrmWindow) -> EdgeDiff {
    let mut removed = Vec::new();
    let mut added = Vec::new();
    let (pa, ca) = (&prev.active, &curr.active);
    let (mut pi, mut ci) = (0usize, 0usize);
    // Ascending merge of the two kept-item lists: rows ascend, and within
    // a row neighbors ascend, so outputs come out lexicographically sorted.
    while pi < pa.len() || ci < ca.len() {
        let pu = pa.get(pi).copied();
        let cu = ca.get(ci).copied();
        match (pu, cu) {
            (Some(u), Some(v)) if u == v => {
                merge_rows(prev, curr, u, &mut removed, &mut added);
                pi += 1;
                ci += 1;
            }
            (Some(u), Some(v)) if u < v => {
                // Kept only in W-1: all its (upper) edges are removals.
                push_upper_row(prev, u, &mut removed);
                pi += 1;
            }
            (Some(_), Some(v)) => {
                push_upper_row(curr, v, &mut added);
                ci += 1;
            }
            (Some(u), None) => {
                push_upper_row(prev, u, &mut removed);
                pi += 1;
            }
            (None, Some(v)) => {
                push_upper_row(curr, v, &mut added);
                ci += 1;
            }
            (None, None) => unreachable!("loop condition"),
        }
    }
    EdgeDiff { removed, added }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    fn window(pairs: &[(u32, u32)]) -> CrmWindow {
        let reqs: Vec<Request> = pairs.iter().map(|&(a, b)| req(&[a, b])).collect();
        build_native(&reqs, 16, 0.0, 1.0)
    }

    /// Reference diff via edge-set differences (the implementation this
    /// module replaced) — the merge must agree exactly.
    fn diff_reference(prev: &CrmWindow, curr: &CrmWindow) -> EdgeDiff {
        use std::collections::HashSet;
        let p: HashSet<(u32, u32)> = prev.edges().into_iter().collect();
        let c: HashSet<(u32, u32)> = curr.edges().into_iter().collect();
        let mut removed: Vec<(u32, u32)> = p.difference(&c).copied().collect();
        let mut added: Vec<(u32, u32)> = c.difference(&p).copied().collect();
        removed.sort_unstable();
        added.sort_unstable();
        EdgeDiff { removed, added }
    }

    #[test]
    fn no_change() {
        let a = window(&[(0, 1), (2, 3)]);
        let b = window(&[(0, 1), (2, 3)]);
        let d = diff_windows(&a, &b);
        assert!(d.is_empty());
    }

    #[test]
    fn detects_added_and_removed() {
        let a = window(&[(0, 1), (2, 3)]);
        let b = window(&[(0, 1), (4, 5)]);
        let d = diff_windows(&a, &b);
        assert_eq!(d.removed, vec![(2, 3)]);
        assert_eq!(d.added, vec![(4, 5)]);
    }

    #[test]
    fn empty_prev_is_all_added() {
        let a = CrmWindow::default();
        let b = window(&[(0, 1)]);
        let d = diff_windows(&a, &b);
        assert!(d.removed.is_empty());
        assert_eq!(d.added, vec![(0, 1)]);
    }

    #[test]
    fn item_leaving_kept_set_removes_edges() {
        let a = window(&[(0, 1), (0, 2), (1, 2)]);
        // New window where only (5,6) appears: all old edges removed.
        let b = window(&[(5, 6)]);
        let d = diff_windows(&a, &b);
        assert_eq!(d.removed.len(), 3);
        assert_eq!(d.added, vec![(5, 6)]);
    }

    #[test]
    fn merge_matches_set_difference_reference() {
        let cases: &[(&[(u32, u32)], &[(u32, u32)])] = &[
            (&[(0, 1), (1, 2), (2, 3)], &[(1, 2), (3, 4), (0, 5)]),
            (&[(0, 9), (4, 7)], &[]),
            (&[], &[(2, 6), (2, 7), (6, 7)]),
            (&[(0, 1), (0, 2), (0, 3)], &[(0, 2)]),
            (&[(1, 3), (5, 8)], &[(1, 3), (5, 8)]),
        ];
        for (pa, ca) in cases {
            let a = window(pa);
            let b = window(ca);
            let got = diff_windows(&a, &b);
            let want = diff_reference(&a, &b);
            assert_eq!(got.removed, want.removed, "{pa:?} -> {ca:?}");
            assert_eq!(got.added, want.added, "{pa:?} -> {ca:?}");
        }
    }

    #[test]
    fn outputs_sorted() {
        let a = window(&[(0, 1), (2, 9), (3, 4), (0, 7)]);
        let b = window(&[(5, 6), (1, 2), (8, 9)]);
        let d = diff_windows(&a, &b);
        assert!(d.removed.windows(2).all(|w| w[0] < w[1]));
        assert!(d.added.windows(2).all(|w| w[0] < w[1]));
    }
}
