//! Edge-level diff between the binary CRMs of consecutive windows —
//! the ΔE input of Algorithm 4 (Adjust Previous Cliques).

use super::CrmWindow;
use std::collections::HashSet;

/// Set of changed edges between `CRM_bin(W-1)` and `CRM_bin(W)`.
#[derive(Debug, Clone, Default)]
pub struct EdgeDiff {
    /// Edges present in W-1 but not in W (item-id pairs, u < v).
    pub removed: Vec<(u32, u32)>,
    /// Edges present in W but not in W-1.
    pub added: Vec<(u32, u32)>,
}

impl EdgeDiff {
    pub fn len(&self) -> usize {
        self.removed.len() + self.added.len()
    }

    pub fn is_empty(&self) -> bool {
        self.removed.is_empty() && self.added.is_empty()
    }
}

/// Compute ΔE between two windows. Works on item-id space, so windows with
/// different kept sets compare correctly (an item leaving the kept set
/// removes all its edges).
pub fn diff_windows(prev: &CrmWindow, curr: &CrmWindow) -> EdgeDiff {
    let prev_edges: HashSet<(u32, u32)> = prev.edges().into_iter().collect();
    let curr_edges: HashSet<(u32, u32)> = curr.edges().into_iter().collect();

    let mut removed: Vec<(u32, u32)> = prev_edges.difference(&curr_edges).copied().collect();
    let mut added: Vec<(u32, u32)> = curr_edges.difference(&prev_edges).copied().collect();
    removed.sort_unstable();
    added.sort_unstable();
    EdgeDiff { removed, added }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crm::native::build_native;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    fn window(pairs: &[(u32, u32)]) -> CrmWindow {
        let reqs: Vec<Request> = pairs.iter().map(|&(a, b)| req(&[a, b])).collect();
        build_native(&reqs, 16, 0.0, 1.0)
    }

    #[test]
    fn no_change() {
        let a = window(&[(0, 1), (2, 3)]);
        let b = window(&[(0, 1), (2, 3)]);
        let d = diff_windows(&a, &b);
        assert!(d.is_empty());
    }

    #[test]
    fn detects_added_and_removed() {
        let a = window(&[(0, 1), (2, 3)]);
        let b = window(&[(0, 1), (4, 5)]);
        let d = diff_windows(&a, &b);
        assert_eq!(d.removed, vec![(2, 3)]);
        assert_eq!(d.added, vec![(4, 5)]);
    }

    #[test]
    fn empty_prev_is_all_added() {
        let a = CrmWindow::default();
        let b = window(&[(0, 1)]);
        let d = diff_windows(&a, &b);
        assert!(d.removed.is_empty());
        assert_eq!(d.added, vec![(0, 1)]);
    }

    #[test]
    fn item_leaving_kept_set_removes_edges() {
        let a = window(&[(0, 1), (0, 2), (1, 2)]);
        // New window where only (5,6) appears: all old edges removed.
        let b = window(&[(5, 6)]);
        let d = diff_windows(&a, &b);
        assert_eq!(d.removed.len(), 3);
        assert_eq!(d.added, vec![(5, 6)]);
    }
}
