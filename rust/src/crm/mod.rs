//! Correlation-matrix (CRM) construction — Algorithm 2 of the paper.
//!
//! Two interchangeable producers exist for the numeric pipeline
//! (co-occurrence → top-p% filter → min-max normalize → binarize):
//!
//! * the **XLA path** ([`crate::runtime`]): executes the AOT-lowered
//!   JAX/Pallas artifact — the production configuration;
//! * the **native path** ([`native::build_native`]): a pure-Rust
//!   re-implementation used for sizes with no artifact, for tests, and as
//!   the ablation baseline in the §Perf comparison.
//!
//! Both produce a [`CrmWindow`]: a **sparse CSR adjacency** over only the
//! kept (top-p% most frequent) items. Realistic CRMs are overwhelmingly
//! sparse — a window touches O(|W|·d̄²) item pairs, not k² — so the window
//! stores one sorted neighbor list per kept item (co-access weight +
//! binary-edge flag per entry) instead of dense `k×k` matrices. Memory is
//! O(k + E); `edges()`/`edge_count()` are O(E); point probes
//! (`edge`/`weight`) binary-search one row. See DESIGN.md §9.

pub mod diff;
pub mod native;

pub use diff::{diff_windows, EdgeDiff};
pub use native::build_native;

use crate::trace::model::Request;

/// Collapse a window of requests into co-utilization *transactions*:
/// consecutive requests at the same server whose inter-arrival gap is at
/// most `gap` (one user session browsing related content — the paper's
/// co-access premise) are unioned into one multi-hot transaction.
///
/// Both CRM engines consume transactions, so a session that walks a bundle
/// one item per request still registers pairwise co-utilization — exactly
/// the signal Figure 2's timeline describes. Within-request co-access is
/// a transaction of its own chain trivially.
///
/// Item lists are accumulated as borrowed slices and each transaction is
/// sorted + deduplicated exactly once, when its session *closes* — not
/// per incoming request.
pub fn sessionize(window: &[Request], gap: f64) -> Vec<Request> {
    use std::collections::HashMap;

    /// An open session: last arrival, its slot in `out`, and the item
    /// slices collected so far (borrowed from `window` — nothing is
    /// copied until the session closes).
    struct Open<'a> {
        last_t: f64,
        idx: usize,
        parts: Vec<&'a [u32]>,
    }

    fn close(open: Open<'_>, out: &mut [Request]) {
        let mut items: Vec<u32> =
            Vec::with_capacity(open.parts.iter().map(|p| p.len()).sum());
        for p in open.parts {
            items.extend_from_slice(p);
        }
        items.sort_unstable();
        items.dedup();
        out[open.idx].items = items;
    }

    let mut open: HashMap<u32, Open<'_>> = HashMap::new();
    let mut out: Vec<Request> = Vec::new();
    for r in window {
        let continues = matches!(
            open.get(&r.server),
            Some(o) if r.time - o.last_t <= gap
        );
        if continues {
            let o = open.get_mut(&r.server).expect("session just probed");
            o.last_t = r.time;
            o.parts.push(&r.items);
        } else {
            let fresh = Open {
                last_t: r.time,
                idx: out.len(),
                parts: vec![&r.items],
            };
            out.push(Request {
                items: Vec::new(),
                server: r.server,
                time: r.time,
            });
            if let Some(prev) = open.insert(r.server, fresh) {
                close(prev, &mut out);
            }
        }
    }
    // Close the still-open sessions in first-seen order (akpc-lint L2):
    // each close writes a disjoint `out[idx]` slot, but draining the map
    // in hash order would still be the exact iteration hazard the lint
    // bans from decision paths, so the drain is sorted explicitly.
    let mut remaining: Vec<Open<'_>> = open.into_values().collect();
    remaining.sort_unstable_by_key(|o| o.idx);
    for o in remaining {
        close(o, &mut out);
    }
    out
}

/// Producer of per-window CRMs — implemented by the native Rust path
/// ([`NativeCrmBuilder`]) and by the XLA runtime
/// ([`crate::runtime::XlaCrmBuilder`]) executing the AOT artifact.
///
/// Deliberately **not** `Send`: the PJRT client is thread-affine
/// (`Rc`-backed), so the coordinator constructs the builder *on* the
/// leader thread that owns the policy (see [`crate::coordinator`]).
pub trait CrmBuilder {
    /// Build the CRM for one window of requests.
    fn build(
        &mut self,
        window: &[Request],
        n_items: u32,
        theta: f32,
        top_frac: f32,
    ) -> CrmWindow;

    /// Engine name for reports ("native" / "xla").
    fn engine_name(&self) -> &'static str;
}

/// Pure-Rust [`CrmBuilder`].
#[derive(Debug, Default, Clone)]
pub struct NativeCrmBuilder;

impl CrmBuilder for NativeCrmBuilder {
    fn build(
        &mut self,
        window: &[Request],
        n_items: u32,
        theta: f32,
        top_frac: f32,
    ) -> CrmWindow {
        native::build_native(window, n_items, theta, top_frac)
    }

    fn engine_name(&self) -> &'static str {
        "native"
    }
}

/// One directed CSR adjacency entry of [`CrmWindow`]: pre-sorted by
/// `(row, neighbor id)` before assembly.
///
/// `is_edge` is the binarization decision (`norm > θ` in the native path,
/// `bin > 0.5` from the artifact) — kept explicitly so the window does not
/// need to remember θ and the XLA outputs round-trip losslessly.
pub(crate) struct CsrEntry {
    /// Row index into `active` (the *source* item).
    pub row: u32,
    /// Neighbor *item id* (not row index).
    pub id: u32,
    /// Min-max-normalized co-access weight.
    pub w: f32,
    /// Binary CRM membership.
    pub is_edge: bool,
}

/// A normalized, thresholded correlation matrix over the kept item set of
/// one clique-generation window `W`, stored as a CSR adjacency.
///
/// Every nonzero co-access pair appears twice (once per direction); each
/// row's neighbor list is sorted by item id. Pairs that never co-occur are
/// implicit (weight 0, no edge — exact match with the dense zero entries,
/// since θ ∈ [0,1] means `0 > θ` is always false). Memory is O(k + E).
#[derive(Debug, Clone, Default)]
pub struct CrmWindow {
    /// Kept item ids (top-p% most frequent active items), ascending.
    pub active: Vec<u32>,
    /// Dense lookup table `item id → row+1` (0 = absent) — the clique
    /// machinery queries rows per item in tight loops, where a vector
    /// probe beats hashing (§Perf iteration 3). This is also the only
    /// id→row map: the former `index: HashMap` duplicate is gone.
    lut: Vec<u32>,
    /// CSR row offsets, `len == k + 1`.
    row_start: Vec<usize>,
    /// Neighbor item ids, ascending within each row.
    nbr_id: Vec<u32>,
    /// Normalized co-access weight per entry.
    nbr_w: Vec<f32>,
    /// Binary-CRM membership per entry.
    nbr_edge: Vec<bool>,
    /// Undirected binary edge count (precomputed at assembly).
    n_edges: usize,
}

impl CrmWindow {
    /// Number of kept items `k`.
    pub fn k(&self) -> usize {
        self.active.len()
    }

    /// Assemble from the kept set and directed adjacency entries.
    /// `entries` must contain both directions of every pair and no
    /// self-loops; it is sorted here.
    pub(crate) fn from_entries(active: Vec<u32>, mut entries: Vec<CsrEntry>) -> Self {
        entries.sort_unstable_by_key(|e| (e.row, e.id));
        let k = active.len();
        let mut w = Self {
            active,
            lut: Vec::new(),
            row_start: vec![0; k + 1],
            nbr_id: Vec::with_capacity(entries.len()),
            nbr_w: Vec::with_capacity(entries.len()),
            nbr_edge: Vec::with_capacity(entries.len()),
            n_edges: 0,
        };
        for e in &entries {
            w.row_start[e.row as usize + 1] += 1;
        }
        for i in 0..k {
            w.row_start[i + 1] += w.row_start[i];
        }
        let mut n_edges = 0usize;
        for e in entries {
            // Count the u < v direction only, so `edge_count()` equals
            // `edges().len()` even if a caller-supplied full matrix is
            // asymmetric (nothing validates symmetry on `from_full`).
            if e.is_edge && e.id > w.active[e.row as usize] {
                n_edges += 1;
            }
            w.nbr_id.push(e.id);
            w.nbr_w.push(e.w);
            w.nbr_edge.push(e.is_edge);
        }
        w.n_edges = n_edges;
        w.build_lut();
        w
    }

    /// Build the internal item-id lookup table; must be called by every
    /// constructor after `active` is final.
    pub(crate) fn build_lut(&mut self) {
        let cap = self
            .active
            .last()
            .map(|&m| m as usize + 1)
            .unwrap_or(0);
        self.lut = vec![0; cap];
        for (i, &item) in self.active.iter().enumerate() {
            self.lut[item as usize] = i as u32 + 1;
        }
    }

    #[inline]
    fn idx(&self, item: u32) -> Option<usize> {
        match self.lut.get(item as usize) {
            Some(&v) if v > 0 => Some(v as usize - 1),
            _ => None,
        }
    }

    /// Row index of `item` in `active`, if kept (the id→row map).
    #[inline]
    pub fn row_index(&self, item: u32) -> Option<usize> {
        self.idx(item)
    }

    /// Is `item` part of the kept set?
    #[inline]
    pub fn contains(&self, item: u32) -> bool {
        self.idx(item).is_some()
    }

    #[inline]
    fn row_range(&self, row: usize) -> std::ops::Range<usize> {
        self.row_start[row]..self.row_start[row + 1]
    }

    /// Position of the `(u, v)` entry in the CSR arrays, if present.
    #[inline]
    fn entry(&self, u: u32, v: u32) -> Option<usize> {
        let i = self.idx(u)?;
        let r = self.row_range(i);
        self.nbr_id[r.clone()]
            .binary_search(&v)
            .ok()
            .map(|p| r.start + p)
    }

    /// Binary edge between two *item ids* (false if either is not kept).
    #[inline]
    pub fn edge(&self, u: u32, v: u32) -> bool {
        match self.entry(u, v) {
            Some(p) => self.nbr_edge[p],
            None => false,
        }
    }

    /// Normalized co-access weight between two item ids (0 if not kept,
    /// or never co-accessed in the window).
    #[inline]
    pub fn weight(&self, u: u32, v: u32) -> f32 {
        match self.entry(u, v) {
            Some(p) => self.nbr_w[p],
            None => 0.0,
        }
    }

    /// The sorted neighbor-id list of `item`'s CSR row (empty slice if
    /// `item` is not kept). Includes sub-threshold co-access neighbors;
    /// pair with [`neighbors`](Self::neighbors) for weights/flags.
    pub fn neighbor_ids(&self, item: u32) -> &[u32] {
        match self.idx(item) {
            Some(i) => &self.nbr_id[self.row_range(i)],
            None => &[],
        }
    }

    /// Iterate `item`'s CSR row as `(neighbor id, weight, is_edge)`,
    /// ascending by id. Empty if `item` is not kept.
    pub fn neighbors(
        &self,
        item: u32,
    ) -> impl Iterator<Item = (u32, f32, bool)> + '_ {
        let r = match self.idx(item) {
            Some(i) => self.row_range(i),
            None => 0..0,
        };
        r.map(move |p| (self.nbr_id[p], self.nbr_w[p], self.nbr_edge[p]))
    }

    /// All binary edges as item-id pairs `(u, v)` with `u < v`, sorted —
    /// one O(k + E) sweep over the CSR rows.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.n_edges);
        for (i, &u) in self.active.iter().enumerate() {
            for p in self.row_range(i) {
                if self.nbr_edge[p] && self.nbr_id[p] > u {
                    out.push((u, self.nbr_id[p]));
                }
            }
        }
        out
    }

    /// Number of binary edges (precomputed — O(1)).
    pub fn edge_count(&self) -> usize {
        self.n_edges
    }

    /// Build from full `n×n` matrices (the XLA artifact's outputs),
    /// compacting to the kept item set. `keep` mirrors the artifact's
    /// internal top-p% rule: an item is kept iff its row/col participates
    /// in the normalized support, i.e. `freq >= kth` among active items.
    /// Only nonzero entries are materialized — the dense inputs are the
    /// artifact's interchange format, not the resident representation.
    pub fn from_full(
        norm_full: &[f32],
        bin_full: &[f32],
        freq: &[f32],
        n: usize,
        top_frac: f32,
    ) -> Self {
        assert_eq!(norm_full.len(), n * n);
        assert_eq!(bin_full.len(), n * n);
        assert_eq!(freq.len(), n);
        let keep = top_k_keep_mask(freq, top_frac);
        let active: Vec<u32> = (0..n as u32).filter(|&i| keep[i as usize]).collect();
        let mut entries = Vec::new();
        for (ci, &u) in active.iter().enumerate() {
            for &v in &active {
                if u == v {
                    continue;
                }
                let w = norm_full[u as usize * n + v as usize];
                let is_edge = bin_full[u as usize * n + v as usize] > 0.5;
                if w != 0.0 || is_edge {
                    entries.push(CsrEntry {
                        row: ci as u32,
                        id: v,
                        w,
                        is_edge,
                    });
                }
            }
        }
        Self::from_entries(active, entries)
    }
}

/// The top-p% keep rule shared by the native path and `from_full`,
/// mirroring the L2 graph exactly: keep item iff `freq > 0` and
/// `freq >= kth`, where `kth` is the `ceil(top_frac · n_active)`-th largest
/// nonzero frequency (ties at the boundary keep everybody). The threshold
/// is found by O(n) selection (`select_nth_unstable_by`), not a full sort.
pub fn top_k_keep_mask(freq: &[f32], top_frac: f32) -> Vec<bool> {
    let mut nonzero: Vec<f32> = freq.iter().copied().filter(|&f| f > 0.0).collect();
    if nonzero.is_empty() {
        return vec![false; freq.len()];
    }
    let k = ((top_frac as f64 * nonzero.len() as f64).ceil() as usize).max(1);
    let pos = (k - 1).min(nonzero.len() - 1);
    let (_, kth, _) =
        nonzero.select_nth_unstable_by(pos, crate::util::order::desc_f32);
    let kth = *kth;
    freq.iter().map(|&f| f > 0.0 && f >= kth).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_mask_top_fraction() {
        // freqs: item0=10, item1=5, item2=1, item3=0
        let freq = vec![10.0, 5.0, 1.0, 0.0];
        // 3 active, top 34% -> k=ceil(1.02)=2 -> kth=5 -> keep 0,1
        let keep = top_k_keep_mask(&freq, 0.34);
        assert_eq!(keep, vec![true, true, false, false]);
    }

    #[test]
    fn keep_mask_ties_keep_boundary() {
        let freq = vec![5.0, 5.0, 5.0, 1.0];
        // k = ceil(0.25*4)=1, kth=5 -> all three fives kept
        let keep = top_k_keep_mask(&freq, 0.25);
        assert_eq!(keep, vec![true, true, true, false]);
    }

    #[test]
    fn keep_mask_all_zero() {
        assert_eq!(top_k_keep_mask(&[0.0, 0.0], 0.5), vec![false, false]);
    }

    #[test]
    fn keep_mask_full_fraction_keeps_all_active() {
        let freq = vec![1.0, 2.0, 0.0];
        assert_eq!(top_k_keep_mask(&freq, 1.0), vec![true, true, false]);
    }

    #[test]
    fn keep_mask_matches_sort_reference() {
        // The O(n) selection must agree with the original full-sort rule
        // on duplicate-heavy inputs (boundary ties keep everybody).
        let cases: &[&[f32]] = &[
            &[3.0, 3.0, 3.0, 2.0, 2.0, 1.0, 0.0],
            &[1.0; 8],
            &[9.0, 1.0, 1.0, 1.0, 1.0],
            &[0.5, 4.5, 4.5, 0.5, 7.0],
        ];
        for freq in cases {
            for frac in [0.1f32, 0.25, 0.5, 0.75, 1.0] {
                let got = top_k_keep_mask(freq, frac);
                // Reference: full descending sort.
                let n_active = freq.iter().filter(|&&f| f > 0.0).count();
                let k = ((frac as f64 * n_active as f64).ceil() as usize).max(1);
                let mut sorted: Vec<f32> =
                    freq.iter().copied().filter(|&f| f > 0.0).collect();
                sorted.sort_unstable_by(crate::util::order::desc_f32);
                let kth = sorted[(k - 1).min(sorted.len() - 1)];
                let want: Vec<bool> =
                    freq.iter().map(|&f| f > 0.0 && f >= kth).collect();
                assert_eq!(got, want, "freq={freq:?} frac={frac}");
            }
        }
    }

    #[test]
    fn from_full_compacts() {
        // n=3, items 0 and 2 kept (freq), 1 inactive.
        let n = 3;
        let mut norm = vec![0.0f32; 9];
        let mut bin = vec![0.0f32; 9];
        norm[2] = 1.0; // [0][2]
        norm[2 * n] = 1.0; // [2][0]
        bin[2] = 1.0;
        bin[2 * n] = 1.0;
        let freq = vec![4.0, 0.0, 4.0];
        let w = CrmWindow::from_full(&norm, &bin, &freq, n, 1.0);
        assert_eq!(w.active, vec![0, 2]);
        assert!(w.edge(0, 2) && w.edge(2, 0));
        assert!(!w.edge(0, 1));
        assert_eq!(w.weight(0, 2), 1.0);
        assert_eq!(w.edges(), vec![(0, 2)]);
        assert_eq!(w.edge_count(), 1);
        assert_eq!(w.neighbor_ids(0), &[2]);
        assert_eq!(w.neighbor_ids(1), &[] as &[u32]);
        assert_eq!(w.row_index(2), Some(1));
    }

    #[test]
    fn from_full_asymmetric_bin_keeps_count_consistent() {
        // Nothing validates symmetry on `from_full`; if an artifact ever
        // emits a one-directional flag, `edge_count()` must still agree
        // with `edges().len()` (both count the u < v direction).
        let n = 3;
        let mut norm = vec![0.0f32; 9];
        let mut bin = vec![0.0f32; 9];
        norm[2] = 1.0; // [0][2]
        norm[2 * n] = 1.0; // [2][0]
        bin[2] = 1.0; // only the (0,2) direction flagged
        let freq = vec![4.0, 0.0, 4.0];
        let w = CrmWindow::from_full(&norm, &bin, &freq, n, 1.0);
        assert_eq!(w.edge_count(), w.edges().len());
        assert_eq!(w.edges(), vec![(0, 2)]);
    }

    #[test]
    fn csr_rows_sorted_and_symmetric() {
        let reqs: Vec<Request> = vec![
            Request::new(vec![0, 1, 2], 0, 0.0),
            Request::new(vec![1, 2], 0, 0.0),
            Request::new(vec![0, 3], 0, 0.0),
        ];
        let w = build_native(&reqs, 8, 0.2, 1.0);
        for &u in &w.active {
            let ids = w.neighbor_ids(u);
            assert!(ids.windows(2).all(|p| p[0] < p[1]), "row {u} unsorted");
            for (v, wt, e) in w.neighbors(u) {
                assert_ne!(u, v, "self loop");
                assert_eq!(w.weight(v, u), wt, "asymmetric weight ({u},{v})");
                assert_eq!(w.edge(v, u), e, "asymmetric edge ({u},{v})");
            }
        }
        // edge_count agrees with the materialized list.
        assert_eq!(w.edge_count(), w.edges().len());
    }

    /// Reference single-pass sessionizer (the pre-CSR implementation):
    /// clones every request up front, re-sorts at the end.
    fn sessionize_reference(window: &[Request], gap: f64) -> Vec<Request> {
        use std::collections::HashMap;
        let mut open: HashMap<u32, (f64, usize)> = HashMap::new();
        let mut out: Vec<Request> = Vec::new();
        for r in window {
            match open.get(&r.server) {
                Some(&(last_t, idx)) if r.time - last_t <= gap => {
                    let tx = &mut out[idx];
                    tx.items.extend_from_slice(&r.items);
                    open.insert(r.server, (r.time, idx));
                }
                _ => {
                    out.push(r.clone());
                    open.insert(r.server, (r.time, out.len() - 1));
                }
            }
        }
        for tx in out.iter_mut() {
            tx.items.sort_unstable();
            tx.items.dedup();
        }
        out
    }

    #[test]
    fn sessionize_matches_reference_on_gap_heavy_trace() {
        // Gap-heavy: inter-arrivals straddle the gap constantly, so
        // sessions open, close, and interleave across servers.
        let mut reqs = Vec::new();
        let mut t = 0.0f64;
        for i in 0..400u32 {
            t += match i % 5 {
                0 => 0.05, // well within gap
                1 => 0.5,  // exactly at gap boundary
                2 => 0.51, // just past gap
                3 => 3.0,  // far past gap
                _ => 0.49,
            };
            let server = i % 3;
            let items = vec![i % 7, (i * 3 + 1) % 7, (i / 2) % 7];
            reqs.push(Request::new(items, server, t));
        }
        for gap in [0.0, 0.5, 1.0, 10.0] {
            assert_eq!(
                sessionize(&reqs, gap),
                sessionize_reference(&reqs, gap),
                "gap={gap}"
            );
        }
    }

    #[test]
    fn sessionize_empty_and_single() {
        assert!(sessionize(&[], 1.0).is_empty());
        let one = vec![Request::new(vec![3, 1], 0, 5.0)];
        let txs = sessionize(&one, 1.0);
        assert_eq!(txs.len(), 1);
        assert_eq!(txs[0].items, vec![1, 3]);
        assert_eq!(txs[0].time, 5.0);
    }
}
