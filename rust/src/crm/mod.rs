//! Correlation-matrix (CRM) construction — Algorithm 2 of the paper.
//!
//! Two interchangeable producers exist for the numeric pipeline
//! (co-occurrence → top-p% filter → min-max normalize → binarize):
//!
//! * the **XLA path** ([`crate::runtime`]): executes the AOT-lowered
//!   JAX/Pallas artifact — the production configuration;
//! * the **native path** ([`native::build_native`]): a pure-Rust
//!   re-implementation used for sizes with no artifact, for tests, and as
//!   the ablation baseline in the §Perf comparison.
//!
//! Both produce a [`CrmWindow`]: a *compacted* dense matrix over only the
//! kept (top-p% most frequent) items, which is what the clique machinery
//! consumes.

pub mod diff;
pub mod native;

pub use diff::{diff_windows, EdgeDiff};
pub use native::build_native;

use std::collections::HashMap;

use crate::trace::model::Request;

/// Collapse a window of requests into co-utilization *transactions*:
/// consecutive requests at the same server whose inter-arrival gap is at
/// most `gap` (one user session browsing related content — the paper's
/// co-access premise) are unioned into one multi-hot transaction.
///
/// Both CRM engines consume transactions, so a session that walks a bundle
/// one item per request still registers pairwise co-utilization — exactly
/// the signal Figure 2's timeline describes. Within-request co-access is
/// a transaction of its own chain trivially.
pub fn sessionize(window: &[Request], gap: f64) -> Vec<Request> {
    // (last time, index into out) per server.
    let mut open: HashMap<u32, (f64, usize)> = HashMap::new();
    let mut out: Vec<Request> = Vec::new();
    for r in window {
        match open.get(&r.server) {
            Some(&(last_t, idx)) if r.time - last_t <= gap => {
                let tx = &mut out[idx];
                tx.items.extend_from_slice(&r.items);
                open.insert(r.server, (r.time, idx));
            }
            _ => {
                out.push(r.clone());
                open.insert(r.server, (r.time, out.len() - 1));
            }
        }
    }
    for tx in out.iter_mut() {
        tx.items.sort_unstable();
        tx.items.dedup();
    }
    out
}

/// Producer of per-window CRMs — implemented by the native Rust path
/// ([`NativeCrmBuilder`]) and by the XLA runtime
/// ([`crate::runtime::XlaCrmBuilder`]) executing the AOT artifact.
///
/// Deliberately **not** `Send`: the PJRT client is thread-affine
/// (`Rc`-backed), so the coordinator constructs the builder *on* the
/// leader thread that owns the policy (see [`crate::coordinator`]).
pub trait CrmBuilder {
    /// Build the CRM for one window of requests.
    fn build(
        &mut self,
        window: &[Request],
        n_items: u32,
        theta: f32,
        top_frac: f32,
    ) -> CrmWindow;

    /// Engine name for reports ("native" / "xla").
    fn engine_name(&self) -> &'static str;
}

/// Pure-Rust [`CrmBuilder`].
#[derive(Debug, Default, Clone)]
pub struct NativeCrmBuilder;

impl CrmBuilder for NativeCrmBuilder {
    fn build(
        &mut self,
        window: &[Request],
        n_items: u32,
        theta: f32,
        top_frac: f32,
    ) -> CrmWindow {
        native::build_native(window, n_items, theta, top_frac)
    }

    fn engine_name(&self) -> &'static str {
        "native"
    }
}

/// A normalized, thresholded correlation matrix over the kept item set of
/// one clique-generation window `W`.
#[derive(Debug, Clone, Default)]
pub struct CrmWindow {
    /// Kept item ids (top-p% most frequent active items), ascending.
    pub active: Vec<u32>,
    /// item id → index into `active` / matrix rows.
    pub index: HashMap<u32, usize>,
    /// Dense lookup table `item id → index+1` (0 = absent) — the clique
    /// machinery queries edges per item pair in tight loops, where a
    /// vector probe beats hashing (§Perf iteration 3).
    lut: Vec<u32>,
    /// Dense `k×k` min-max-normalized co-access strengths, row-major.
    pub norm: Vec<f32>,
    /// Dense `k×k` binary adjacency (`norm > θ`), row-major.
    pub bin: Vec<bool>,
}

impl CrmWindow {
    /// Number of kept items `k`.
    pub fn k(&self) -> usize {
        self.active.len()
    }

    /// Build the internal item-id lookup table; must be called by every
    /// constructor after `active`/`index` are final.
    pub(crate) fn build_lut(&mut self) {
        let cap = self
            .active
            .last()
            .map(|&m| m as usize + 1)
            .unwrap_or(0);
        self.lut = vec![0; cap];
        for (i, &item) in self.active.iter().enumerate() {
            self.lut[item as usize] = i as u32 + 1;
        }
    }

    #[inline]
    fn idx(&self, item: u32) -> Option<usize> {
        match self.lut.get(item as usize) {
            Some(&v) if v > 0 => Some(v as usize - 1),
            _ => None,
        }
    }

    /// Is `item` part of the kept set?
    #[inline]
    pub fn contains(&self, item: u32) -> bool {
        self.idx(item).is_some()
    }

    /// Binary edge between two *item ids* (false if either is not kept).
    #[inline]
    pub fn edge(&self, u: u32, v: u32) -> bool {
        match (self.idx(u), self.idx(v)) {
            (Some(i), Some(j)) if i != j => self.bin[i * self.k() + j],
            _ => false,
        }
    }

    /// Normalized co-access weight between two item ids (0 if not kept).
    #[inline]
    pub fn weight(&self, u: u32, v: u32) -> f32 {
        match (self.idx(u), self.idx(v)) {
            (Some(i), Some(j)) if i != j => self.norm[i * self.k() + j],
            _ => 0.0,
        }
    }

    /// All binary edges as item-id pairs `(u, v)` with `u < v`.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let k = self.k();
        let mut out = Vec::new();
        for i in 0..k {
            for j in (i + 1)..k {
                if self.bin[i * k + j] {
                    out.push((self.active[i], self.active[j]));
                }
            }
        }
        out
    }

    /// Number of binary edges.
    pub fn edge_count(&self) -> usize {
        let k = self.k();
        let mut c = 0;
        for i in 0..k {
            for j in (i + 1)..k {
                if self.bin[i * k + j] {
                    c += 1;
                }
            }
        }
        c
    }

    /// Build from full `n×n` matrices (the XLA artifact's outputs),
    /// compacting to the kept item set. `keep` mirrors the artifact's
    /// internal top-p% rule: an item is kept iff its row/col participates
    /// in the normalized support, i.e. `freq >= kth` among active items.
    pub fn from_full(
        norm_full: &[f32],
        bin_full: &[f32],
        freq: &[f32],
        n: usize,
        top_frac: f32,
    ) -> Self {
        assert_eq!(norm_full.len(), n * n);
        assert_eq!(bin_full.len(), n * n);
        assert_eq!(freq.len(), n);
        let keep = top_k_keep_mask(freq, top_frac);
        let active: Vec<u32> = (0..n as u32).filter(|&i| keep[i as usize]).collect();
        let k = active.len();
        let mut index = HashMap::with_capacity(k);
        for (ci, &item) in active.iter().enumerate() {
            index.insert(item, ci);
        }
        let mut norm = vec![0.0f32; k * k];
        let mut bin = vec![false; k * k];
        for (ci, &u) in active.iter().enumerate() {
            for (cj, &v) in active.iter().enumerate() {
                norm[ci * k + cj] = norm_full[u as usize * n + v as usize];
                bin[ci * k + cj] = bin_full[u as usize * n + v as usize] > 0.5;
            }
        }
        let mut w = Self {
            active,
            index,
            lut: Vec::new(),
            norm,
            bin,
        };
        w.build_lut();
        w
    }
}

/// The top-p% keep rule shared by the native path and `from_full`,
/// mirroring the L2 graph exactly: keep item iff `freq > 0` and
/// `freq >= kth`, where `kth` is the `ceil(top_frac · n_active)`-th largest
/// nonzero frequency (ties at the boundary keep everybody).
pub fn top_k_keep_mask(freq: &[f32], top_frac: f32) -> Vec<bool> {
    let n_active = freq.iter().filter(|&&f| f > 0.0).count();
    if n_active == 0 {
        return vec![false; freq.len()];
    }
    let k = ((top_frac as f64 * n_active as f64).ceil() as usize).max(1);
    let mut sorted: Vec<f32> = freq.iter().copied().filter(|&f| f > 0.0).collect();
    sorted.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let kth = sorted[(k - 1).min(sorted.len() - 1)];
    freq.iter().map(|&f| f > 0.0 && f >= kth).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_mask_top_fraction() {
        // freqs: item0=10, item1=5, item2=1, item3=0
        let freq = vec![10.0, 5.0, 1.0, 0.0];
        // 3 active, top 34% -> k=ceil(1.02)=2 -> kth=5 -> keep 0,1
        let keep = top_k_keep_mask(&freq, 0.34);
        assert_eq!(keep, vec![true, true, false, false]);
    }

    #[test]
    fn keep_mask_ties_keep_boundary() {
        let freq = vec![5.0, 5.0, 5.0, 1.0];
        // k = ceil(0.25*4)=1, kth=5 -> all three fives kept
        let keep = top_k_keep_mask(&freq, 0.25);
        assert_eq!(keep, vec![true, true, true, false]);
    }

    #[test]
    fn keep_mask_all_zero() {
        assert_eq!(top_k_keep_mask(&[0.0, 0.0], 0.5), vec![false, false]);
    }

    #[test]
    fn keep_mask_full_fraction_keeps_all_active() {
        let freq = vec![1.0, 2.0, 0.0];
        assert_eq!(top_k_keep_mask(&freq, 1.0), vec![true, true, false]);
    }

    #[test]
    fn from_full_compacts() {
        // n=3, items 0 and 2 kept (freq), 1 inactive.
        let n = 3;
        let mut norm = vec![0.0f32; 9];
        let mut bin = vec![0.0f32; 9];
        norm[0 * n + 2] = 1.0;
        norm[2 * n + 0] = 1.0;
        bin[0 * n + 2] = 1.0;
        bin[2 * n + 0] = 1.0;
        let freq = vec![4.0, 0.0, 4.0];
        let w = CrmWindow::from_full(&norm, &bin, &freq, n, 1.0);
        assert_eq!(w.active, vec![0, 2]);
        assert!(w.edge(0, 2) && w.edge(2, 0));
        assert!(!w.edge(0, 1));
        assert_eq!(w.weight(0, 2), 1.0);
        assert_eq!(w.edges(), vec![(0, 2)]);
        assert_eq!(w.edge_count(), 1);
    }
}
