//! Native (pure-Rust) CRM construction — the same pipeline the AOT
//! artifact computes, used when no artifact covers the item-universe size,
//! in tests, and as the §Perf ablation baseline for the XLA path.
//!
//! Semantics must match `python/compile/model.py` bit-for-bit at the
//! *decision* level (same kept set, same binary edges); the integration
//! test `integration_runtime.rs` asserts agreement between the two paths.
//!
//! Co-occurrence is accumulated **sparsely** — one hash bucket per item
//! pair that actually co-occurs — and assembled straight into the CSR
//! window. No `k×k` matrix is ever materialized: memory is O(n + E) and
//! work is O(|W|·d̄² + E log E) (the paper's Algorithm-2 cost plus the
//! per-row sort), which is what makes per-window bundle maintenance cheap
//! relative to serving at CDN catalog scale (DESIGN.md §9).

use super::{top_k_keep_mask, CrmWindow, CsrEntry};
use crate::trace::model::Request;
use std::collections::HashMap;

/// Build a [`CrmWindow`] from the requests of one window.
///
/// `n_items` is the universe size; `theta` the binarization threshold;
/// `top_frac` the kept fraction of active items (Algorithm 2 + §V-A).
pub fn build_native(
    window: &[Request],
    n_items: u32,
    theta: f32,
    top_frac: f32,
) -> CrmWindow {
    // Pass 1: frequencies (diagonal of X^T X).
    let mut freq = vec![0.0f32; n_items as usize];
    for r in window {
        for &d in &r.items {
            freq[d as usize] += 1.0;
        }
    }
    let keep = top_k_keep_mask(&freq, top_frac);
    let active: Vec<u32> = (0..n_items).filter(|&i| keep[i as usize]).collect();
    let k = active.len();
    if k == 0 {
        return CrmWindow::default();
    }
    // id → row map (vector LUT; `active` is ascending so rows are too).
    let cap = *active.last().unwrap() as usize + 1;
    let mut row_of = vec![u32::MAX; cap];
    for (ci, &item) in active.iter().enumerate() {
        row_of[item as usize] = ci as u32;
    }

    // Pass 2: sparse co-occurrence over kept items only — one bucket per
    // pair that co-occurs, keyed `(min_row << 32) | max_row`. The request
    // sets are tiny, so this is O(|W|·d̄²) like the paper, and the bucket
    // count is E, not k².
    let mut raw: HashMap<u64, f32> = HashMap::new();
    let mut kept_buf: Vec<u32> = Vec::with_capacity(8);
    for r in window {
        kept_buf.clear();
        kept_buf.extend(r.items.iter().filter_map(|&d| {
            match row_of.get(d as usize) {
                Some(&row) if row != u32::MAX => Some(row),
                _ => None,
            }
        }));
        for a in 0..kept_buf.len() {
            for b in (a + 1)..kept_buf.len() {
                // Request items are strictly ascending, so rows are too.
                let key = (kept_buf[a] as u64) << 32 | kept_buf[b] as u64;
                *raw.entry(key).or_insert(0.0) += 1.0;
            }
        }
    }

    // Min-max normalize over the off-diagonal support. The minimum is
    // anchored at zero: the raw CRM of any realistic window is dominated
    // by never-co-accessed (zero) pairs, so min = 0 in practice; anchoring
    // avoids the degenerate all-equal-counts window collapsing to zero
    // edges (matches the L2 graph — see python/compile/model.py). Zero
    // pairs stay implicit in the CSR: their normalized weight is 0 and
    // `0 > θ` is false for θ ∈ [0,1], exactly the dense zero entries.
    let lo = 0.0f32;
    let mut hi = f32::NEG_INFINITY;
    for &c in raw.values() {
        hi = hi.max(c);
    }
    if !hi.is_finite() {
        hi = 0.0;
    }
    let span = (hi - lo).max(1e-9);

    let mut entries = Vec::with_capacity(raw.len() * 2);
    // akpc-lint: allow(L2) -- from_entries sorts by (row, id); bucket drain order is immaterial
    for (key, c) in raw {
        let (i, j) = ((key >> 32) as u32, key as u32);
        let v = (c - lo) / span;
        let is_edge = v > theta;
        entries.push(CsrEntry {
            row: i,
            id: active[j as usize],
            w: v,
            is_edge,
        });
        entries.push(CsrEntry {
            row: j,
            id: active[i as usize],
            w: v,
            is_edge,
        });
    }
    CrmWindow::from_entries(active, entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    #[test]
    fn paper_worked_example() {
        // §IV-A-1: r1={d1,d2,d3}, r2={d2,d3}; (d2,d3) counted twice.
        let w = build_native(&[req(&[1, 2, 3]), req(&[2, 3])], 8, 0.4, 1.0);
        assert_eq!(w.active, vec![1, 2, 3]);
        // (2,3) is the max pair -> normalizes to 1.0 -> edge at theta=0.4.
        assert!((w.weight(2, 3) - 1.0).abs() < 1e-6);
        assert!(w.edge(2, 3));
        // (1,2) count 1 -> norm 0.5 (zero-anchored min-max): edge at
        // θ=0.4, no edge at θ=0.6.
        assert!((w.weight(1, 2) - 0.5).abs() < 1e-6);
        assert!(w.edge(1, 2));
        let w6 = build_native(&[req(&[1, 2, 3]), req(&[2, 3])], 8, 0.6, 1.0);
        assert!(!w6.edge(1, 2));
        assert!(w6.edge(2, 3));
    }

    #[test]
    fn empty_window() {
        let w = build_native(&[], 10, 0.2, 0.1);
        assert_eq!(w.k(), 0);
        assert!(w.edges().is_empty());
        assert_eq!(w.edge_count(), 0);
    }

    #[test]
    fn symmetry() {
        let reqs: Vec<Request> = vec![
            req(&[0, 1, 2]),
            req(&[1, 2]),
            req(&[0, 2]),
            req(&[3, 4]),
        ];
        let w = build_native(&reqs, 8, 0.1, 1.0);
        for &u in &w.active {
            for &v in &w.active {
                assert_eq!(w.edge(u, v), w.edge(v, u));
                assert_eq!(w.weight(u, v), w.weight(v, u));
            }
        }
    }

    #[test]
    fn top_frac_drops_rare_items() {
        let mut reqs = vec![];
        for _ in 0..10 {
            reqs.push(req(&[0, 1]));
        }
        reqs.push(req(&[6, 7]));
        let w = build_native(&reqs, 8, 0.0, 0.5);
        // 4 active items, keep top ceil(0.5*4)=2 -> {0,1}.
        assert_eq!(w.active, vec![0, 1]);
        assert!(w.edge(0, 1));
        assert!(!w.edge(6, 7));
    }

    #[test]
    fn threshold_excludes_weak_edges() {
        let mut reqs = vec![];
        for _ in 0..10 {
            reqs.push(req(&[0, 1])); // strong pair
        }
        reqs.push(req(&[0, 2])); // weak pair
        reqs.push(req(&[1, 2])); // weak pair (keeps 2 active in top set)
        let w = build_native(&reqs, 4, 0.5, 1.0);
        assert!(w.edge(0, 1));
        assert!(!w.edge(0, 2));
        // Sub-threshold co-access is still probeable by weight.
        assert!(w.weight(0, 2) > 0.0);
    }

    #[test]
    fn norm_weights_in_unit_interval() {
        let reqs: Vec<Request> = (0..50)
            .map(|i| req(&[(i % 5) as u32, ((i + 1) % 5) as u32]))
            .collect();
        let w = build_native(&reqs, 5, 0.2, 1.0);
        for &u in &w.active {
            for (_, wt, _) in w.neighbors(u) {
                assert!((0.0..=1.0).contains(&wt), "{wt}");
            }
        }
    }

    #[test]
    fn csr_stores_only_cooccurring_pairs() {
        // 6 kept items, but only 2 co-access pairs -> 4 directed entries,
        // not 30: the O(k + E) memory claim, observable through the rows.
        let reqs = vec![req(&[0, 1]), req(&[2, 3]), req(&[4]), req(&[5])];
        let w = build_native(&reqs, 8, 0.0, 1.0);
        assert_eq!(w.k(), 6);
        let stored: usize = w.active.iter().map(|&u| w.neighbor_ids(u).len()).sum();
        assert_eq!(stored, 4);
        assert_eq!(w.edge_count(), 2);
        assert!(w.neighbor_ids(4).is_empty());
    }
}
