//! Native (pure-Rust) CRM construction — the same pipeline the AOT
//! artifact computes, used when no artifact covers the item-universe size,
//! in tests, and as the §Perf ablation baseline for the XLA path.
//!
//! Semantics must match `python/compile/model.py` bit-for-bit at the
//! *decision* level (same kept set, same binary edges); the integration
//! test `integration_runtime.rs` asserts agreement between the two paths.

use super::{top_k_keep_mask, CrmWindow};
use crate::trace::model::Request;
use std::collections::HashMap;

/// Build a [`CrmWindow`] from the requests of one window.
///
/// `n_items` is the universe size; `theta` the binarization threshold;
/// `top_frac` the kept fraction of active items (Algorithm 2 + §V-A).
pub fn build_native(
    window: &[Request],
    n_items: u32,
    theta: f32,
    top_frac: f32,
) -> CrmWindow {
    // Pass 1: frequencies (diagonal of X^T X).
    let mut freq = vec![0.0f32; n_items as usize];
    for r in window {
        for &d in &r.items {
            freq[d as usize] += 1.0;
        }
    }
    let keep = top_k_keep_mask(&freq, top_frac);
    let active: Vec<u32> = (0..n_items).filter(|&i| keep[i as usize]).collect();
    let k = active.len();
    if k == 0 {
        return CrmWindow::default();
    }
    let mut index = HashMap::with_capacity(k);
    for (ci, &item) in active.iter().enumerate() {
        index.insert(item, ci);
    }

    // Pass 2: co-occurrence over kept items only (sparse accumulation —
    // the request sets are tiny, so this is O(|W|·d̄²) like the paper).
    let mut raw = vec![0.0f32; k * k];
    let mut kept_buf: Vec<usize> = Vec::with_capacity(8);
    for r in window {
        kept_buf.clear();
        kept_buf.extend(r.items.iter().filter_map(|d| index.get(d).copied()));
        for a in 0..kept_buf.len() {
            for b in (a + 1)..kept_buf.len() {
                let (i, j) = (kept_buf[a], kept_buf[b]);
                raw[i * k + j] += 1.0;
                raw[j * k + i] += 1.0;
            }
        }
    }

    // Min-max normalize over the off-diagonal support. The minimum is
    // anchored at zero: the raw CRM of any realistic window is dominated
    // by never-co-accessed (zero) pairs, so min = 0 in practice; anchoring
    // avoids the degenerate all-equal-counts window collapsing to zero
    // edges (matches the L2 graph — see python/compile/model.py).
    let lo = 0.0f32;
    let mut hi = f32::NEG_INFINITY;
    for i in 0..k {
        for j in 0..k {
            if i != j {
                hi = hi.max(raw[i * k + j]);
            }
        }
    }
    if !hi.is_finite() {
        hi = 0.0;
    }
    let span = (hi - lo).max(1e-9);

    let mut norm = vec![0.0f32; k * k];
    let mut bin = vec![false; k * k];
    for i in 0..k {
        for j in 0..k {
            if i != j {
                let v = (raw[i * k + j] - lo) / span;
                norm[i * k + j] = v;
                bin[i * k + j] = v > theta;
            }
        }
    }

    let mut w = CrmWindow {
        active,
        index,
        lut: Vec::new(),
        norm,
        bin,
    };
    w.build_lut();
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::model::Request;

    fn req(items: &[u32]) -> Request {
        Request::new(items.to_vec(), 0, 0.0)
    }

    #[test]
    fn paper_worked_example() {
        // §IV-A-1: r1={d1,d2,d3}, r2={d2,d3}; (d2,d3) counted twice.
        let w = build_native(&[req(&[1, 2, 3]), req(&[2, 3])], 8, 0.4, 1.0);
        assert_eq!(w.active, vec![1, 2, 3]);
        // (2,3) is the max pair -> normalizes to 1.0 -> edge at theta=0.4.
        assert!((w.weight(2, 3) - 1.0).abs() < 1e-6);
        assert!(w.edge(2, 3));
        // (1,2) count 1 -> norm 0.5 (zero-anchored min-max): edge at
        // θ=0.4, no edge at θ=0.6.
        assert!((w.weight(1, 2) - 0.5).abs() < 1e-6);
        assert!(w.edge(1, 2));
        let w6 = build_native(&[req(&[1, 2, 3]), req(&[2, 3])], 8, 0.6, 1.0);
        assert!(!w6.edge(1, 2));
        assert!(w6.edge(2, 3));
    }

    #[test]
    fn empty_window() {
        let w = build_native(&[], 10, 0.2, 0.1);
        assert_eq!(w.k(), 0);
        assert!(w.edges().is_empty());
    }

    #[test]
    fn symmetry() {
        let reqs: Vec<Request> = vec![
            req(&[0, 1, 2]),
            req(&[1, 2]),
            req(&[0, 2]),
            req(&[3, 4]),
        ];
        let w = build_native(&reqs, 8, 0.1, 1.0);
        for &u in &w.active {
            for &v in &w.active {
                assert_eq!(w.edge(u, v), w.edge(v, u));
                assert_eq!(w.weight(u, v), w.weight(v, u));
            }
        }
    }

    #[test]
    fn top_frac_drops_rare_items() {
        let mut reqs = vec![];
        for _ in 0..10 {
            reqs.push(req(&[0, 1]));
        }
        reqs.push(req(&[6, 7]));
        let w = build_native(&reqs, 8, 0.0, 0.5);
        // 4 active items, keep top ceil(0.5*4)=2 -> {0,1}.
        assert_eq!(w.active, vec![0, 1]);
        assert!(w.edge(0, 1));
        assert!(!w.edge(6, 7));
    }

    #[test]
    fn threshold_excludes_weak_edges() {
        let mut reqs = vec![];
        for _ in 0..10 {
            reqs.push(req(&[0, 1])); // strong pair
        }
        reqs.push(req(&[0, 2])); // weak pair
        reqs.push(req(&[1, 2])); // weak pair (keeps 2 active in top set)
        let w = build_native(&reqs, 4, 0.5, 1.0);
        assert!(w.edge(0, 1));
        assert!(!w.edge(0, 2));
    }

    #[test]
    fn norm_weights_in_unit_interval() {
        let reqs: Vec<Request> = (0..50)
            .map(|i| req(&[(i % 5) as u32, ((i + 1) % 5) as u32]))
            .collect();
        let w = build_native(&reqs, 5, 0.2, 1.0);
        for &v in &w.norm {
            assert!((0.0..=1.0).contains(&v), "{v}");
        }
    }
}
