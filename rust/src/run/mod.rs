//! The unified public Run API (DESIGN.md §8) — one facade over every way
//! of executing a policy against a workload:
//!
//! * [`registry`] — [`PolicyRegistry`]: the single source of truth
//!   mapping policy names ↔ [`PolicyChoice`] ↔ factory closures, with
//!   capability flags (`supports_sharded`, `needs_offline_trace`) and
//!   `register()` for downstream extension;
//! * [`spec`] — [`RunSpec`]: workload (generated | trace file | compiled
//!   scenario | external CSV | streamed source) × driver (single-leader |
//!   sharded{n_shards, mode}) × policy-by-name × config overrides, with
//!   `validate()` centralizing the effective-config derivation;
//!   [`Workload::Streamed`] covers both `akpc run --stream` and the
//!   serving daemon's live ingest ([`SourceHandle`] is the consume-once
//!   wrapper around an opened stream);
//! * [`outcome`] — [`RunOutcome`]: the one report type (total/transfer/
//!   memory cost, per-phase deltas, per-shard ledgers, wall time) with
//!   shared `row()`/`to_json()`;
//! * [`observe`] — the [`Observer`] trait (`on_window`, `on_phase`,
//!   `on_done`) with [`NullObserver`], a [`ProgressPrinter`], and a
//!   [`JsonlSink`] — the hook live serving and future dashboards attach
//!   to;
//! * [`drive`] — the instrumented driver loops the legacy entry points
//!   (`sim::run`, `scenario::run_phased`, `scenario::run_phased_sharded`)
//!   now shim onto; [`drive_trace`] consumes a streaming
//!   [`TraceSource`](crate::trace::stream::TraceSource), so replays are
//!   bounded-memory end to end (DESIGN.md §10).
//!
//! ```
//! use akpc::config::AkpcConfig;
//! use akpc::run::{PolicyRegistry, RunSpec, Workload};
//! use akpc::trace::generator::TraceKind;
//!
//! let registry = PolicyRegistry::builtin();
//! let cfg = AkpcConfig { n_items: 30, n_servers: 12, ..Default::default() };
//! let spec = RunSpec::new()
//!     .config(cfg)
//!     .workload(Workload::Generated { kind: TraceKind::Netflix, n_requests: 1_000 })
//!     .policy("packcache");
//! let outcome = spec.execute(&registry).unwrap();
//! println!("{}", outcome.row());
//! assert_eq!(outcome.n_shards, 0);
//! ```

pub mod drive;
pub mod observe;
pub mod outcome;
pub mod registry;
pub mod spec;

pub use drive::{drive_phased, drive_phased_sharded, drive_trace};
pub use observe::{
    Fanout, JsonlSink, NullObserver, Observer, PhaseEvent, ProgressPrinter, WindowEvent,
};
pub use outcome::RunOutcome;
pub use registry::{PolicyCaps, PolicyEntry, PolicyFactory, PolicyRegistry};
pub use spec::{
    cell_config, generated_source, generated_trace, parse_dataset, Driver, PreparedRun, RunSpec,
    SourceHandle, StreamInput, Workload, WorkloadData,
};

// The engine/policy selectors live with the sweep machinery; re-export
// them so facade users need only `akpc::run::*`.
pub use crate::bench::sweep::{EngineChoice, PolicyChoice};
