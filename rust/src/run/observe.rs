//! Streaming run observers: hooks the drivers invoke while a run is in
//! flight — the attachment point for live progress, metrics sinks, and
//! (later) dashboards.
//!
//! Delivery guarantees by driver:
//!
//! * single-leader (trace or scenario): [`Observer::on_window`] after
//!   every clique-generation window, [`Observer::on_phase`] at each
//!   scenario phase boundary, [`Observer::on_done`] once;
//! * sharded scenario: `on_phase` + `on_done` (windows tick inside the
//!   coordinator's background worker). The final phase event is emitted
//!   *before* the shutdown quiesce, so its ledger excludes the residual
//!   retention rent that the outcome's last [`PhaseCost`] includes;
//! * sharded trace replay: `on_done` only.

use std::io::Write;

use crate::cache::CostLedger;
use crate::scenario::PhaseCost;
use crate::util::Json;

use super::outcome::RunOutcome;

/// One clique-generation window closed.
#[derive(Debug)]
pub struct WindowEvent<'a> {
    /// 1-based window index.
    pub window: u64,
    /// Requests served so far (cumulative).
    pub requests_done: usize,
    /// Cumulative ledger after the window.
    pub ledger: &'a CostLedger,
}

/// One scenario phase completed.
#[derive(Debug)]
pub struct PhaseEvent<'a> {
    /// 0-based phase index.
    pub index: usize,
    /// The phase's cost delta (not cumulative).
    pub phase: &'a PhaseCost,
}

/// Streaming run observer. All hooks default to no-ops so implementors
/// override only what they need.
pub trait Observer {
    fn on_window(&mut self, _ev: &WindowEvent<'_>) {}
    fn on_phase(&mut self, _ev: &PhaseEvent<'_>) {}
    fn on_done(&mut self, _outcome: &RunOutcome) {}
}

/// The do-nothing observer the legacy shims pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// Prints a progress line to stderr every `every` windows (and at every
/// phase boundary / completion).
#[derive(Debug)]
pub struct ProgressPrinter {
    every: u64,
}

impl ProgressPrinter {
    pub fn new(every: u64) -> Self {
        Self {
            every: every.max(1),
        }
    }
}

impl Observer for ProgressPrinter {
    fn on_window(&mut self, ev: &WindowEvent<'_>) {
        if ev.window % self.every == 0 {
            eprintln!(
                "[window {:>6}] {:>9} requests  total={:>12.1}  hit={:>5.1}%",
                ev.window,
                ev.requests_done,
                ev.ledger.total(),
                ev.ledger.hit_rate() * 100.0,
            );
        }
    }

    fn on_phase(&mut self, ev: &PhaseEvent<'_>) {
        eprintln!(
            "[phase {:>2} `{}`] {} requests  total={:.1}",
            ev.index,
            ev.phase.label,
            ev.phase.n_requests,
            ev.phase.ledger.total(),
        );
    }

    fn on_done(&mut self, outcome: &RunOutcome) {
        eprintln!("[done] {}", outcome.row());
    }
}

/// Writes one JSON object per event to `out` — the JSONL metrics sink
/// (plot pipelines tail it; a dashboard would stream it). Write errors
/// are swallowed (the sink is diagnostics, never the run's critical
/// path); callers that need durability should `flush`/inspect the inner
/// writer via [`JsonlSink::into_inner`].
#[derive(Debug)]
pub struct JsonlSink<W: Write> {
    out: W,
}

impl JsonlSink<std::io::BufWriter<std::fs::File>> {
    /// Create (truncate) a JSONL file sink.
    pub fn create(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        Ok(Self::new(std::io::BufWriter::new(std::fs::File::create(
            path.as_ref(),
        )?)))
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(out: W) -> Self {
        Self { out }
    }

    /// Flush and hand back the inner writer (tests inspect buffers).
    pub fn into_inner(mut self) -> W {
        let _ = self.out.flush();
        self.out
    }

    fn emit(&mut self, line: Json) {
        let _ = writeln!(self.out, "{}", line.to_string());
    }
}

impl<W: Write> Observer for JsonlSink<W> {
    fn on_window(&mut self, ev: &WindowEvent<'_>) {
        self.emit(Json::obj(vec![
            ("event", Json::Str("window".to_string())),
            ("window", Json::Num(ev.window as f64)),
            ("requests_done", Json::Num(ev.requests_done as f64)),
            ("ledger", ev.ledger.to_json()),
        ]));
    }

    fn on_phase(&mut self, ev: &PhaseEvent<'_>) {
        self.emit(Json::obj(vec![
            ("event", Json::Str("phase".to_string())),
            ("index", Json::Num(ev.index as f64)),
            ("phase", ev.phase.to_json()),
        ]));
    }

    fn on_done(&mut self, outcome: &RunOutcome) {
        self.emit(Json::obj(vec![
            ("event", Json::Str("done".to_string())),
            ("outcome", outcome.to_json()),
        ]));
        let _ = self.out.flush();
    }
}

/// Broadcasts every event to a list of observers (the CLI composes
/// `--progress` and `--jsonl` with it).
#[derive(Default)]
pub struct Fanout {
    observers: Vec<Box<dyn Observer>>,
}

impl Fanout {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, obs: Box<dyn Observer>) {
        self.observers.push(obs);
    }

    pub fn is_empty(&self) -> bool {
        self.observers.is_empty()
    }
}

impl Observer for Fanout {
    fn on_window(&mut self, ev: &WindowEvent<'_>) {
        for o in &mut self.observers {
            o.on_window(ev);
        }
    }

    fn on_phase(&mut self, ev: &PhaseEvent<'_>) {
        for o in &mut self.observers {
            o.on_phase(ev);
        }
    }

    fn on_done(&mut self, outcome: &RunOutcome) {
        for o in &mut self.observers {
            o.on_done(outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_sink_emits_parseable_lines() {
        let mut sink = JsonlSink::new(Vec::new());
        let ledger = CostLedger::default();
        sink.on_window(&WindowEvent {
            window: 1,
            requests_done: 200,
            ledger: &ledger,
        });
        sink.on_phase(&PhaseEvent {
            index: 0,
            phase: &PhaseCost {
                label: "warm".to_string(),
                n_requests: 200,
                t_start: 0.0,
                t_end: 1.0,
                ledger: ledger.clone(),
            },
        });
        let buf = sink.into_inner();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = crate::util::json::parse(line).unwrap();
            assert!(v.get("event").is_some(), "{line}");
        }
    }

    #[test]
    fn fanout_broadcasts() {
        struct Counter(std::rc::Rc<std::cell::Cell<u64>>);
        impl Observer for Counter {
            fn on_window(&mut self, _ev: &WindowEvent<'_>) {
                self.0.set(self.0.get() + 1);
            }
        }
        let n = std::rc::Rc::new(std::cell::Cell::new(0));
        let mut fan = Fanout::new();
        assert!(fan.is_empty());
        fan.push(Box::new(Counter(n.clone())));
        fan.push(Box::new(Counter(n.clone())));
        let ledger = CostLedger::default();
        fan.on_window(&WindowEvent {
            window: 1,
            requests_done: 10,
            ledger: &ledger,
        });
        assert_eq!(n.get(), 2);
    }
}
