//! The policy registry — the single source of truth mapping policy names
//! ↔ [`PolicyChoice`] ↔ factory closures.
//!
//! Every driver (CLI subcommands, the experiment sweeps, the scenario
//! suite, the [`RunSpec`](super::RunSpec) facade) constructs policies
//! here; adding a policy means adding **one** entry instead of editing
//! four `match` blocks. Entries carry capability flags so driver/policy
//! conflicts (e.g. `--shards` with an offline baseline) become a lookup,
//! not a hand-rolled `ensure!` at each call site.

use crate::algo::{AdaptiveK, Akpc, CachePolicy, DpGreedy, NoPacking, Opt, PackCache2};
use crate::bench::sweep::{EngineChoice, PolicyChoice};
use crate::config::AkpcConfig;
use crate::policy::{BundleOpt, Predictive};

/// What a policy can do — consulted by
/// [`RunSpec::validate`](super::RunSpec::validate) before any work
/// starts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PolicyCaps {
    /// The sharded online coordinator can run this policy (today: AKPC
    /// only — the coordinator *is* the AKPC serving path, DESIGN.md §2.3).
    pub supports_sharded: bool,
    /// `prepare` must see the full trace up front (clairvoyant/offline
    /// policies; meaningless in a live serving deployment).
    pub needs_offline_trace: bool,
    /// The elastic replay driver can resize this policy's coordinator
    /// mid-run with exact state handoff (DESIGN.md §13). Implies
    /// `supports_sharded` — the handoff is a coordinator operation.
    pub supports_elastic: bool,
}

impl PolicyCaps {
    /// Compact rendering for `akpc policy list`.
    pub fn summary(&self) -> String {
        let mut parts = vec![if self.needs_offline_trace {
            "offline-trace"
        } else {
            "online"
        }];
        if self.supports_sharded {
            parts.push("sharded");
        }
        if self.supports_elastic {
            parts.push("elastic");
        }
        parts.join("+")
    }
}

/// Factory closure: config × engine → boxed policy.
pub type PolicyFactory =
    Box<dyn Fn(&AkpcConfig, EngineChoice) -> Box<dyn CachePolicy> + Send + Sync>;

/// One registered policy.
pub struct PolicyEntry {
    name: String,
    description: String,
    caps: PolicyCaps,
    choice: Option<PolicyChoice>,
    factory: PolicyFactory,
}

impl PolicyEntry {
    /// A downstream (non-builtin) entry; it has no [`PolicyChoice`]
    /// mapping, so experiment sweeps won't pick it up, but `RunSpec`,
    /// `build`, and the CLI resolve it by name like any builtin.
    pub fn new(
        name: impl Into<String>,
        description: impl Into<String>,
        caps: PolicyCaps,
        factory: PolicyFactory,
    ) -> Self {
        Self {
            name: name.into(),
            description: description.into(),
            caps,
            choice: None,
            factory,
        }
    }

    fn builtin(
        choice: PolicyChoice,
        description: &str,
        caps: PolicyCaps,
        factory: PolicyFactory,
    ) -> Self {
        Self {
            name: choice.cli_name().to_string(),
            description: description.to_string(),
            caps,
            choice: Some(choice),
            factory,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn description(&self) -> &str {
        &self.description
    }

    pub fn caps(&self) -> &PolicyCaps {
        &self.caps
    }

    /// The sweep-enum identity of a builtin entry (None for registered
    /// extensions).
    pub fn choice(&self) -> Option<PolicyChoice> {
        self.choice
    }

    /// Instantiate the policy.
    pub fn build(&self, cfg: &AkpcConfig, engine: EngineChoice) -> Box<dyn CachePolicy> {
        (self.factory)(cfg, engine)
    }
}

impl std::fmt::Debug for PolicyEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PolicyEntry")
            .field("name", &self.name)
            .field("caps", &self.caps)
            .field("choice", &self.choice)
            .finish()
    }
}

/// Name-keyed policy store. [`PolicyRegistry::builtin`] covers the
/// paper's full evaluation set; `register` extends it downstream.
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    /// An empty registry (downstream embedders that want full control).
    pub fn empty() -> Self {
        Self {
            entries: Vec::new(),
        }
    }

    /// The built-in set: every policy the paper evaluates plus the
    /// adaptive-ω controller. Names are the CLI names
    /// ([`PolicyChoice::cli_name`] keeps the bijection in one place).
    pub fn builtin() -> Self {
        let online = PolicyCaps::default();
        let offline = PolicyCaps {
            needs_offline_trace: true,
            ..PolicyCaps::default()
        };
        let mut reg = Self::empty();
        let entries = vec![
            PolicyEntry::builtin(
                PolicyChoice::NoPacking,
                "independent per-item caching, online (Wang et al.)",
                online,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(NoPacking::new(cfg))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::PackCache,
                "pairwise packing, online (PackCache, Wu et al.)",
                online,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(PackCache2::new(cfg))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::DpGreedy,
                "pairwise packing from the full offline trace (Huang et al.)",
                offline,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(DpGreedy::new(cfg))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::Akpc,
                "Adaptive K-PackCache (proposed): K-cliques with CS + ACM",
                PolicyCaps {
                    supports_sharded: true,
                    supports_elastic: true,
                    ..PolicyCaps::default()
                },
                Box::new(|cfg: &AkpcConfig, engine: EngineChoice| -> Box<dyn CachePolicy> {
                    Box::new(Akpc::with_builder(
                        cfg,
                        engine.to_engine().builder(&cfg.artifacts_dir),
                    ))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::AkpcNoAcm,
                "AKPC ablation without approximate clique merging (Fig. 9a)",
                online,
                Box::new(|cfg: &AkpcConfig, engine: EngineChoice| -> Box<dyn CachePolicy> {
                    Box::new(Akpc::with_builder(
                        &cfg.without_acm(),
                        engine.to_engine().builder(&cfg.artifacts_dir),
                    ))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::AkpcNoCsNoAcm,
                "AKPC ablation without clique splitting or merging (Fig. 5/7/9)",
                online,
                Box::new(|cfg: &AkpcConfig, engine: EngineChoice| -> Box<dyn CachePolicy> {
                    Box::new(Akpc::with_builder(
                        &cfg.without_cs_acm(),
                        engine.to_engine().builder(&cfg.artifacts_dir),
                    ))
                }),
            ),
            PolicyEntry::new(
                "akpc-adaptive-k",
                "AKPC with the adaptive-ω epoch controller (future-work item i)",
                online,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(AdaptiveK::new(cfg))
                }),
            ),
            PolicyEntry::new(
                "predictive",
                "EWMA co-access forecast packs cliques ahead of the access (Choi et al.)",
                online,
                Box::new(|cfg: &AkpcConfig, engine: EngineChoice| -> Box<dyn CachePolicy> {
                    Box::new(Predictive::with_builder(
                        cfg,
                        engine.to_engine().builder(&cfg.artifacts_dir),
                    ))
                }),
            ),
            PolicyEntry::new(
                "bundle-opt",
                "online file-bundle caching baseline (Qin & Etesami)",
                online,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(BundleOpt::new(cfg))
                }),
            ),
            PolicyEntry::builtin(
                PolicyChoice::Opt,
                "clairvoyant per-request optimal packing (lower bound)",
                offline,
                Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                    Box::new(Opt::new(cfg))
                }),
            ),
        ];
        for e in entries {
            reg.register(e).expect("builtin names are unique");
        }
        reg
    }

    /// Add a policy; rejects duplicate names.
    pub fn register(&mut self, entry: PolicyEntry) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.get(entry.name()).is_none(),
            "policy `{}` is already registered",
            entry.name()
        );
        self.entries.push(entry);
        Ok(())
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    /// All entries (for `akpc policy list`).
    pub fn iter(&self) -> impl Iterator<Item = &PolicyEntry> {
        self.entries.iter()
    }

    pub fn get(&self, name: &str) -> Option<&PolicyEntry> {
        self.entries.iter().find(|e| e.name() == name)
    }

    /// Lookup that enumerates the valid names on failure — the CLI's
    /// unknown-policy error.
    pub fn resolve(&self, name: &str) -> anyhow::Result<&PolicyEntry> {
        self.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown policy `{name}` (valid: {})",
                self.names().join(", ")
            )
        })
    }

    /// Build a policy by name.
    pub fn build(
        &self,
        name: &str,
        cfg: &AkpcConfig,
        engine: EngineChoice,
    ) -> anyhow::Result<Box<dyn CachePolicy>> {
        Ok(self.resolve(name)?.build(cfg, engine))
    }

    /// Build a policy from its sweep-enum identity. Panics if `choice`
    /// has no entry — impossible on a registry containing the builtin
    /// set, which is the only way sweeps obtain one.
    pub fn build_choice(
        &self,
        choice: PolicyChoice,
        cfg: &AkpcConfig,
        engine: EngineChoice,
    ) -> Box<dyn CachePolicy> {
        self.entries
            .iter()
            .find(|e| e.choice == Some(choice))
            .unwrap_or_else(|| panic!("no registry entry for {choice:?}"))
            .build(cfg, engine)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_names_are_unique_and_cover_choices() {
        let reg = PolicyRegistry::builtin();
        let names = reg.names();
        let set: std::collections::HashSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len(), "duplicate names: {names:?}");
        for &c in PolicyChoice::FIG5.iter().chain(PolicyChoice::SWEEP) {
            assert!(
                reg.get(c.cli_name()).is_some(),
                "{c:?} ({}) missing from registry",
                c.cli_name()
            );
        }
    }

    #[test]
    fn every_builtin_builds_a_named_policy() {
        let reg = PolicyRegistry::builtin();
        let cfg = AkpcConfig::default();
        for e in reg.iter() {
            let p = e.build(&cfg, EngineChoice::Native);
            assert!(!p.name().is_empty(), "{} built a nameless policy", e.name());
            assert!(!e.description().is_empty());
        }
    }

    #[test]
    fn resolve_enumerates_valid_names() {
        let reg = PolicyRegistry::builtin();
        let err = reg.resolve("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown policy `bogus`"), "{err}");
        assert!(err.contains("akpc") && err.contains("no-packing"), "{err}");
    }

    #[test]
    fn register_extends_and_rejects_duplicates() {
        let mut reg = PolicyRegistry::builtin();
        reg.register(PolicyEntry::new(
            "my-policy",
            "downstream extension",
            PolicyCaps::default(),
            Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                Box::new(NoPacking::new(cfg))
            }),
        ))
        .unwrap();
        assert!(reg.get("my-policy").is_some());
        assert!(reg
            .build("my-policy", &AkpcConfig::default(), EngineChoice::Native)
            .is_ok());
        let dup = reg.register(PolicyEntry::new(
            "akpc",
            "clash",
            PolicyCaps::default(),
            Box::new(|cfg: &AkpcConfig, _| -> Box<dyn CachePolicy> {
                Box::new(NoPacking::new(cfg))
            }),
        ));
        assert!(dup.is_err());
    }

    #[test]
    fn registry_caps_agree_with_policy_instances() {
        // `PolicyCaps::needs_offline_trace` (the registry's static flag)
        // and `CachePolicy::needs_offline_trace` (what the streaming
        // driver consults) must never drift apart.
        let reg = PolicyRegistry::builtin();
        let cfg = AkpcConfig::default();
        for e in reg.iter() {
            let p = e.build(&cfg, EngineChoice::Native);
            assert_eq!(
                e.caps().needs_offline_trace,
                p.needs_offline_trace(),
                "registry/instance offline flag disagrees for `{}`",
                e.name()
            );
        }
    }

    #[test]
    fn capability_flags_match_policy_nature() {
        let reg = PolicyRegistry::builtin();
        assert!(reg.get("akpc").unwrap().caps().supports_sharded);
        assert!(reg.get("akpc").unwrap().caps().supports_elastic);
        assert!(!reg.get("no-packing").unwrap().caps().supports_sharded);
        assert!(!reg.get("no-packing").unwrap().caps().supports_elastic);
        assert!(reg.get("opt").unwrap().caps().needs_offline_trace);
        assert!(reg.get("dp-greedy").unwrap().caps().needs_offline_trace);
        assert_eq!(
            reg.get("akpc").unwrap().caps().summary(),
            "online+sharded+elastic"
        );
        assert_eq!(reg.get("opt").unwrap().caps().summary(), "offline-trace");
        // The extended policy families (DESIGN.md §15) are online-only:
        // neither drives the sharded coordinator (AKPC-specific path) nor
        // needs the trace up front.
        for name in ["predictive", "bundle-opt"] {
            let caps = reg.get(name).unwrap().caps();
            assert_eq!(caps.summary(), "online", "`{name}` caps drifted");
            assert!(!caps.supports_sharded);
            assert!(!caps.needs_offline_trace);
        }
        // Elastic implies sharded for every entry (the handoff is a
        // coordinator operation).
        for e in reg.iter() {
            assert!(
                !e.caps().supports_elastic || e.caps().supports_sharded,
                "`{}` claims elastic without sharded",
                e.name()
            );
        }
    }
}
