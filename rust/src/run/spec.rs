//! [`RunSpec`] — the declarative description of one run: workload ×
//! driver × policy-by-name × config overrides, validated against a
//! [`PolicyRegistry`] before any work starts.
//!
//! `validate()` is the **single place** the effective per-cell config is
//! derived: n_items/n_servers always come from the materialized workload
//! (trace header or scenario universe), never from ad-hoc call-site
//! overrides — sharded and single-leader runs of the same spec are
//! guaranteed to see identical effective configs.

use std::sync::{Arc, Mutex};

use crate::config::AkpcConfig;
use crate::elastic::{ControllerConfig, RentalModel};
use crate::scenario::{CompiledScenario, ScenarioSpec};
use crate::sim::ReplayMode;
use crate::trace::generator::{self, GeneratorParams, TraceKind};
use crate::trace::io as trace_io;
use crate::trace::model::Trace;
use crate::trace::stream::{
    BinaryStreamSource, CsvStreamSource, GeneratorSource, MemorySource, TraceMeta, TraceSource,
    DEFAULT_CHUNK_LEN,
};

use super::drive;
use super::observe::{NullObserver, Observer};
use super::outcome::RunOutcome;
use super::registry::PolicyRegistry;
use super::EngineChoice;

/// Where the requests come from.
#[derive(Debug, Clone)]
pub enum Workload {
    /// Synthetic trace from one of the built-in generators; the universe
    /// shape (n_items/n_servers) comes from the spec's base config.
    Generated { kind: TraceKind, n_requests: usize },
    /// An `akpc-trace` file (`.csv` via [`trace_io::read_csv`], anything
    /// else via [`trace_io::read_binary`]).
    TraceFile(String),
    /// A Kaggle-style external CSV ([`trace_io::read_external_csv`]).
    ExternalCsv(String),
    /// An in-memory trace (library callers, tests). Arc-shared so
    /// repeated `validate`/`execute` calls on one spec never copy the
    /// request vector.
    Inline(Arc<Trace>),
    /// A declarative scenario, compiled at `scale` during validation.
    Scenario { spec: ScenarioSpec, scale: f64 },
    /// A lazily-pulled streaming workload: requests never materialize as
    /// a full `Trace`; validation opens a [`TraceSource`] and the run
    /// drains it chunk by chunk (bounded memory, DESIGN.md §10). This is
    /// the spec-level home of `akpc run --stream` and of the serving
    /// daemon's live ingest (DESIGN.md §12).
    Streamed { input: StreamInput, chunk: usize },
}

/// Where a [`Workload::Streamed`] run pulls its requests from.
#[derive(Debug, Clone)]
pub enum StreamInput {
    /// Chunk-by-chunk synthetic generation ([`generated_source`]).
    Generated { kind: TraceKind, n_requests: usize },
    /// A trace file streamed record by record (`.csv` via
    /// [`CsvStreamSource`], anything else via [`BinaryStreamSource`]).
    File(String),
    /// A caller-supplied live source — e.g. the serving daemon's
    /// [`ChannelSource`](crate::trace::stream::ChannelSource) over its
    /// admission queue.
    Source(SourceHandle),
}

/// A cloneable, consume-once handle around a boxed [`TraceSource`].
///
/// `RunSpec` and `Workload` are `Clone` so specs can be reused across
/// policies; a live stream, however, can be drained only once. The
/// handle squares that circle: clones share one interior slot, the
/// stream [`TraceMeta`] stays inspectable forever, and the first run
/// [`take`](Self::take)s the source while later runs fail with a clear
/// error instead of silently replaying nothing.
#[derive(Clone)]
pub struct SourceHandle {
    meta: TraceMeta,
    inner: Arc<Mutex<Option<Box<dyn TraceSource + Send>>>>,
}

impl SourceHandle {
    /// Wrap `source`, capturing its header for later inspection.
    pub fn new(source: Box<dyn TraceSource + Send>) -> Self {
        let meta = source.meta().clone();
        Self {
            meta,
            inner: Arc::new(Mutex::new(Some(source))),
        }
    }

    /// The stream header (outlives the consumed source).
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// Take ownership of the source; errors once a previous run already
    /// consumed it.
    pub fn take(&self) -> anyhow::Result<Box<dyn TraceSource + Send>> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .take()
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "stream source `{}` already consumed — a live stream \
                     replays once; build a fresh source for another run",
                    self.meta.name
                )
            })
    }
}

impl std::fmt::Debug for SourceHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SourceHandle")
            .field("meta", &self.meta)
            .finish_non_exhaustive()
    }
}

/// How the run is executed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Driver {
    /// In-process simulator loop (any policy, incl. offline baselines).
    SingleLeader,
    /// The sharded online coordinator (policies with the
    /// `supports_sharded` capability).
    Sharded { n_shards: usize, mode: ReplayMode },
    /// The elastic coordinator (policies with the `supports_elastic`
    /// capability): the fleet starts at `ctrl.min_shards` and the
    /// controller resizes it at window boundaries with exact state
    /// handoff; `rental` prices the shard-seconds (DESIGN.md §13).
    Elastic {
        ctrl: ControllerConfig,
        rental: RentalModel,
    },
}

/// Map a CLI dataset name to a generator kind.
pub fn parse_dataset(name: &str) -> anyhow::Result<TraceKind> {
    match name {
        "netflix" => Ok(TraceKind::Netflix),
        "spotify" => Ok(TraceKind::Spotify),
        d => anyhow::bail!("unknown dataset `{d}` (expected netflix|spotify)"),
    }
}

/// The one derivation of generator parameters from a config: preset
/// shape from `kind`, universe from `cfg`, `cfg.seed` folded in.
fn generator_params(kind: TraceKind, cfg: &AkpcConfig, n_requests: usize) -> GeneratorParams {
    let mut params = match kind {
        TraceKind::Netflix => GeneratorParams::netflix(cfg.n_items, cfg.n_servers, n_requests),
        TraceKind::Spotify => GeneratorParams::spotify(cfg.n_items, cfg.n_servers, n_requests),
    };
    params.seed ^= cfg.seed;
    params
}

/// Generate a synthetic workload trace from `cfg`'s universe shape,
/// folding `cfg.seed` into the generator seed (the one generation path —
/// `gen-trace`, `RunSpec`, and the serve demo all use it).
pub fn generated_trace(
    kind: TraceKind,
    cfg: &AkpcConfig,
    n_requests: usize,
) -> anyhow::Result<Trace> {
    generator::try_generate(&generator_params(kind, cfg, n_requests), kind)
}

/// The streaming form of [`generated_trace`]: same parameters, same
/// request stream, but pulled chunk by chunk through a
/// [`GeneratorSource`] instead of materialized (`akpc run --stream` /
/// `gen-trace --chunked`).
pub fn generated_source(
    kind: TraceKind,
    cfg: &AkpcConfig,
    n_requests: usize,
    chunk_len: usize,
) -> anyhow::Result<GeneratorSource> {
    GeneratorSource::new(&generator_params(kind, cfg, n_requests), kind, chunk_len)
}

/// The single source of the per-cell config derivation: the workload's
/// universe shape overrides the base config's, everything else carries
/// over. Both replay drivers and the scenario suite go through here.
pub fn cell_config(base: &AkpcConfig, n_items: u32, n_servers: u32) -> AkpcConfig {
    AkpcConfig {
        n_items,
        n_servers,
        ..base.clone()
    }
}

/// Declarative run description. See the crate-level example in
/// [`crate::run`].
///
/// ```
/// use akpc::config::AkpcConfig;
/// use akpc::run::{PolicyRegistry, RunSpec, Workload};
/// use akpc::trace::generator::TraceKind;
///
/// let registry = PolicyRegistry::builtin();
/// let cfg = AkpcConfig { n_items: 30, n_servers: 12, ..Default::default() };
/// let outcome = RunSpec::new()
///     .config(cfg)
///     .workload(Workload::Generated { kind: TraceKind::Netflix, n_requests: 1_000 })
///     .policy("no-packing")
///     .execute(&registry)
///     .unwrap();
/// assert_eq!(outcome.ledger.requests, 1_000);
/// assert!(outcome.total() > 0.0);
/// ```
#[derive(Debug, Clone)]
pub struct RunSpec {
    workload: Option<Workload>,
    driver: Driver,
    policy: String,
    engine: EngineChoice,
    base_cfg: AkpcConfig,
    batch_size: Option<usize>,
    seed: Option<u64>,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self {
            workload: None,
            driver: Driver::SingleLeader,
            policy: "akpc".to_string(),
            engine: EngineChoice::Native,
            base_cfg: AkpcConfig::default(),
            batch_size: None,
            seed: None,
        }
    }
}

impl RunSpec {
    /// A fresh spec: single-leader, `akpc`, native engine, Table-II
    /// defaults — only the workload is mandatory.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the workload (mandatory).
    pub fn workload(mut self, w: Workload) -> Self {
        self.workload = Some(w);
        self
    }

    /// Sugar: generated synthetic workload.
    pub fn generated(self, kind: TraceKind, n_requests: usize) -> Self {
        self.workload(Workload::Generated { kind, n_requests })
    }

    /// Sugar: trace file workload.
    pub fn trace_file(self, path: impl Into<String>) -> Self {
        self.workload(Workload::TraceFile(path.into()))
    }

    /// Sugar: in-memory trace workload (wrapped in an `Arc` once here).
    pub fn inline_trace(self, trace: Trace) -> Self {
        self.workload(Workload::Inline(Arc::new(trace)))
    }

    /// Sugar: scenario workload at `scale`.
    pub fn scenario(self, spec: ScenarioSpec, scale: f64) -> Self {
        self.workload(Workload::Scenario { spec, scale })
    }

    /// Sugar: streaming workload with the default chunk length.
    pub fn streamed(self, input: StreamInput) -> Self {
        self.workload(Workload::Streamed {
            input,
            chunk: DEFAULT_CHUNK_LEN,
        })
    }

    /// Sugar: chunked synthetic generation, never materialized.
    pub fn stream_generated(self, kind: TraceKind, n_requests: usize) -> Self {
        self.streamed(StreamInput::Generated { kind, n_requests })
    }

    /// Sugar: record-streamed trace file.
    pub fn stream_file(self, path: impl Into<String>) -> Self {
        self.streamed(StreamInput::File(path.into()))
    }

    /// Sugar: caller-supplied live source (consume-once).
    pub fn stream_source(self, handle: SourceHandle) -> Self {
        self.streamed(StreamInput::Source(handle))
    }

    /// Select the driver (default: single-leader).
    pub fn driver(mut self, d: Driver) -> Self {
        self.driver = d;
        self
    }

    /// Sugar: sharded driver.
    pub fn sharded(self, n_shards: usize, mode: ReplayMode) -> Self {
        self.driver(Driver::Sharded { n_shards, mode })
    }

    /// Sugar: elastic driver (autoscaled fleet, shard-second billing).
    pub fn elastic(self, ctrl: ControllerConfig, rental: RentalModel) -> Self {
        self.driver(Driver::Elastic { ctrl, rental })
    }

    /// Select the policy by registry name (default: `akpc`).
    pub fn policy(mut self, name: impl Into<String>) -> Self {
        self.policy = name.into();
        self
    }

    /// CRM engine for AKPC variants (default: native).
    pub fn engine(mut self, engine: EngineChoice) -> Self {
        self.engine = engine;
        self
    }

    /// Base configuration (default: Table II). The workload's universe
    /// shape overrides its n_items/n_servers at validation.
    pub fn config(mut self, cfg: AkpcConfig) -> Self {
        self.base_cfg = cfg;
        self
    }

    /// Override the clique-generation batch size.
    pub fn batch_size(mut self, batch_size: usize) -> Self {
        self.batch_size = Some(batch_size);
        self
    }

    /// Override every seed in one place: the config seed (generated
    /// workloads fold it in) and a scenario workload's spec seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Resolve the policy, materialize the workload, and derive the
    /// effective config. All driver/policy conflicts surface here,
    /// before any simulation work.
    pub fn validate(&self, registry: &PolicyRegistry) -> anyhow::Result<PreparedRun> {
        let entry = registry.resolve(&self.policy)?;
        if let Driver::Sharded { n_shards, .. } = self.driver {
            anyhow::ensure!(n_shards >= 1, "sharded driver needs n_shards >= 1");
            if !entry.caps().supports_sharded {
                let capable: Vec<&str> = registry
                    .iter()
                    .filter(|e| e.caps().supports_sharded)
                    .map(|e| e.name())
                    .collect();
                anyhow::bail!(
                    "policy `{}` does not support the sharded driver \
                     (sharded-capable: {}); use the single-leader driver",
                    entry.name(),
                    capable.join(", ")
                );
            }
        }
        if let Driver::Elastic { .. } = self.driver {
            if !entry.caps().supports_elastic {
                let capable: Vec<&str> = registry
                    .iter()
                    .filter(|e| e.caps().supports_elastic)
                    .map(|e| e.name())
                    .collect();
                anyhow::bail!(
                    "policy `{}` does not support the elastic driver \
                     (elastic-capable: {})",
                    entry.name(),
                    capable.join(", ")
                );
            }
            anyhow::ensure!(
                !matches!(self.workload, Some(Workload::Streamed { .. })),
                "the elastic driver replays a materialized trace (the \
                 controller re-reads window boundaries); use a trace, \
                 generated, or scenario workload — live elastic serving \
                 is the daemon's `POST /reload` path"
            );
        }
        let workload = self.workload.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "RunSpec needs a workload (generated | trace file | scenario | external CSV)"
            )
        })?;

        // Overrides apply before generation so generated workloads and
        // scenario compilation follow them.
        let mut cfg = self.base_cfg.clone();
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        if let Some(b) = self.batch_size {
            cfg.batch_size = b;
        }

        let data = match workload {
            Workload::Generated { kind, n_requests } => {
                WorkloadData::Trace(Arc::new(generated_trace(*kind, &cfg, *n_requests)?))
            }
            Workload::TraceFile(path) => {
                let t = if path.ends_with(".csv") {
                    trace_io::read_csv(path)?
                } else {
                    trace_io::read_binary(path)?
                };
                t.validate()?;
                WorkloadData::Trace(Arc::new(t))
            }
            Workload::ExternalCsv(path) => {
                let t = trace_io::read_external_csv(path)?;
                t.validate()?;
                WorkloadData::Trace(Arc::new(t))
            }
            Workload::Inline(t) => {
                t.validate()?;
                WorkloadData::Trace(Arc::clone(t))
            }
            Workload::Scenario { spec, scale } => {
                let mut spec = spec.clone();
                if let Some(s) = self.seed {
                    spec.seed = s;
                }
                WorkloadData::Scenario(spec.compile(*scale)?)
            }
            Workload::Streamed { input, chunk } => {
                let chunk = (*chunk).max(1);
                let handle = match input {
                    StreamInput::Generated { kind, n_requests } => SourceHandle::new(
                        Box::new(generated_source(*kind, &cfg, *n_requests, chunk)?),
                    ),
                    StreamInput::File(path) => {
                        let src: Box<dyn TraceSource + Send> = if path.ends_with(".csv") {
                            Box::new(CsvStreamSource::open(path, chunk)?)
                        } else {
                            Box::new(BinaryStreamSource::open(path, chunk)?)
                        };
                        SourceHandle::new(src)
                    }
                    StreamInput::Source(handle) => handle.clone(),
                };
                WorkloadData::Stream(handle)
            }
        };

        // The one place n_items/n_servers derive from the workload.
        let cfg = match &data {
            WorkloadData::Trace(t) => cell_config(&cfg, t.n_items, t.n_servers),
            WorkloadData::Scenario(sc) => cell_config(&cfg, sc.n_items, sc.n_servers),
            WorkloadData::Stream(h) => cell_config(&cfg, h.meta().n_items, h.meta().n_servers),
        };
        cfg.validate()?;

        Ok(PreparedRun {
            policy: entry.name().to_string(),
            engine: self.engine,
            driver: self.driver,
            cfg,
            data,
        })
    }

    /// Validate, then execute with `obs` attached.
    pub fn run(
        &self,
        registry: &PolicyRegistry,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<RunOutcome> {
        self.validate(registry)?.run(registry, obs)
    }

    /// Validate, then execute without observers.
    pub fn execute(&self, registry: &PolicyRegistry) -> anyhow::Result<RunOutcome> {
        self.run(registry, &mut NullObserver)
    }
}

/// The materialized workload a validated spec will replay. The trace is
/// Arc-shared: cloning a `WorkloadData` (or validating an `Inline`
/// workload again) never copies the request vector.
#[derive(Debug, Clone)]
pub enum WorkloadData {
    Trace(Arc<Trace>),
    Scenario(CompiledScenario),
    /// An opened streaming source. Consume-once: cloning the data clones
    /// the [`SourceHandle`], not the stream — the first `run()` drains
    /// it, later runs fail with the handle's "already consumed" error.
    Stream(SourceHandle),
}

/// A validated, materialized run: effective config derived, policy
/// resolved, workload compiled. Inspect it (CLI banners, config
/// regression tests) or [`run`](PreparedRun::run) it.
#[derive(Debug)]
pub struct PreparedRun {
    policy: String,
    engine: EngineChoice,
    driver: Driver,
    cfg: AkpcConfig,
    data: WorkloadData,
}

impl PreparedRun {
    /// The effective config every driver will see: n_items/n_servers
    /// from the workload, overrides applied.
    pub fn effective_config(&self) -> &AkpcConfig {
        &self.cfg
    }

    /// Resolved policy name.
    pub fn policy(&self) -> &str {
        &self.policy
    }

    /// Rebind the policy without re-materializing the workload — the
    /// cheap path for A/B comparisons over one compiled scenario or
    /// generated trace. Re-checks driver capabilities against
    /// `registry`.
    pub fn with_policy(
        mut self,
        registry: &PolicyRegistry,
        name: &str,
    ) -> anyhow::Result<Self> {
        let entry = registry.resolve(name)?;
        if matches!(self.driver, Driver::Sharded { .. }) {
            anyhow::ensure!(
                entry.caps().supports_sharded,
                "policy `{}` does not support the sharded driver",
                entry.name()
            );
        }
        if matches!(self.driver, Driver::Elastic { .. }) {
            anyhow::ensure!(
                entry.caps().supports_elastic,
                "policy `{}` does not support the elastic driver",
                entry.name()
            );
        }
        self.policy = entry.name().to_string();
        Ok(self)
    }

    pub fn driver(&self) -> Driver {
        self.driver
    }

    pub fn workload(&self) -> &WorkloadData {
        &self.data
    }

    /// One-line banner describing what is about to run.
    pub fn describe(&self) -> String {
        match &self.data {
            WorkloadData::Trace(t) => format!(
                "trace `{}`: {} requests, universe {} items × {} servers",
                t.name,
                t.len(),
                t.n_items,
                t.n_servers
            ),
            WorkloadData::Scenario(sc) => format!(
                "scenario `{}`: {} phases, {} requests, universe {} items × {} servers",
                sc.name,
                sc.phases.len(),
                sc.total_requests(),
                sc.n_items,
                sc.n_servers
            ),
            WorkloadData::Stream(h) => {
                let m = h.meta();
                let len = m
                    .est_len
                    .map_or_else(|| "unbounded".to_string(), |n| n.to_string());
                format!(
                    "stream `{}`: {} requests, universe {} items × {} servers",
                    m.name, len, m.n_items, m.n_servers
                )
            }
        }
    }

    /// Execute the run, streaming events to `obs` and emitting
    /// `on_done` with the final outcome.
    pub fn run(
        &self,
        registry: &PolicyRegistry,
        obs: &mut dyn Observer,
    ) -> anyhow::Result<RunOutcome> {
        let entry = registry.resolve(&self.policy)?;
        let outcome = match (self.driver, &self.data) {
            (Driver::SingleLeader, WorkloadData::Trace(t)) => {
                let mut policy = entry.build(&self.cfg, self.engine);
                // Lend the Arc-shared trace through the streaming driver;
                // `as_trace` hands offline policies the same allocation.
                let mut source = MemorySource::new(Arc::clone(t));
                let rep =
                    drive::drive_trace(policy.as_mut(), &mut source, self.cfg.batch_size, obs)?;
                RunOutcome::from_sim(rep)
            }
            (Driver::SingleLeader, WorkloadData::Scenario(sc)) => {
                let mut policy = entry.build(&self.cfg, self.engine);
                let run = drive::drive_phased(policy.as_mut(), sc, self.cfg.batch_size, obs);
                let hist = policy.clique_sizes();
                RunOutcome::from_scenario(run, hist)
            }
            (Driver::Sharded { n_shards, mode }, WorkloadData::Trace(t)) => {
                let rep = crate::sim::replay_sharded(
                    &self.cfg,
                    self.engine.to_engine(),
                    t,
                    n_shards,
                    mode,
                )?;
                RunOutcome::from_sharded(rep, t.name.clone())
            }
            (Driver::Sharded { n_shards, mode }, WorkloadData::Scenario(sc)) => {
                let (run, metrics) = drive::drive_phased_sharded(
                    &self.cfg,
                    self.engine.to_engine(),
                    sc,
                    n_shards,
                    mode,
                    obs,
                )?;
                RunOutcome::from_scenario_sharded(run, mode, metrics)
            }
            (Driver::SingleLeader, WorkloadData::Stream(h)) => {
                let mut policy = entry.build(&self.cfg, self.engine);
                let mut source = h.take()?;
                let rep = drive::drive_trace(
                    policy.as_mut(),
                    source.as_mut(),
                    self.cfg.batch_size,
                    obs,
                )?;
                RunOutcome::from_sim(rep)
            }
            (Driver::Sharded { n_shards, mode }, WorkloadData::Stream(h)) => {
                let mut source = h.take()?;
                let rep = crate::sim::replay_sharded_stream(
                    &self.cfg,
                    self.engine.to_engine(),
                    source.as_mut(),
                    n_shards,
                    mode,
                )?;
                RunOutcome::from_sharded(rep, h.meta().name.clone())
            }
            (Driver::Elastic { ctrl, rental }, WorkloadData::Trace(t)) => {
                let out = crate::elastic::drive_elastic(
                    &self.cfg,
                    self.engine.to_engine(),
                    &t.requests,
                    ctrl,
                    rental,
                )?;
                RunOutcome::from_elastic(out, t.name.clone())
            }
            (Driver::Elastic { ctrl, rental }, WorkloadData::Scenario(sc)) => {
                // The controller reacts to the *global* timeline, so the
                // phases replay as one flat trace; per-phase cost deltas
                // are a static-driver concern.
                let t = sc.concat_trace();
                let out = crate::elastic::drive_elastic(
                    &self.cfg,
                    self.engine.to_engine(),
                    &t.requests,
                    ctrl,
                    rental,
                )?;
                RunOutcome::from_elastic(out, sc.name.clone())
            }
            (Driver::Elastic { .. }, WorkloadData::Stream(_)) => {
                anyhow::bail!(
                    "elastic driver cannot replay a stream workload \
                     (validate() rejects this combination)"
                )
            }
        };
        obs.on_done(&outcome);
        Ok(outcome)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 30,
            n_servers: 12,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn missing_workload_rejected() {
        let reg = PolicyRegistry::builtin();
        let err = RunSpec::new().validate(&reg).unwrap_err().to_string();
        assert!(err.contains("needs a workload"), "{err}");
    }

    #[test]
    fn unknown_policy_rejected_with_names() {
        let reg = PolicyRegistry::builtin();
        let err = RunSpec::new()
            .generated(TraceKind::Netflix, 100)
            .policy("nope")
            .validate(&reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown policy `nope`"), "{err}");
        assert!(err.contains("no-packing"), "{err}");
    }

    #[test]
    fn sharded_unsupported_policy_rejected() {
        let reg = PolicyRegistry::builtin();
        let err = RunSpec::new()
            .config(small_cfg())
            .generated(TraceKind::Netflix, 100)
            .policy("opt")
            .sharded(2, ReplayMode::Ordered)
            .validate(&reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not support the sharded driver"), "{err}");
        assert!(err.contains("akpc"), "{err}");
    }

    #[test]
    fn effective_config_follows_workload_universe() {
        let reg = PolicyRegistry::builtin();
        // Base config is 60×600; the inline trace is 30×12.
        let trace = crate::trace::generator::netflix_like(30, 12, 400, 7);
        let prepared = RunSpec::new()
            .inline_trace(trace)
            .policy("no-packing")
            .validate(&reg)
            .unwrap();
        assert_eq!(prepared.effective_config().n_items, 30);
        assert_eq!(prepared.effective_config().n_servers, 12);
        assert!(prepared.describe().contains("30 items × 12 servers"));
    }

    #[test]
    fn seed_override_moves_generated_workload() {
        let reg = PolicyRegistry::builtin();
        let base = RunSpec::new()
            .config(small_cfg())
            .generated(TraceKind::Netflix, 300)
            .policy("no-packing");
        let a = base.clone().seed(1).validate(&reg).unwrap();
        let b = base.clone().seed(2).validate(&reg).unwrap();
        let (WorkloadData::Trace(ta), WorkloadData::Trace(tb)) = (a.workload(), b.workload())
        else {
            panic!("generated workloads materialize as traces");
        };
        assert_ne!(ta.requests, tb.requests);
        assert_eq!(a.effective_config().seed, 1);
    }

    #[test]
    fn streamed_generated_matches_materialized_run() {
        let reg = PolicyRegistry::builtin();
        let base = RunSpec::new().config(small_cfg()).policy("no-packing");
        let mat = base
            .clone()
            .generated(TraceKind::Netflix, 500)
            .execute(&reg)
            .unwrap();
        let streamed = base
            .stream_generated(TraceKind::Netflix, 500)
            .execute(&reg)
            .unwrap();
        assert_eq!(streamed.ledger.requests, 500);
        let rel = (streamed.total() - mat.total()).abs() / mat.total().max(1e-12);
        assert!(rel < 1e-9, "streamed {} vs {}", streamed.total(), mat.total());
    }

    #[test]
    fn streamed_sharded_runs_and_reports_shards() {
        let reg = PolicyRegistry::builtin();
        let out = RunSpec::new()
            .config(small_cfg())
            .stream_generated(TraceKind::Netflix, 400)
            .sharded(2, ReplayMode::Ordered)
            .execute(&reg)
            .unwrap();
        assert_eq!(out.n_shards, 2);
        assert_eq!(out.ledger.requests, 400);
    }

    #[test]
    fn stream_source_is_consume_once() {
        let reg = PolicyRegistry::builtin();
        let cfg = small_cfg();
        let src = generated_source(TraceKind::Netflix, &cfg, 200, 64).unwrap();
        let handle = SourceHandle::new(Box::new(src));
        assert_eq!(handle.meta().est_len, Some(200));
        let spec = RunSpec::new()
            .config(cfg)
            .stream_source(handle)
            .policy("no-packing");
        spec.execute(&reg).unwrap();
        let err = spec.execute(&reg).unwrap_err().to_string();
        assert!(err.contains("already consumed"), "{err}");
    }

    #[test]
    fn elastic_unsupported_policy_rejected() {
        let reg = PolicyRegistry::builtin();
        let err = RunSpec::new()
            .config(small_cfg())
            .generated(TraceKind::Netflix, 100)
            .policy("no-packing")
            .elastic(
                crate::elastic::ControllerConfig::default(),
                crate::elastic::RentalModel::default(),
            )
            .validate(&reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not support the elastic driver"), "{err}");
        assert!(err.contains("akpc"), "{err}");
    }

    #[test]
    fn elastic_rejects_stream_workloads() {
        let reg = PolicyRegistry::builtin();
        let err = RunSpec::new()
            .config(small_cfg())
            .stream_generated(TraceKind::Netflix, 100)
            .elastic(
                crate::elastic::ControllerConfig::default(),
                crate::elastic::RentalModel::default(),
            )
            .validate(&reg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("materialized trace"), "{err}");
    }

    #[test]
    fn elastic_run_reports_bill_and_matches_request_count() {
        let reg = PolicyRegistry::builtin();
        let out = RunSpec::new()
            .config(small_cfg())
            .generated(TraceKind::Netflix, 400)
            .elastic(
                crate::elastic::ControllerConfig {
                    min_shards: 2,
                    max_shards: 2,
                    ..Default::default()
                },
                crate::elastic::RentalModel::default(),
            )
            .execute(&reg)
            .unwrap();
        assert_eq!(out.ledger.requests, 400);
        let e = out.elastic.as_ref().expect("elastic driver attaches a report");
        assert!(e.resizes.is_empty(), "pinned [2,2] fleet cannot resize");
        assert_eq!(out.n_shards, 2);
        assert!(e.cost.rental > 0.0, "rental must bill shard-seconds");
        assert!(out.row().contains("elastic(peak=2,final=2)"));
        crate::util::json::parse(&out.to_json().to_string()).unwrap();
    }

    #[test]
    fn batch_size_override_lands_in_effective_config() {
        let reg = PolicyRegistry::builtin();
        let prepared = RunSpec::new()
            .config(small_cfg())
            .generated(TraceKind::Netflix, 100)
            .batch_size(50)
            .validate(&reg)
            .unwrap();
        assert_eq!(prepared.effective_config().batch_size, 50);
    }
}
