//! [`RunOutcome`] — the one report type every driver produces, unifying
//! the legacy `SimReport` / `ShardedReport` / `ScenarioRun` triple:
//! total cost breakdown, per-phase deltas, per-shard ledgers (via the
//! embedded metrics snapshot), clique histogram, wall time.

use crate::cache::CostLedger;
use crate::coordinator::MetricsSnapshot;
use crate::elastic::{ElasticOutcome, ElasticReport};
use crate::scenario::{PhaseCost, ScenarioRun};
use crate::sim::{ReplayMode, ShardedReport, SimReport};
use crate::util::{Histogram, Json};

/// Result of one facade run, whatever the driver.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    /// Policy display name (e.g. "AKPC w/o ACM").
    pub policy: String,
    /// Workload identity: trace name or scenario name.
    pub workload: String,
    /// Shard actors used; 0 = the in-process single-leader driver.
    pub n_shards: usize,
    /// Replay scheduling of a sharded run (None for single-leader).
    pub mode: Option<ReplayMode>,
    /// Requests served.
    pub n_requests: usize,
    /// Whole-run cost ledger.
    pub ledger: CostLedger,
    /// Per-phase ledger deltas (empty for plain trace workloads). They
    /// sum to `ledger`.
    pub phases: Vec<PhaseCost>,
    /// Clique-size distribution; None when the policy does not track
    /// packing (NoPacking, OPT) or the driver discards it.
    pub clique_hist: Option<Histogram>,
    /// Full coordinator metrics (per-shard ledgers, latency quantiles);
    /// sharded drivers only.
    pub metrics: Option<MetricsSnapshot>,
    /// Elasticity report (bill + resize log); elastic driver only.
    pub elastic: Option<ElasticReport>,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
}

impl RunOutcome {
    /// Total cost C = C_T + C_P.
    pub fn total(&self) -> f64 {
        self.ledger.total()
    }

    /// Per-shard ledgers (empty for single-leader runs).
    pub fn shard_ledgers(&self) -> Vec<CostLedger> {
        self.metrics
            .as_ref()
            .map(|m| m.per_shard.iter().map(|s| s.ledger.clone()).collect())
            .unwrap_or_default()
    }

    fn driver_label(&self) -> String {
        if let Some(e) = &self.elastic {
            return format!("elastic(peak={},final={})", e.peak_shards, e.final_shards);
        }
        match (self.n_shards, self.mode) {
            (0, _) => "single-leader".to_string(),
            (n, Some(m)) => format!("{n}-shard/{}", format!("{m:?}").to_lowercase()),
            (n, None) => format!("{n}-shard"),
        }
    }

    /// One human-readable summary row (shared across all drivers).
    pub fn row(&self) -> String {
        format!(
            "{:<24} {:<18} total={:>12.1}  C_T={:>12.1}  C_P={:>12.1}  hit={:>5.1}%  eff={:>5.1}%  {:.2}s",
            self.policy,
            self.driver_label(),
            self.total(),
            self.ledger.c_t,
            self.ledger.c_p,
            self.ledger.hit_rate() * 100.0,
            self.ledger.delivery_efficiency() * 100.0,
            self.wall_secs,
        )
    }

    /// Multi-line report: the summary row plus any per-phase breakdown.
    pub fn render(&self) -> String {
        let mut out = format!("workload={}\n{}\n", self.workload, self.row());
        for p in &self.phases {
            out.push_str(&p.row());
            out.push('\n');
        }
        out
    }

    /// JSON export (one schema for every driver).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("workload", Json::Str(self.workload.clone())),
            ("driver", Json::Str(self.driver_label())),
            ("n_shards", Json::Num(self.n_shards as f64)),
            ("n_requests", Json::Num(self.n_requests as f64)),
            ("ledger", self.ledger.to_json()),
            (
                "phases",
                Json::Arr(self.phases.iter().map(PhaseCost::to_json).collect()),
            ),
            (
                "clique_hist",
                match &self.clique_hist {
                    Some(h) => h.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "metrics",
                match &self.metrics {
                    Some(m) => m.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "elastic",
                match &self.elastic {
                    Some(e) => e.to_json(),
                    None => Json::Null,
                },
            ),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("requests_per_sec", Json::Num(self.requests_per_sec)),
        ])
    }

    /// From a single-leader trace run.
    pub fn from_sim(rep: SimReport) -> Self {
        Self {
            policy: rep.name,
            workload: rep.trace,
            n_shards: 0,
            mode: None,
            n_requests: rep.n_requests,
            ledger: rep.ledger,
            phases: Vec::new(),
            clique_hist: rep.clique_hist,
            metrics: None,
            elastic: None,
            wall_secs: rep.wall_secs,
            requests_per_sec: rep.requests_per_sec,
        }
    }

    /// From a sharded trace replay.
    pub fn from_sharded(rep: ShardedReport, workload: String) -> Self {
        Self {
            policy: rep.metrics.policy.clone(),
            workload,
            n_shards: rep.n_shards,
            mode: Some(rep.mode),
            n_requests: rep.metrics.served as usize,
            ledger: rep.metrics.ledger.clone(),
            phases: Vec::new(),
            clique_hist: Some(rep.metrics.clique_hist.clone()),
            metrics: Some(rep.metrics),
            elastic: None,
            wall_secs: rep.wall_secs,
            requests_per_sec: rep.requests_per_sec,
        }
    }

    /// From a single-leader phased scenario run (the driver captures the
    /// policy's histogram separately since `ScenarioRun` predates it).
    pub fn from_scenario(run: ScenarioRun, clique_hist: Option<Histogram>) -> Self {
        let requests_per_sec = run.total.requests as f64 / run.wall_secs.max(1e-12);
        Self {
            policy: run.policy,
            workload: run.scenario,
            n_shards: run.n_shards,
            mode: None,
            n_requests: run.total.requests as usize,
            ledger: run.total,
            phases: run.phases,
            clique_hist,
            metrics: None,
            elastic: None,
            wall_secs: run.wall_secs,
            requests_per_sec,
        }
    }

    /// From a sharded phased scenario run plus its shutdown metrics.
    pub fn from_scenario_sharded(
        run: ScenarioRun,
        mode: ReplayMode,
        metrics: MetricsSnapshot,
    ) -> Self {
        let requests_per_sec = run.total.requests as f64 / run.wall_secs.max(1e-12);
        Self {
            policy: run.policy,
            workload: run.scenario,
            n_shards: run.n_shards,
            mode: Some(mode),
            n_requests: run.total.requests as usize,
            ledger: run.total,
            phases: run.phases,
            clique_hist: Some(metrics.clique_hist.clone()),
            metrics: Some(metrics),
            elastic: None,
            wall_secs: run.wall_secs,
            requests_per_sec,
        }
    }

    /// From an elastic replay ([`crate::elastic::drive_elastic`]):
    /// ledger and metrics are the epoch-merged totals; the bill and the
    /// resize log land in `elastic`.
    pub fn from_elastic(out: ElasticOutcome, workload: String) -> Self {
        let requests_per_sec = out.metrics.served as f64 / out.wall_secs.max(1e-12);
        Self {
            policy: out.metrics.policy.clone(),
            workload,
            n_shards: out.final_shards,
            mode: None,
            n_requests: out.metrics.served as usize,
            ledger: out.metrics.ledger.clone(),
            phases: Vec::new(),
            clique_hist: Some(out.metrics.clique_hist.clone()),
            elastic: Some(out.report()),
            metrics: Some(out.metrics),
            wall_secs: out.wall_secs,
            requests_per_sec,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> RunOutcome {
        let ledger = CostLedger {
            c_t: 10.0,
            c_p: 5.0,
            requests: 100,
            ..Default::default()
        };
        RunOutcome {
            policy: "AKPC".to_string(),
            workload: "unit".to_string(),
            n_shards: 0,
            mode: None,
            n_requests: 100,
            ledger,
            phases: Vec::new(),
            clique_hist: None,
            metrics: None,
            elastic: None,
            wall_secs: 0.5,
            requests_per_sec: 200.0,
        }
    }

    #[test]
    fn row_and_render_include_driver() {
        let o = outcome();
        assert!(o.row().contains("single-leader"));
        assert!(o.render().contains("workload=unit"));
        let mut sharded = outcome();
        sharded.n_shards = 4;
        sharded.mode = Some(ReplayMode::Ordered);
        assert!(sharded.row().contains("4-shard/ordered"));
    }

    #[test]
    fn json_round_trips_with_null_histogram() {
        let o = outcome();
        let text = o.to_json().to_string();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.get("clique_hist"), Some(&Json::Null));
        assert_eq!(v.get("policy").and_then(Json::as_str), Some("AKPC"));
        assert!((o.total() - 15.0).abs() < 1e-12);
        assert!(o.shard_ledgers().is_empty());
    }
}
