//! The drivers behind the facade: single-leader trace, single-leader
//! phased scenario, and sharded phased scenario loops, all instrumented
//! with [`Observer`] hooks. The legacy entry points (`sim::run`,
//! `scenario::run_phased`, `scenario::run_phased_sharded`) are thin
//! shims over these functions with a [`NullObserver`](super::NullObserver),
//! so facade and legacy runs are the *same code path* — the
//! facade-equivalence test in `tests/run_api.rs` pins it.

use std::time::Instant;

use crate::algo::CachePolicy;
use crate::cache::CostLedger;
use crate::config::AkpcConfig;
use crate::coordinator::{Coordinator, MetricsSnapshot, ServeRequest, TickMode};
use crate::runtime::CrmEngine;
use crate::scenario::driver::phase_cost;
use crate::scenario::{CompiledScenario, ScenarioRun};
use crate::sim::{ReplayMode, SimReport};
use crate::trace::model::Request;
use crate::trace::stream::{MemorySource, TraceSource};

use super::observe::{Observer, PhaseEvent, WindowEvent};

/// Drive `policy` over a streaming [`TraceSource`] with clique-generation
/// windows of `batch_size` requests, reporting each closed window to
/// `obs`.
///
/// Timeline semantics (paper Fig. 3): requests of batch *i* are served
/// under the packing computed from batches *< i*; `end_batch` runs after
/// the batch is fully served. Peak memory is one chunk plus one window —
/// independent of trace length — **except** for offline policies
/// (`needs_offline_trace`): their `prepare` must see the whole timeline,
/// so the stream is collected first, re-materializing the memory cliff
/// this driver otherwise avoids (DESIGN.md §10.4). Sources that already
/// sit on an in-memory trace ([`MemorySource`]) lend it to `prepare`
/// without a second copy.
///
/// The window boundaries are identical to the materialized
/// `Trace::batches` walk regardless of how the source chunks its
/// requests, so streamed and materialized replays of the same stream are
/// ledger-identical (pinned at 1e-9 by `tests/stream.rs`).
pub fn drive_trace(
    policy: &mut dyn CachePolicy,
    source: &mut dyn TraceSource,
    batch_size: usize,
    obs: &mut dyn Observer,
) -> anyhow::Result<SimReport> {
    let wall = Instant::now();
    if policy.needs_offline_trace() {
        if let Some(t) = source.as_trace() {
            policy.prepare(t);
        } else {
            // The documented memory cliff: an offline policy over a
            // file/generator stream collects it whole.
            let collected = source.collect()?;
            policy.prepare(&collected);
            let mut mem = MemorySource::new(&collected);
            return stream_windows(policy, &mut mem, batch_size, obs, wall);
        }
    }
    stream_windows(policy, source, batch_size, obs, wall)
}

/// The bounded-memory window loop shared by both `drive_trace` paths:
/// re-batches arbitrary source chunks into exact `batch_size` windows
/// (trailing partial window included), holding at most one window plus
/// one chunk.
fn stream_windows(
    policy: &mut dyn CachePolicy,
    source: &mut dyn TraceSource,
    batch_size: usize,
    obs: &mut dyn Observer,
    wall: Instant,
) -> anyhow::Result<SimReport> {
    // Mirror the `Trace::batches` clamp so batch_size == 0 windows match.
    let batch = batch_size.max(1);
    let name = source.meta().name.clone();
    let mut chunk: Vec<Request> = Vec::new();
    let mut window_buf: Vec<Request> = Vec::with_capacity(batch);
    let mut window = 0u64;
    let mut requests_done = 0usize;
    let mut close_window = |policy: &mut dyn CachePolicy,
                            window_buf: &mut Vec<Request>,
                            obs: &mut dyn Observer| {
        policy.end_batch(window_buf);
        window += 1;
        requests_done += window_buf.len();
        obs.on_window(&WindowEvent {
            window,
            requests_done,
            ledger: policy.ledger(),
        });
        window_buf.clear();
    };
    while source.next_chunk(&mut chunk)? {
        for r in chunk.drain(..) {
            policy.handle_request(&r);
            window_buf.push(r);
            if window_buf.len() == batch {
                close_window(policy, &mut window_buf, obs);
            }
        }
    }
    if !window_buf.is_empty() {
        close_window(policy, &mut window_buf, obs);
    }
    drop(close_window);
    Ok(SimReport::from_parts(
        policy,
        &name,
        requests_done,
        wall.elapsed().as_secs_f64(),
    ))
}

/// Drive `policy` through a compiled scenario with the single-leader
/// loop, snapshotting the ledger at each phase boundary. Windows never
/// span phase boundaries (DESIGN.md §7.3).
pub fn drive_phased(
    policy: &mut dyn CachePolicy,
    sc: &CompiledScenario,
    batch_size: usize,
    obs: &mut dyn Observer,
) -> ScenarioRun {
    let wall = Instant::now();
    // Offline policies (OPT, DP_Greedy) see the whole timeline up front;
    // for everyone else the flattened trace is never built (the concat
    // is lazy — DESIGN.md §10.4), so phased replays of online policies
    // hold one phase at a time plus cache state.
    if policy.needs_offline_trace() {
        policy.prepare(sc.concat_trace());
    }
    let mut prev = CostLedger::default();
    let mut phases = Vec::with_capacity(sc.phases.len());
    let mut window = 0u64;
    let mut requests_done = 0usize;
    for (i, ph) in sc.phases.iter().enumerate() {
        for batch in ph.trace.batches(batch_size) {
            for r in batch {
                policy.handle_request(r);
            }
            // The trailing chunk may be partial: windows end at phase
            // boundaries by construction.
            policy.end_batch(batch);
            window += 1;
            requests_done += batch.len();
            obs.on_window(&WindowEvent {
                window,
                requests_done,
                ledger: policy.ledger(),
            });
        }
        let cumulative = policy.ledger().clone();
        let pc = phase_cost(sc, i, &cumulative, &prev);
        obs.on_phase(&PhaseEvent {
            index: i,
            phase: &pc,
        });
        phases.push(pc);
        prev = cumulative;
    }
    ScenarioRun {
        scenario: sc.name.clone(),
        policy: policy.name(),
        n_shards: 0,
        phases,
        total: policy.ledger().clone(),
        wall_secs: wall.elapsed().as_secs_f64(),
    }
}

/// Drive a compiled scenario through the sharded online coordinator
/// (AKPC), one coordinator across all phases so cache/ledger state
/// carries over. `Ordered` replays the global time order from one thread
/// (deterministic, ledger-equivalent to [`drive_phased`] with AKPC);
/// `Parallel` replays each shard's subsequence concurrently within every
/// phase.
///
/// `cfg` must already be the *effective* cell config — its
/// n_items/n_servers matching the scenario universe
/// ([`cell_config`](super::cell_config) / `RunSpec::validate` derive
/// it). Returns the run plus the coordinator's shutdown metrics
/// (per-shard ledgers, latency, clique histogram).
pub fn drive_phased_sharded(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    sc: &CompiledScenario,
    n_shards: usize,
    mode: ReplayMode,
    obs: &mut dyn Observer,
) -> anyhow::Result<(ScenarioRun, MetricsSnapshot)> {
    anyhow::ensure!(
        cfg.n_items == sc.n_items && cfg.n_servers == sc.n_servers,
        "drive_phased_sharded needs the effective cell config \
         ({}×{} given, scenario universe is {}×{}; derive it with \
         run::cell_config or RunSpec::validate)",
        cfg.n_items,
        cfg.n_servers,
        sc.n_items,
        sc.n_servers
    );
    let tick = match mode {
        ReplayMode::Ordered => TickMode::Sync,
        ReplayMode::Parallel => TickMode::Async,
    };
    let coord = Coordinator::start_with(cfg.clone(), engine, n_shards, tick)?;
    let n_shards = coord.n_shards();
    let wall = Instant::now();

    let mut prev = CostLedger::default();
    let mut phases = Vec::with_capacity(sc.phases.len());
    for (i, ph) in sc.phases.iter().enumerate() {
        match mode {
            ReplayMode::Ordered => {
                for r in &ph.trace.requests {
                    coord.serve(ServeRequest {
                        items: r.items.clone(),
                        server: r.server,
                        time: Some(r.time),
                    })?;
                }
            }
            ReplayMode::Parallel => {
                // Partition by the coordinator's own Placement so this
                // harness can never disagree with shard ownership.
                let placement = coord.placement();
                let mut handles = Vec::with_capacity(n_shards);
                for shard in 0..n_shards {
                    let client = coord.client();
                    let requests: Vec<_> = ph
                        .trace
                        .requests
                        .iter()
                        .filter(|r| placement.owns(shard, r.server))
                        .cloned()
                        .collect();
                    handles.push(std::thread::spawn(move || -> anyhow::Result<()> {
                        for r in requests {
                            client.serve(ServeRequest {
                                items: r.items,
                                server: r.server,
                                time: Some(r.time),
                            })?;
                        }
                        Ok(())
                    }));
                }
                for h in handles {
                    h.join()
                        .map_err(|_| anyhow::anyhow!("scenario replay client panicked"))??;
                }
            }
        }
        // Windows never span phases (DESIGN.md §7.3).
        coord.flush_window()?;
        let m = coord.metrics()?;
        let pc = phase_cost(sc, i, &m.ledger, &prev);
        // Streamed as the phase completes — before the shutdown quiesce,
        // so the final phase event excludes the residual retention rent
        // the outcome's last PhaseCost includes (observe.rs module docs).
        obs.on_phase(&PhaseEvent {
            index: i,
            phase: &pc,
        });
        phases.push(pc);
        prev = m.ledger;
    }

    let wall_secs = wall.elapsed().as_secs_f64();
    let metrics = coord.shutdown();
    // The shutdown quiesce sweeps retention rent accrued after the last
    // request (DESIGN.md §2.3); fold the residual into the final phase so
    // the per-phase ledgers still sum to the run total.
    if let Some(last) = phases.last_mut() {
        last.ledger.merge(&metrics.ledger.delta_from(&prev));
    }
    let run = ScenarioRun {
        scenario: sc.name.clone(),
        policy: metrics.policy.clone(),
        n_shards,
        phases,
        total: metrics.ledger.clone(),
        wall_secs,
    };
    Ok((run, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Akpc;
    use crate::run::observe::NullObserver;
    use crate::scenario::ScenarioSpec;
    use crate::trace::generator::netflix_like;

    struct Counting {
        windows: u64,
        phases: usize,
        last_requests: usize,
    }

    impl Observer for Counting {
        fn on_window(&mut self, ev: &WindowEvent<'_>) {
            self.windows += 1;
            self.last_requests = ev.requests_done;
            assert_eq!(self.windows, ev.window, "windows arrive in order");
        }

        fn on_phase(&mut self, ev: &PhaseEvent<'_>) {
            assert_eq!(self.phases, ev.index);
            self.phases += 1;
        }
    }

    #[test]
    fn drive_trace_reports_every_window() {
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let trace = netflix_like(30, 12, 1_000, 9);
        let mut obs = Counting {
            windows: 0,
            phases: 0,
            last_requests: 0,
        };
        // A chunk length coprime to the batch size: the re-batcher must
        // still close exact batch_size windows.
        let mut src = MemorySource::new(&trace).with_chunk_len(137);
        let rep =
            drive_trace(&mut Akpc::new(&cfg), &mut src, cfg.batch_size, &mut obs).unwrap();
        assert_eq!(obs.windows, 5, "1000 requests / batch 200");
        assert_eq!(obs.last_requests, 1_000);
        assert_eq!(rep.ledger.requests, 1_000);
        assert_eq!(rep.n_requests, 1_000);
        assert_eq!(rep.trace, trace.name);
    }

    #[test]
    fn drive_trace_collects_for_offline_policies_without_as_trace() {
        // An offline policy over a pure stream (no as_trace) must see
        // the full timeline via the collect fallback and still match
        // the borrowed-trace path exactly.
        use crate::algo::DpGreedy;
        use crate::trace::generator::GeneratorParams;
        use crate::trace::stream::GeneratorSource;
        use crate::trace::TraceKind;

        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let p = GeneratorParams::netflix(30, 12, 800);
        let mut gen_src = GeneratorSource::new(&p, TraceKind::Netflix, 100).unwrap();
        let streamed = drive_trace(
            &mut DpGreedy::new(&cfg),
            &mut gen_src,
            cfg.batch_size,
            &mut NullObserver,
        )
        .unwrap();

        let trace = crate::trace::generator::generate(&p, TraceKind::Netflix);
        let mut mem = MemorySource::new(&trace);
        let borrowed = drive_trace(
            &mut DpGreedy::new(&cfg),
            &mut mem,
            cfg.batch_size,
            &mut NullObserver,
        )
        .unwrap();
        assert_eq!(streamed.ledger.c_t, borrowed.ledger.c_t);
        assert_eq!(streamed.ledger.c_p, borrowed.ledger.c_p);
    }

    #[test]
    fn drive_phased_reports_phases_and_windows() {
        let sc = ScenarioSpec::from_toml_str(
            r#"
            name = "obs"
            seed = 3
            n_items = 30
            n_servers = 12

            [phase]
            generator = "netflix"
            requests = 500

            [phase]
            generator = "netflix"
            requests = 300
            "#,
        )
        .unwrap()
        .compile(1.0)
        .unwrap();
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 12,
            ..Default::default()
        };
        let mut obs = Counting {
            windows: 0,
            phases: 0,
            last_requests: 0,
        };
        let run = drive_phased(&mut Akpc::new(&cfg), &sc, cfg.batch_size, &mut obs);
        // 500 -> 3 windows (200/200/100), 300 -> 2 windows (200/100).
        assert_eq!(obs.windows, 5);
        assert_eq!(obs.phases, 2);
        assert_eq!(run.phases.len(), 2);
    }

    #[test]
    fn drive_phased_sharded_rejects_wrong_cell_config() {
        let sc = ScenarioSpec::from_toml_str(
            r#"
            name = "cfg"
            n_items = 30
            n_servers = 12
            [phase]
            generator = "netflix"
            requests = 300
            "#,
        )
        .unwrap()
        .compile(1.0)
        .unwrap();
        // Default cfg is 60×600 — not the scenario universe.
        let err = drive_phased_sharded(
            &AkpcConfig::default(),
            CrmEngine::Native,
            &sc,
            2,
            ReplayMode::Ordered,
            &mut NullObserver,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("effective cell config"), "{err}");
    }
}
