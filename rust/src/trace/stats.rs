//! Trace statistics: popularity skew, co-access strength, request mix.
//!
//! Used by `akpc trace stats`, by DESIGN/EXPERIMENTS documentation, and by
//! tests asserting that generated traces exhibit the structure the paper's
//! datasets have.

use std::collections::HashMap;

use super::model::Trace;

/// Summary statistics of a trace.
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub n_requests: usize,
    pub n_items: u32,
    pub n_servers: u32,
    pub time_span: f64,
    pub mean_request_size: f64,
    /// Fraction of accesses going to the top 10% of items.
    pub top10pct_item_share: f64,
    /// Fraction of requests landing on the top 10% of servers.
    pub top10pct_server_share: f64,
    /// Number of distinct co-accessed pairs observed.
    pub distinct_pairs: usize,
    /// Mean co-access count over observed pairs.
    pub mean_pair_count: f64,
}

impl TraceStats {
    /// JSON export.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("n_requests", Json::Num(self.n_requests as f64)),
            ("n_items", Json::Num(self.n_items as f64)),
            ("n_servers", Json::Num(self.n_servers as f64)),
            ("time_span", Json::Num(self.time_span)),
            ("mean_request_size", Json::Num(self.mean_request_size)),
            ("top10pct_item_share", Json::Num(self.top10pct_item_share)),
            (
                "top10pct_server_share",
                Json::Num(self.top10pct_server_share),
            ),
            ("distinct_pairs", Json::Num(self.distinct_pairs as f64)),
            ("mean_pair_count", Json::Num(self.mean_pair_count)),
        ])
    }
}

/// Compute [`TraceStats`].
pub fn analyze(trace: &Trace) -> TraceStats {
    let mut item_counts: HashMap<u32, u64> = HashMap::new();
    let mut server_counts: HashMap<u32, u64> = HashMap::new();
    let mut pair_counts: HashMap<(u32, u32), u64> = HashMap::new();
    let mut size_sum = 0usize;

    for r in &trace.requests {
        size_sum += r.items.len();
        *server_counts.entry(r.server).or_default() += 1;
        for (i, &a) in r.items.iter().enumerate() {
            *item_counts.entry(a).or_default() += 1;
            for &b in &r.items[i + 1..] {
                *pair_counts.entry((a, b)).or_default() += 1;
            }
        }
    }

    let share_top10 = |counts: &HashMap<u32, u64>| -> f64 {
        if counts.is_empty() {
            return 0.0;
        }
        let mut v: Vec<u64> = counts.values().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let k = (v.len() as f64 * 0.10).ceil() as usize;
        let top: u64 = v[..k.max(1).min(v.len())].iter().sum();
        let total: u64 = v.iter().sum();
        top as f64 / total.max(1) as f64
    };

    let time_span = match (trace.requests.first(), trace.requests.last()) {
        (Some(a), Some(b)) => b.time - a.time,
        _ => 0.0,
    };

    TraceStats {
        n_requests: trace.len(),
        n_items: trace.n_items,
        n_servers: trace.n_servers,
        time_span,
        mean_request_size: size_sum as f64 / trace.len().max(1) as f64,
        top10pct_item_share: share_top10(&item_counts),
        top10pct_server_share: share_top10(&server_counts),
        distinct_pairs: pair_counts.len(),
        mean_pair_count: {
            let s: u64 = pair_counts.values().sum();
            s as f64 / pair_counts.len().max(1) as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::{netflix_like, spotify_like};

    #[test]
    fn netflix_more_skewed_than_spotify() {
        let nf = analyze(&netflix_like(60, 100, 30_000, 1));
        let sp = analyze(&spotify_like(60, 100, 30_000, 1));
        assert!(
            nf.top10pct_item_share > sp.top10pct_item_share,
            "netflix {} vs spotify {}",
            nf.top10pct_item_share,
            sp.top10pct_item_share
        );
    }

    #[test]
    fn stats_shapes() {
        let s = analyze(&netflix_like(60, 100, 10_000, 2));
        assert_eq!(s.n_requests, 10_000);
        assert!(s.mean_request_size >= 1.0 && s.mean_request_size <= 5.0);
        assert!(s.time_span > 0.0);
        assert!(s.distinct_pairs > 0);
    }

    #[test]
    fn empty_trace_stats() {
        let s = analyze(&Trace::default());
        assert_eq!(s.n_requests, 0);
        assert_eq!(s.mean_request_size, 0.0);
    }
}
