//! Core request/trace types (paper §III-B).
//!
//! A request is the tuple `r_i = ⟨D_i, s_j, t_i⟩`: a set of data items, the
//! ESS it arrives at, and its arrival time.

/// A single user request `⟨D_i, s_j, t_i⟩`.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Requested data-item ids, strictly ascending, non-empty,
    /// `len <= d_max`.
    pub items: Vec<u32>,
    /// ESS index `s_j ∈ [0, m)`.
    pub server: u32,
    /// Arrival time `t_i` (continuous, in Δt units at ρ=1).
    pub time: f64,
}

impl Request {
    /// Construct, sorting + deduplicating the item set.
    pub fn new(mut items: Vec<u32>, server: u32, time: f64) -> Self {
        items.sort_unstable();
        items.dedup();
        Self {
            items,
            server,
            time,
        }
    }
}

/// A full workload trace, time-ordered.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
    /// Item-universe size n = |U|.
    pub n_items: u32,
    /// Server count m = |S|.
    pub n_servers: u32,
    /// Human-readable provenance ("netflix-like", "spotify-like", file...).
    pub name: String,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Check structural invariants (ordering, bounds). Used by tests and
    /// after IO round-trips.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut last_t = f64::NEG_INFINITY;
        for (i, r) in self.requests.iter().enumerate() {
            anyhow::ensure!(!r.items.is_empty(), "request {i} empty");
            anyhow::ensure!(
                r.items.windows(2).all(|w| w[0] < w[1]),
                "request {i} items not strictly ascending"
            );
            anyhow::ensure!(
                *r.items.last().unwrap() < self.n_items,
                "request {i} item out of range"
            );
            anyhow::ensure!(r.server < self.n_servers, "request {i} server out of range");
            anyhow::ensure!(r.time >= last_t, "request {i} out of time order");
            last_t = r.time;
        }
        Ok(())
    }

    /// Iterate the trace in consecutive batches of `batch_size` (the
    /// clique-generation window granularity, Fig. 3).
    ///
    /// `batch_size == 0` is clamped to 1 — every request becomes its own
    /// window — rather than panicking (`slice::chunks` rejects 0). The
    /// streaming driver re-batcher mirrors this clamp so materialized
    /// and streamed replays window identically at every `batch_size`.
    pub fn batches(&self, batch_size: usize) -> impl Iterator<Item = &[Request]> {
        self.requests.chunks(batch_size.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_new_sorts_and_dedups() {
        let r = Request::new(vec![5, 1, 5, 3], 0, 0.0);
        assert_eq!(r.items, vec![1, 3, 5]);
    }

    #[test]
    fn validate_accepts_good_trace() {
        let t = Trace {
            requests: vec![
                Request::new(vec![0, 1], 0, 0.0),
                Request::new(vec![2], 1, 1.0),
            ],
            n_items: 3,
            n_servers: 2,
            name: "t".into(),
        };
        t.validate().unwrap();
    }

    #[test]
    fn validate_rejects_out_of_range_item() {
        let t = Trace {
            requests: vec![Request::new(vec![9], 0, 0.0)],
            n_items: 3,
            n_servers: 1,
            name: "t".into(),
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_rejects_time_disorder() {
        let t = Trace {
            requests: vec![
                Request::new(vec![0], 0, 5.0),
                Request::new(vec![1], 0, 1.0),
            ],
            n_items: 2,
            n_servers: 1,
            name: "t".into(),
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn batches_chunk_correctly() {
        let t = Trace {
            requests: (0..10)
                .map(|i| Request::new(vec![0], 0, i as f64))
                .collect(),
            n_items: 1,
            n_servers: 1,
            name: "t".into(),
        };
        let sizes: Vec<usize> = t.batches(4).map(|b| b.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn batches_zero_clamps_to_singletons() {
        // batch_size == 0 must not panic: it degrades to one-request
        // windows (documented clamp).
        let t = Trace {
            requests: (0..3)
                .map(|i| Request::new(vec![0], 0, i as f64))
                .collect(),
            n_items: 1,
            n_servers: 1,
            name: "t".into(),
        };
        let sizes: Vec<usize> = t.batches(0).map(|b| b.len()).collect();
        assert_eq!(sizes, vec![1, 1, 1]);
    }
}
