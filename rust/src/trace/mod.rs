//! Workload traces: the request model, synthetic trace generators standing
//! in for the paper's Netflix/Spotify Kaggle traces (see DESIGN.md §2),
//! trace file IO, and the streaming [`TraceSource`](stream::TraceSource)
//! engine for bounded-memory replays (DESIGN.md §10).

pub mod generator;
pub mod io;
pub mod model;
pub mod stats;
pub mod stream;

pub use generator::{
    netflix_like, spotify_like, try_generate, GeneratorParams, TraceGenerator, TraceKind,
};
pub use model::{Request, Trace};
pub use stream::{
    BinaryStreamSource, CsvStreamSource, GeneratorSource, MemorySource, TraceMeta, TraceSource,
    DEFAULT_CHUNK_LEN,
};
