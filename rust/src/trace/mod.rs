//! Workload traces: the request model, synthetic trace generators standing
//! in for the paper's Netflix/Spotify Kaggle traces (see DESIGN.md §2), and
//! trace file IO.

pub mod generator;
pub mod io;
pub mod model;
pub mod stats;

pub use generator::{netflix_like, spotify_like, try_generate, GeneratorParams, TraceKind};
pub use model::{Request, Trace};
