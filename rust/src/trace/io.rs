//! Trace file IO: a human-readable CSV form and a compact binary form.
//!
//! CSV (one request per line): `time,server,item[;item...]`
//! Binary: little-endian framed records, magic `AKPT`, version 1 — about
//! 6x smaller and 10x faster to load for the 1M-request evaluation traces.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::model::{Request, Trace};

const MAGIC: &[u8; 4] = b"AKPT";
const VERSION: u32 = 1;

/// Write a trace as CSV (with a `#` header carrying metadata).
pub fn write_csv(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# akpc-trace v1 name={} n_items={} n_servers={}",
        trace.name, trace.n_items, trace.n_servers
    )?;
    for r in &trace.requests {
        let items = r
            .items
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(";");
        writeln!(w, "{},{},{}", r.time, r.server, items)?;
    }
    Ok(())
}

/// Read a CSV trace written by [`write_csv`].
///
/// Malformed rows are rejected with their 1-based line number; empty item
/// lists are errors, and when the `#` header carries `n_items=`, every
/// item id is validated against it.
pub fn read_csv(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut trace = Trace::default();
    for (i, line) in r.lines().enumerate() {
        let lineno = i + 1;
        let line = line?;
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('#') {
            for tok in hdr.split_whitespace() {
                if let Some(v) = tok.strip_prefix("name=") {
                    trace.name = v.to_string();
                } else if let Some(v) = tok.strip_prefix("n_items=") {
                    trace.n_items = v
                        .parse()
                        .map_err(|e| anyhow::anyhow!("line {lineno}: bad n_items `{v}`: {e}"))?;
                } else if let Some(v) = tok.strip_prefix("n_servers=") {
                    trace.n_servers = v.parse().map_err(|e| {
                        anyhow::anyhow!("line {lineno}: bad n_servers `{v}`: {e}")
                    })?;
                }
            }
            continue;
        }
        let mut parts = line.splitn(3, ',');
        let time: f64 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing time"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad time: {e}"))?;
        let server: u32 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing server"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad server: {e}"))?;
        let items_field = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing items"))?;
        anyhow::ensure!(!items_field.is_empty(), "line {lineno}: empty item list");
        let items: Vec<u32> = items_field
            .split(';')
            .map(|s| {
                s.parse::<u32>()
                    .map_err(|e| anyhow::anyhow!("line {lineno}: bad item `{s}`: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        if trace.n_items > 0 {
            if let Some(&bad) = items.iter().find(|&&d| d >= trace.n_items) {
                anyhow::bail!(
                    "line {lineno}: item {bad} out of range (header n_items={})",
                    trace.n_items
                );
            }
        }
        trace.requests.push(Request::new(items, server, time));
    }
    Ok(trace)
}

/// Ingest an external "Kaggle-style" request dump as a [`Trace`] — the
/// adapter the scenario engine uses for real-dataset phases (DESIGN.md
/// §7.4).
///
/// Expected shape: a comma-separated file whose first non-empty line is a
/// header naming the columns. Recognized column names (case-insensitive):
///
/// * time:   `time`, `timestamp`, `t`, `ts`
/// * server: `server`, `server_id`, `ess`, `region`, `user_id`, `user`
/// * items:  `item`, `item_id`, `items`, `track_id`, `movie_id`, `title_id`
///
/// The item cell may hold several `;`-separated ids. A column whose
/// values all parse as `u32` keeps its numeric ids; otherwise the whole
/// column is interned to dense indices in first-seen order (all-or-
/// nothing per column, so a mixed column can never alias an interned id
/// onto a literal numeric one). Rows are sorted by `(time, server)`
/// (stable), rows with identical `(time, server)` merge into one
/// multi-item request, and `n_items` / `n_servers` are inferred from the
/// data.
/// Split one CSV row on commas, honoring double-quoted fields (commas
/// inside `"..."` do not separate; `""` inside a quoted field is an
/// escaped quote). Cells come back trimmed and unquoted.
fn split_csv_row(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => cells.push(std::mem::take(&mut cur).trim().to_string()),
            c => cur.push(c),
        }
    }
    cells.push(cur.trim().to_string());
    cells
}

pub fn read_external_csv(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let path = path.as_ref();
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut lines = r.lines().enumerate();

    // Locate + parse the header row.
    let (mut time_col, mut server_col, mut item_col) = (None, None, None);
    for (i, line) in lines.by_ref() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        for (col, name) in split_csv_row(&line).into_iter().enumerate() {
            match name.to_ascii_lowercase().as_str() {
                "time" | "timestamp" | "t" | "ts" => time_col = Some(col),
                "server" | "server_id" | "ess" | "region" | "user_id" | "user" => {
                    server_col = Some(col)
                }
                "item" | "item_id" | "items" | "track_id" | "movie_id" | "title_id" => {
                    item_col = Some(col)
                }
                _ => {}
            }
        }
        anyhow::ensure!(
            time_col.is_some() && server_col.is_some() && item_col.is_some(),
            "line {}: header must name time/server/item columns (got `{line}`)",
            i + 1
        );
        break;
    }
    let (time_col, server_col, item_col) = match (time_col, server_col, item_col) {
        (Some(t), Some(s), Some(d)) => (t, s, d),
        _ => anyhow::bail!("empty file: no header row"),
    };

    // First pass: collect raw cells (id resolution is per-column,
    // all-or-nothing, so it must wait until the whole file is read).
    let mut rows: Vec<(f64, String, Vec<String>)> = Vec::new();
    for (i, line) in lines {
        let lineno = i + 1;
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let cells = split_csv_row(&line);
        let cell = |col: usize, what: &str| -> anyhow::Result<&str> {
            cells
                .get(col)
                .map(|s| s.as_str())
                .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing {what} column"))
        };
        let time: f64 = cell(time_col, "time")?
            .parse()
            .map_err(|e| anyhow::anyhow!("line {lineno}: bad time: {e}"))?;
        anyhow::ensure!(time.is_finite(), "line {lineno}: non-finite timestamp");
        let server = cell(server_col, "server")?.to_string();
        anyhow::ensure!(!server.is_empty(), "line {lineno}: empty server id");
        let item_cell = cell(item_col, "item")?;
        anyhow::ensure!(!item_cell.is_empty(), "line {lineno}: empty item list");
        let items: Vec<String> = item_cell
            .split(';')
            .map(|s| {
                let s = s.trim();
                anyhow::ensure!(!s.is_empty(), "line {lineno}: empty item in `{item_cell}`");
                Ok(s.to_string())
            })
            .collect::<anyhow::Result<_>>()?;
        rows.push((time, server, items));
    }
    anyhow::ensure!(!rows.is_empty(), "no data rows in external trace");

    // Per-column id resolution: numeric ids pass through only when the
    // *entire* column is numeric; otherwise every value is interned in
    // first-seen (file-order) order. Mixing the two in one column would
    // let a dense interned index alias a literal numeric id.
    let resolve = |numeric: bool, map: &mut std::collections::HashMap<String, u32>, raw: &str| {
        if numeric {
            raw.parse::<u32>().expect("checked numeric column")
        } else {
            let next = map.len() as u32;
            *map.entry(raw.to_string()).or_insert(next)
        }
    };
    let servers_numeric = rows.iter().all(|(_, s, _)| s.parse::<u32>().is_ok());
    let items_numeric = rows
        .iter()
        .all(|(_, _, items)| items.iter().all(|d| d.parse::<u32>().is_ok()));
    let mut item_ids = std::collections::HashMap::new();
    let mut server_ids = std::collections::HashMap::new();
    let mut resolved: Vec<(f64, u32, Vec<u32>)> = rows
        .into_iter()
        .map(|(time, server, items)| {
            let server = resolve(servers_numeric, &mut server_ids, &server);
            let items = items
                .iter()
                .map(|d| resolve(items_numeric, &mut item_ids, d))
                .collect();
            (time, server, items)
        })
        .collect();

    // Stable (time, server) sort, then merge identical (time, server)
    // rows into one request (per-item dump formats emit one row per
    // item); sorting by server within a timestamp makes equal keys
    // adjacent even when another server's row lands between them.
    resolved.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let mut requests: Vec<Request> = Vec::with_capacity(resolved.len());
    for (time, server, items) in resolved {
        match requests.last_mut() {
            Some(prev) if prev.time == time && prev.server == server => {
                let mut merged = prev.items.clone();
                merged.extend(items);
                *prev = Request::new(merged, server, time);
            }
            _ => requests.push(Request::new(items, server, time)),
        }
    }

    let n_items = 1 + requests
        .iter()
        .flat_map(|r| r.items.iter().copied())
        .max()
        .unwrap_or(0);
    let n_servers = 1 + requests.iter().map(|r| r.server).max().unwrap_or(0);
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("external")
        .to_string();
    let trace = Trace {
        requests,
        n_items,
        n_servers,
        name,
    };
    trace.validate()?;
    Ok(trace)
}

/// Write the compact binary form.
pub fn write_binary(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&trace.n_items.to_le_bytes())?;
    w.write_all(&trace.n_servers.to_le_bytes())?;
    let name = trace.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(trace.requests.len() as u64).to_le_bytes())?;
    for r in &trace.requests {
        w.write_all(&r.time.to_le_bytes())?;
        w.write_all(&r.server.to_le_bytes())?;
        w.write_all(&(r.items.len() as u16).to_le_bytes())?;
        for &d in &r.items {
            w.write_all(&d.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the compact binary form.
pub fn read_binary(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let mut data = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut data)?;
    let mut pos = 0usize;

    let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
        anyhow::ensure!(*pos + n <= data.len(), "truncated trace file");
        let s = &data[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };

    anyhow::ensure!(take(&mut pos, 4)? == MAGIC, "bad magic");
    let ver = u32_at(&mut pos)?;
    anyhow::ensure!(ver == VERSION, "unsupported version {ver}");
    let n_items = u32_at(&mut pos)?;
    let n_servers = u32_at(&mut pos)?;
    let name_len = u32_at(&mut pos)? as usize;
    let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
    let n_reqs = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;

    let mut requests = Vec::with_capacity(n_reqs);
    for _ in 0..n_reqs {
        let time = f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let server = u32_at(&mut pos)?;
        let k = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
        let mut items = Vec::with_capacity(k);
        for _ in 0..k {
            items.push(u32_at(&mut pos)?);
        }
        requests.push(Request {
            items,
            server,
            time,
        });
    }
    Ok(Trace {
        requests,
        n_items,
        n_servers,
        name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;
    use crate::util::tempdir::TempDir;

    #[test]
    fn csv_roundtrip() {
        let t = netflix_like(30, 10, 500, 1);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.csv");
        write_csv(&t, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.n_items, t.n_items);
        assert_eq!(back.n_servers, t.n_servers);
        assert_eq!(back.name, t.name);
        assert_eq!(back.requests.len(), t.requests.len());
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert_eq!(a.items, b.items);
            assert_eq!(a.server, b.server);
            assert!((a.time - b.time).abs() < 1e-9);
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = netflix_like(30, 10, 500, 2);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.bin");
        write_binary(&t, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.requests, t.requests); // bit-exact times
        back.validate().unwrap();
    }

    #[test]
    fn csv_errors_carry_line_numbers() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("bad.csv");
        std::fs::write(&p, "# akpc-trace v1 n_items=10 n_servers=2\n0.5,0,1;2\n1.0,zero,3\n")
            .unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 3"), "error lacks line number: {err}");

        std::fs::write(&p, "0.5,0,\n").unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("empty item list"), "{err}");
    }

    #[test]
    fn csv_validates_items_against_header() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("range.csv");
        std::fs::write(&p, "# akpc-trace v1 n_items=4 n_servers=2\n0.5,0,1;9\n").unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("out of range"), "{err}");
        // Without a header the same row is accepted (range unknown).
        std::fs::write(&p, "0.5,0,1;9\n").unwrap();
        assert_eq!(read_csv(&p).unwrap().requests.len(), 1);
    }

    #[test]
    fn external_csv_ingests_kaggle_shape() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("kaggle.csv");
        // Out-of-order times, string ids, one row per item.
        std::fs::write(
            &p,
            "timestamp,user_id,track_id\n\
             3.0,u1,songB\n\
             1.0,u0,songA\n\
             1.0,u0,songB\n\
             2.5,u1,songC;songA\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        t.validate().unwrap();
        assert_eq!(t.n_servers, 2);
        assert_eq!(t.n_items, 3);
        // Rows at (1.0, u0) merged into one request.
        assert_eq!(t.requests.len(), 3);
        assert_eq!(t.requests[0].items.len(), 2);
        assert_eq!(t.name, "kaggle");
        // Deterministic interning: re-reading yields the identical trace.
        assert_eq!(read_external_csv(&p).unwrap().requests, t.requests);
    }

    #[test]
    fn external_csv_merges_interleaved_and_interns_mixed_columns() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("mixed.csv");
        // Coarse timestamps interleave servers; the item column mixes a
        // literal numeric id with names, so the whole column is interned
        // (numeric passthrough would alias "0" with the first interned
        // name).
        std::fs::write(
            &p,
            "time,server,item\n\
             1.0,3,songA\n\
             1.0,7,songX\n\
             1.0,3,0\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        // Both server-3 rows merged despite the interleaved server-7 row.
        assert_eq!(t.requests.len(), 2);
        assert_eq!(t.requests[0].server, 3); // numeric column passes through
        assert_eq!(t.requests[0].items, vec![0, 2]); // songA=0, songX=1, "0"=2
        assert_eq!(t.n_items, 3);
        assert_eq!(t.n_servers, 8);
    }

    #[test]
    fn external_csv_handles_quoted_commas_and_rejects_empty_tokens() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("quoted.csv");
        std::fs::write(
            &p,
            "time,user_id,track_id\n\
             1.0,u0,\"Song, Pt. 2\"\n\
             2.0,u0,\"Song, Pt. 2\"\n\
             3.0,u1,\"He said \"\"hi\"\"\"\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        // The quoted comma does not split: one title, re-seen = same id.
        assert_eq!(t.n_items, 2);
        assert_eq!(t.requests[0].items, t.requests[1].items);

        let bad = dir.file("empty-token.csv");
        std::fs::write(&bad, "time,user_id,track_id\n1.0,u0,12;;34\n").unwrap();
        let err = read_external_csv(&bad).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("empty item"), "{err}");
    }

    #[test]
    fn external_csv_rejects_missing_columns() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("nohdr.csv");
        std::fs::write(&p, "a,b\n1,2\n").unwrap();
        assert!(read_external_csv(&p).is_err());
        let empty = dir.file("empty.csv");
        std::fs::write(&empty, "").unwrap();
        assert!(read_external_csv(&empty).is_err());
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("bad.bin");
        std::fs::write(&p, b"not a trace").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn binary_rejects_truncated() {
        let t = netflix_like(10, 5, 100, 3);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.bin");
        write_binary(&t, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() / 2]).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
