//! Trace file IO: a human-readable CSV form and a compact binary form.
//!
//! CSV (one request per line): `time,server,item[;item...]`
//! Binary: little-endian framed records, magic `AKPT`, version 1 — about
//! 6x smaller and 10x faster to load for the 1M-request evaluation traces.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::model::{Request, Trace};

const MAGIC: &[u8; 4] = b"AKPT";
const VERSION: u32 = 1;

/// Write a trace as CSV (with a `#` header carrying metadata).
pub fn write_csv(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# akpc-trace v1 name={} n_items={} n_servers={}",
        trace.name, trace.n_items, trace.n_servers
    )?;
    for r in &trace.requests {
        let items = r
            .items
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(";");
        writeln!(w, "{},{},{}", r.time, r.server, items)?;
    }
    Ok(())
}

/// Read a CSV trace written by [`write_csv`].
pub fn read_csv(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let f = std::fs::File::open(path)?;
    let r = BufReader::new(f);
    let mut trace = Trace::default();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('#') {
            for tok in hdr.split_whitespace() {
                if let Some(v) = tok.strip_prefix("name=") {
                    trace.name = v.to_string();
                } else if let Some(v) = tok.strip_prefix("n_items=") {
                    trace.n_items = v.parse()?;
                } else if let Some(v) = tok.strip_prefix("n_servers=") {
                    trace.n_servers = v.parse()?;
                }
            }
            continue;
        }
        let mut parts = line.splitn(3, ',');
        let time: f64 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing time"))?
            .parse()?;
        let server: u32 = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing server"))?
            .parse()?;
        let items: Vec<u32> = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing items"))?
            .split(';')
            .map(|s| s.parse::<u32>())
            .collect::<Result<_, _>>()?;
        trace.requests.push(Request::new(items, server, time));
    }
    Ok(trace)
}

/// Write the compact binary form.
pub fn write_binary(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&trace.n_items.to_le_bytes())?;
    w.write_all(&trace.n_servers.to_le_bytes())?;
    let name = trace.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(trace.requests.len() as u64).to_le_bytes())?;
    for r in &trace.requests {
        w.write_all(&r.time.to_le_bytes())?;
        w.write_all(&r.server.to_le_bytes())?;
        w.write_all(&(r.items.len() as u16).to_le_bytes())?;
        for &d in &r.items {
            w.write_all(&d.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the compact binary form.
pub fn read_binary(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let mut data = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut data)?;
    let mut pos = 0usize;

    let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
        anyhow::ensure!(*pos + n <= data.len(), "truncated trace file");
        let s = &data[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };

    anyhow::ensure!(take(&mut pos, 4)? == MAGIC, "bad magic");
    let ver = u32_at(&mut pos)?;
    anyhow::ensure!(ver == VERSION, "unsupported version {ver}");
    let n_items = u32_at(&mut pos)?;
    let n_servers = u32_at(&mut pos)?;
    let name_len = u32_at(&mut pos)? as usize;
    let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())?;
    let n_reqs = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;

    let mut requests = Vec::with_capacity(n_reqs);
    for _ in 0..n_reqs {
        let time = f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
        let server = u32_at(&mut pos)?;
        let k = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
        let mut items = Vec::with_capacity(k);
        for _ in 0..k {
            items.push(u32_at(&mut pos)?);
        }
        requests.push(Request {
            items,
            server,
            time,
        });
    }
    Ok(Trace {
        requests,
        n_items,
        n_servers,
        name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;
    use crate::util::tempdir::TempDir;

    #[test]
    fn csv_roundtrip() {
        let t = netflix_like(30, 10, 500, 1);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.csv");
        write_csv(&t, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.n_items, t.n_items);
        assert_eq!(back.n_servers, t.n_servers);
        assert_eq!(back.name, t.name);
        assert_eq!(back.requests.len(), t.requests.len());
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert_eq!(a.items, b.items);
            assert_eq!(a.server, b.server);
            assert!((a.time - b.time).abs() < 1e-9);
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = netflix_like(30, 10, 500, 2);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.bin");
        write_binary(&t, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.requests, t.requests); // bit-exact times
        back.validate().unwrap();
    }

    #[test]
    fn binary_rejects_garbage() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("bad.bin");
        std::fs::write(&p, b"not a trace").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn binary_rejects_truncated() {
        let t = netflix_like(10, 5, 100, 3);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.bin");
        write_binary(&t, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() / 2]).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
