//! Trace file IO: a human-readable CSV form and a compact binary form.
//!
//! CSV (one request per line): `time,server,item[;item...]`
//! Binary: little-endian framed records, magic `AKPT` — about 6x smaller
//! and 10x faster to load for the 1M-request evaluation traces. Two
//! versions share the header layout (DESIGN.md §10.2):
//!
//! * **v1 (flat)** — the header's `n_reqs` records back to back;
//! * **v2 (chunked)** — records grouped into length-prefixed frames
//!   (`u32` record count per frame), so a reader can pull one bounded
//!   chunk at a time ([`BinaryStreamSource`]) and a writer can emit a
//!   trace it never holds ([`write_binary_chunked_from`]).
//!
//! [`BinaryStreamSource`]: super::stream::BinaryStreamSource
//!
//! Every CSV row error carries the 1-based line number *and* the row's
//! starting byte offset, so a bad row in a multi-gigabyte dump can be
//! located with `dd`/`tail -c` instead of a line-counting pass.

use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::model::{Request, Trace};
use super::stream::{MemorySource, TraceSource};

const MAGIC: &[u8; 4] = b"AKPT";
/// Flat record layout (the original format).
pub(crate) const VERSION_FLAT: u32 = 1;
/// Chunk-framed layout ([`write_binary_chunked`]).
pub(crate) const VERSION_CHUNKED: u32 = 2;

/// Write a trace as CSV (with a `#` header carrying metadata).
pub fn write_csv(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "# akpc-trace v1 name={} n_items={} n_servers={}",
        trace.name, trace.n_items, trace.n_servers
    )?;
    for r in &trace.requests {
        let items = r
            .items
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(";");
        writeln!(w, "{},{},{}", r.time, r.server, items)?;
    }
    Ok(())
}

/// Parse the `#`-prefixed metadata header tokens.
/// Returns `(name, n_items, n_servers)` — each present only if its
/// `key=` token appeared.
pub(crate) fn parse_csv_header(
    hdr: &str,
    lineno: usize,
    byte_off: u64,
) -> anyhow::Result<(Option<String>, Option<u32>, Option<u32>)> {
    let (mut name, mut n_items, mut n_servers) = (None, None, None);
    for tok in hdr.split_whitespace() {
        if let Some(v) = tok.strip_prefix("name=") {
            name = Some(v.to_string());
        } else if let Some(v) = tok.strip_prefix("n_items=") {
            n_items = Some(v.parse().map_err(|e| {
                anyhow::anyhow!("line {lineno} (byte {byte_off}): bad n_items `{v}`: {e}")
            })?);
        } else if let Some(v) = tok.strip_prefix("n_servers=") {
            n_servers = Some(v.parse().map_err(|e| {
                anyhow::anyhow!("line {lineno} (byte {byte_off}): bad n_servers `{v}`: {e}")
            })?);
        }
    }
    Ok((name, n_items, n_servers))
}

/// Parse one `time,server,item[;item...]` data row. When `n_items > 0`
/// every item id is validated against it. Errors carry the 1-based line
/// number and the row's starting byte offset.
pub(crate) fn parse_csv_data_row(
    line: &str,
    lineno: usize,
    byte_off: u64,
    n_items: u32,
) -> anyhow::Result<Request> {
    let mut parts = line.splitn(3, ',');
    let time: f64 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {lineno} (byte {byte_off}): missing time"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("line {lineno} (byte {byte_off}): bad time: {e}"))?;
    let server: u32 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {lineno} (byte {byte_off}): missing server"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("line {lineno} (byte {byte_off}): bad server: {e}"))?;
    let items_field = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("line {lineno} (byte {byte_off}): missing items"))?;
    anyhow::ensure!(
        !items_field.is_empty(),
        "line {lineno} (byte {byte_off}): empty item list"
    );
    let items: Vec<u32> = items_field
        .split(';')
        .map(|s| {
            s.parse::<u32>().map_err(|e| {
                anyhow::anyhow!("line {lineno} (byte {byte_off}): bad item `{s}`: {e}")
            })
        })
        .collect::<anyhow::Result<_>>()?;
    if n_items > 0 {
        if let Some(&bad) = items.iter().find(|&&d| d >= n_items) {
            anyhow::bail!(
                "line {lineno} (byte {byte_off}): item {bad} out of range \
                 (header n_items={n_items})"
            );
        }
    }
    Ok(Request::new(items, server, time))
}

/// Read a CSV trace written by [`write_csv`].
///
/// Malformed rows are rejected with their 1-based line number and byte
/// offset; empty item lists are errors, and when the `#` header carries
/// `n_items=`, every item id is validated against it. Header-less files
/// are accepted for legacy compatibility (the universe shape stays 0 —
/// the streaming reader
/// [`CsvStreamSource`](super::stream::CsvStreamSource) is stricter
/// because it must know the shape up front).
pub fn read_csv(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut trace = Trace::default();
    let mut line = String::new();
    let (mut lineno, mut byte_off) = (0usize, 0u64);
    loop {
        line.clear();
        let n = r.read_line(&mut line)?;
        if n == 0 {
            break;
        }
        lineno += 1;
        let start = byte_off;
        byte_off += n as u64;
        let text = line.trim_end_matches(['\n', '\r']);
        if text.is_empty() {
            continue;
        }
        if let Some(hdr) = text.strip_prefix('#') {
            let (name, n_items, n_servers) = parse_csv_header(hdr, lineno, start)?;
            if let Some(v) = name {
                trace.name = v;
            }
            if let Some(v) = n_items {
                trace.n_items = v;
            }
            if let Some(v) = n_servers {
                trace.n_servers = v;
            }
            continue;
        }
        trace
            .requests
            .push(parse_csv_data_row(text, lineno, start, trace.n_items)?);
    }
    Ok(trace)
}

/// Ingest an external "Kaggle-style" request dump as a [`Trace`] — the
/// adapter the scenario engine uses for real-dataset phases (DESIGN.md
/// §7.4).
///
/// Expected shape: a comma-separated file whose first non-empty line is a
/// header naming the columns. Recognized column names (case-insensitive):
///
/// * time:   `time`, `timestamp`, `t`, `ts`
/// * server: `server`, `server_id`, `ess`, `region`, `user_id`, `user`
/// * items:  `item`, `item_id`, `items`, `track_id`, `movie_id`, `title_id`
///
/// The item cell may hold several `;`-separated ids. A column whose
/// values all parse as `u32` keeps its numeric ids; otherwise the whole
/// column is interned to dense indices in first-seen order (all-or-
/// nothing per column, so a mixed column can never alias an interned id
/// onto a literal numeric one). Rows are sorted by `(time, server)`
/// (stable), rows with identical `(time, server)` merge into one
/// multi-item request, and `n_items` / `n_servers` are inferred from the
/// data.
///
/// This reader is **inherently materializing** (DESIGN.md §10.4): the
/// all-or-nothing id interning and the global `(time, server)` sort both
/// need the whole file, so there is no streaming form — wrap the result
/// in a [`MemorySource`] to feed the streaming drivers.
/// Split one CSV row on commas, honoring double-quoted fields (commas
/// inside `"..."` do not separate; `""` inside a quoted field is an
/// escaped quote). Cells come back trimmed and unquoted.
fn split_csv_row(line: &str) -> Vec<String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => cells.push(std::mem::take(&mut cur).trim().to_string()),
            c => cur.push(c),
        }
    }
    cells.push(cur.trim().to_string());
    cells
}

pub fn read_external_csv(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let path = path.as_ref();
    // One read_line pass tracking (lineno, byte offset) — only the
    // parsed `rows` stay resident (the raw text does not; the function
    // is "materializing" in the §10.4 sense because of the id-interning
    // and sort phases below, not because of the file read).
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut line = String::new();
    let (mut lineno, mut byte_off) = (0usize, 0u64);
    let (mut time_col, mut server_col, mut item_col) = (None, None, None);
    let mut header_found = false;
    let mut rows: Vec<(f64, String, Vec<String>)> = Vec::new();
    loop {
        line.clear();
        let n = r.read_line(&mut line)?;
        if n == 0 {
            break;
        }
        lineno += 1;
        let start = byte_off;
        byte_off += n as u64;
        let text = line.trim_end_matches(['\n', '\r']);
        if text.trim().is_empty() {
            continue;
        }

        if !header_found {
            // Locate + parse the header row.
            for (col, name) in split_csv_row(text).into_iter().enumerate() {
                match name.to_ascii_lowercase().as_str() {
                    "time" | "timestamp" | "t" | "ts" => time_col = Some(col),
                    "server" | "server_id" | "ess" | "region" | "user_id" | "user" => {
                        server_col = Some(col)
                    }
                    "item" | "item_id" | "items" | "track_id" | "movie_id" | "title_id" => {
                        item_col = Some(col)
                    }
                    _ => {}
                }
            }
            anyhow::ensure!(
                time_col.is_some() && server_col.is_some() && item_col.is_some(),
                "line {lineno} (byte {start}): header must name time/server/item \
                 columns (got `{text}`)"
            );
            header_found = true;
            continue;
        }

        // Data row: collect raw cells (id resolution is per-column,
        // all-or-nothing, so it must wait until the whole file is read).
        let cells = split_csv_row(text);
        let cell = |col: Option<usize>, what: &str| -> anyhow::Result<&str> {
            cells
                .get(col.expect("header checked"))
                .map(|s| s.as_str())
                .ok_or_else(|| {
                    anyhow::anyhow!("line {lineno} (byte {start}): missing {what} column")
                })
        };
        let time: f64 = cell(time_col, "time")?.parse().map_err(|e| {
            anyhow::anyhow!("line {lineno} (byte {start}): bad time: {e}")
        })?;
        anyhow::ensure!(
            time.is_finite(),
            "line {lineno} (byte {start}): non-finite timestamp"
        );
        let server = cell(server_col, "server")?.to_string();
        anyhow::ensure!(
            !server.is_empty(),
            "line {lineno} (byte {start}): empty server id"
        );
        let item_cell = cell(item_col, "item")?;
        anyhow::ensure!(
            !item_cell.is_empty(),
            "line {lineno} (byte {start}): empty item list"
        );
        let items: Vec<String> = item_cell
            .split(';')
            .map(|s| {
                let s = s.trim();
                anyhow::ensure!(
                    !s.is_empty(),
                    "line {lineno} (byte {start}): empty item in `{item_cell}`"
                );
                Ok(s.to_string())
            })
            .collect::<anyhow::Result<_>>()?;
        rows.push((time, server, items));
    }
    anyhow::ensure!(header_found, "empty file: no header row");
    anyhow::ensure!(!rows.is_empty(), "no data rows in external trace");

    // Per-column id resolution: numeric ids pass through only when the
    // *entire* column is numeric; otherwise every value is interned in
    // first-seen (file-order) order. Mixing the two in one column would
    // let a dense interned index alias a literal numeric id.
    let resolve = |numeric: bool, map: &mut std::collections::HashMap<String, u32>, raw: &str| {
        if numeric {
            raw.parse::<u32>().expect("checked numeric column")
        } else {
            let next = map.len() as u32;
            *map.entry(raw.to_string()).or_insert(next)
        }
    };
    let servers_numeric = rows.iter().all(|(_, s, _)| s.parse::<u32>().is_ok());
    let items_numeric = rows
        .iter()
        .all(|(_, _, items)| items.iter().all(|d| d.parse::<u32>().is_ok()));
    let mut item_ids = std::collections::HashMap::new();
    let mut server_ids = std::collections::HashMap::new();
    let mut resolved: Vec<(f64, u32, Vec<u32>)> = rows
        .into_iter()
        .map(|(time, server, items)| {
            let server = resolve(servers_numeric, &mut server_ids, &server);
            let items = items
                .iter()
                .map(|d| resolve(items_numeric, &mut item_ids, d))
                .collect();
            (time, server, items)
        })
        .collect();

    // Stable (time, server) sort, then merge identical (time, server)
    // rows into one request (per-item dump formats emit one row per
    // item); sorting by server within a timestamp makes equal keys
    // adjacent even when another server's row lands between them. The
    // time key uses `total_cmp` (akpc-lint L1): the old
    // `partial_cmp(..).unwrap_or(Equal)` fallback broke strict weak
    // ordering on NaN timestamps, which `sort_by` may answer with an
    // arbitrary permutation.
    resolved.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut requests: Vec<Request> = Vec::with_capacity(resolved.len());
    for (time, server, items) in resolved {
        match requests.last_mut() {
            Some(prev) if prev.time == time && prev.server == server => {
                let mut merged = prev.items.clone();
                merged.extend(items);
                *prev = Request::new(merged, server, time);
            }
            _ => requests.push(Request::new(items, server, time)),
        }
    }

    let n_items = 1 + requests
        .iter()
        .flat_map(|r| r.items.iter().copied())
        .max()
        .unwrap_or(0);
    let n_servers = 1 + requests.iter().map(|r| r.server).max().unwrap_or(0);
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("external")
        .to_string();
    let trace = Trace {
        requests,
        n_items,
        n_servers,
        name,
    };
    trace.validate()?;
    Ok(trace)
}

// ---------------------------------------------------------------------
// Binary format: shared byte-level helpers
// ---------------------------------------------------------------------

/// `read_exact` with EOF mapped to the canonical truncation error.
fn fill(r: &mut impl Read, buf: &mut [u8]) -> anyhow::Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            anyhow::anyhow!("truncated trace file")
        } else {
            anyhow::Error::from(e)
        }
    })
}

fn read_u16(r: &mut impl Read) -> anyhow::Result<u16> {
    let mut b = [0u8; 2];
    fill(r, &mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    fill(r, &mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    fill(r, &mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64(r: &mut impl Read) -> anyhow::Result<f64> {
    let mut b = [0u8; 8];
    fill(r, &mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// The parsed fixed header both binary versions share.
#[derive(Debug, Clone)]
pub(crate) struct BinaryHeader {
    pub version: u32,
    pub n_items: u32,
    pub n_servers: u32,
    pub name: String,
    pub n_reqs: u64,
}

/// Read and validate the versioned header (magic, version, universe
/// shape, name, request count). Corruption errors name what was
/// expected so a mis-pointed path fails with a self-explaining message.
pub(crate) fn read_binary_header(r: &mut impl Read) -> anyhow::Result<BinaryHeader> {
    let mut magic = [0u8; 4];
    fill(r, &mut magic)?;
    anyhow::ensure!(
        &magic == MAGIC,
        "bad magic `{}`: not an `AKPT` binary trace file",
        String::from_utf8_lossy(&magic).escape_default()
    );
    let version = read_u32(r)?;
    anyhow::ensure!(
        version == VERSION_FLAT || version == VERSION_CHUNKED,
        "unsupported version {version} (supported: {VERSION_FLAT} flat, \
         {VERSION_CHUNKED} chunked)"
    );
    let n_items = read_u32(r)?;
    let n_servers = read_u32(r)?;
    let name_len = read_u32(r)? as usize;
    anyhow::ensure!(
        name_len <= 1 << 16,
        "corrupt header: name length {name_len} exceeds 64KiB"
    );
    let mut name_bytes = vec![0u8; name_len];
    fill(r, &mut name_bytes)?;
    let name = String::from_utf8(name_bytes)?;
    let n_reqs = read_u64(r)?;
    Ok(BinaryHeader {
        version,
        n_items,
        n_servers,
        name,
        n_reqs,
    })
}

/// Read one v2 frame header: the record count of the next chunk.
pub(crate) fn read_frame_header(r: &mut impl Read) -> anyhow::Result<u32> {
    read_u32(r)
}

/// Read one `(time, server, k, items...)` record (identical in v1/v2).
pub(crate) fn read_binary_record(r: &mut impl Read) -> anyhow::Result<Request> {
    let time = read_f64(r)?;
    let server = read_u32(r)?;
    let k = read_u16(r)? as usize;
    let mut items = Vec::with_capacity(k);
    for _ in 0..k {
        items.push(read_u32(r)?);
    }
    Ok(Request {
        items,
        server,
        time,
    })
}

fn write_record(w: &mut impl Write, r: &Request) -> anyhow::Result<()> {
    anyhow::ensure!(
        r.items.len() <= u16::MAX as usize,
        "request has {} items (format limit {})",
        r.items.len(),
        u16::MAX
    );
    w.write_all(&r.time.to_le_bytes())?;
    w.write_all(&r.server.to_le_bytes())?;
    w.write_all(&(r.items.len() as u16).to_le_bytes())?;
    for &d in &r.items {
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

fn write_header(
    w: &mut impl Write,
    version: u32,
    n_items: u32,
    n_servers: u32,
    name: &str,
    n_reqs: u64,
) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&n_items.to_le_bytes())?;
    w.write_all(&n_servers.to_le_bytes())?;
    let name = name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&n_reqs.to_le_bytes())?;
    Ok(())
}

/// Byte offset of the `n_reqs` field ([`write_binary_chunked_from`]
/// patches it after streaming).
fn n_reqs_offset(name: &str) -> u64 {
    (4 + 4 + 4 + 4 + 4 + name.len()) as u64
}

/// Write the compact binary form (flat v1 layout).
pub fn write_binary(trace: &Trace, path: impl AsRef<Path>) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    write_header(
        &mut w,
        VERSION_FLAT,
        trace.n_items,
        trace.n_servers,
        &trace.name,
        trace.requests.len() as u64,
    )?;
    for r in &trace.requests {
        write_record(&mut w, r)?;
    }
    w.flush()?;
    Ok(())
}

/// Write the chunk-framed v2 layout from an in-memory trace, `chunk_len`
/// requests per frame.
pub fn write_binary_chunked(
    trace: &Trace,
    path: impl AsRef<Path>,
    chunk_len: usize,
) -> anyhow::Result<()> {
    let mut src = MemorySource::new(trace).with_chunk_len(chunk_len);
    write_binary_chunked_from(&mut src, path)?;
    Ok(())
}

/// Stream a [`TraceSource`] straight to a chunk-framed v2 file — the
/// writer never holds more than one chunk (`akpc gen-trace --chunked`
/// produces 10⁸-request traces through here). Each pulled chunk becomes
/// one frame; the header's `n_reqs` is patched in after the stream ends,
/// so sources with unknown length (`est_len: None`) work too. Returns
/// the number of requests written.
pub fn write_binary_chunked_from(
    source: &mut dyn TraceSource,
    path: impl AsRef<Path>,
) -> anyhow::Result<u64> {
    let meta = source.meta().clone();
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    write_header(
        &mut w,
        VERSION_CHUNKED,
        meta.n_items,
        meta.n_servers,
        &meta.name,
        0, // patched below once the true count is known
    )?;
    let mut total = 0u64;
    let mut buf = Vec::new();
    while source.next_chunk(&mut buf)? {
        w.write_all(&(buf.len() as u32).to_le_bytes())?;
        for r in &buf {
            write_record(&mut w, r)?;
        }
        total += buf.len() as u64;
    }
    w.flush()?;
    let f = w.get_mut();
    f.seek(SeekFrom::Start(n_reqs_offset(&meta.name)))?;
    f.write_all(&total.to_le_bytes())?;
    f.flush()?;
    Ok(total)
}

/// Read the compact binary form (v1 flat or v2 chunked) into memory.
/// For bounded-memory consumption use
/// [`BinaryStreamSource`](super::stream::BinaryStreamSource) instead.
pub fn read_binary(path: impl AsRef<Path>) -> anyhow::Result<Trace> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let hdr = read_binary_header(&mut r)?;
    // Cap the pre-allocation so a corrupt count cannot OOM before the
    // truncation check fires.
    let mut requests = Vec::with_capacity((hdr.n_reqs as usize).min(1 << 22));
    match hdr.version {
        VERSION_FLAT => {
            for _ in 0..hdr.n_reqs {
                requests.push(read_binary_record(&mut r)?);
            }
        }
        _ => {
            let mut seen = 0u64;
            while seen < hdr.n_reqs {
                let n = read_frame_header(&mut r)? as u64;
                anyhow::ensure!(
                    n >= 1 && n <= hdr.n_reqs - seen,
                    "corrupt chunk frame: {n} records framed, {} remaining",
                    hdr.n_reqs - seen
                );
                for _ in 0..n {
                    requests.push(read_binary_record(&mut r)?);
                }
                seen += n;
            }
        }
    }
    Ok(Trace {
        requests,
        n_items: hdr.n_items,
        n_servers: hdr.n_servers,
        name: hdr.name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;
    use crate::util::tempdir::TempDir;

    #[test]
    fn csv_roundtrip() {
        let t = netflix_like(30, 10, 500, 1);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.csv");
        write_csv(&t, &p).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.n_items, t.n_items);
        assert_eq!(back.n_servers, t.n_servers);
        assert_eq!(back.name, t.name);
        assert_eq!(back.requests.len(), t.requests.len());
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert_eq!(a.items, b.items);
            assert_eq!(a.server, b.server);
            assert!((a.time - b.time).abs() < 1e-9);
        }
    }

    #[test]
    fn binary_roundtrip_exact() {
        let t = netflix_like(30, 10, 500, 2);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.bin");
        write_binary(&t, &p).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.requests, t.requests); // bit-exact times
        back.validate().unwrap();
    }

    #[test]
    fn chunked_binary_roundtrip_exact() {
        let t = netflix_like(30, 10, 500, 4);
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("t.akpt");
        write_binary_chunked(&t, &p, 64).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(back.requests, t.requests);
        assert_eq!(back.n_items, t.n_items);
        assert_eq!(back.n_servers, t.n_servers);
        assert_eq!(back.name, t.name);
    }

    #[test]
    fn csv_errors_carry_line_numbers_and_byte_offsets() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("bad.csv");
        let header = "# akpc-trace v1 n_items=10 n_servers=2\n";
        let row2 = "0.5,0,1;2\n";
        std::fs::write(&p, format!("{header}{row2}1.0,zero,3\n")).unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 3"), "error lacks line number: {err}");
        let expect_off = header.len() + row2.len();
        assert!(
            err.contains(&format!("byte {expect_off}")),
            "error lacks byte offset {expect_off}: {err}"
        );

        std::fs::write(&p, "0.5,0,\n").unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 1") && err.contains("empty item list"), "{err}");
        assert!(err.contains("byte 0"), "{err}");
    }

    #[test]
    fn csv_validates_items_against_header() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("range.csv");
        std::fs::write(&p, "# akpc-trace v1 n_items=4 n_servers=2\n0.5,0,1;9\n").unwrap();
        let err = read_csv(&p).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("out of range"), "{err}");
        // Without a header the same row is accepted (range unknown).
        std::fs::write(&p, "0.5,0,1;9\n").unwrap();
        assert_eq!(read_csv(&p).unwrap().requests.len(), 1);
    }

    #[test]
    fn external_csv_ingests_kaggle_shape() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("kaggle.csv");
        // Out-of-order times, string ids, one row per item.
        std::fs::write(
            &p,
            "timestamp,user_id,track_id\n\
             3.0,u1,songB\n\
             1.0,u0,songA\n\
             1.0,u0,songB\n\
             2.5,u1,songC;songA\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        t.validate().unwrap();
        assert_eq!(t.n_servers, 2);
        assert_eq!(t.n_items, 3);
        // Rows at (1.0, u0) merged into one request.
        assert_eq!(t.requests.len(), 3);
        assert_eq!(t.requests[0].items.len(), 2);
        assert_eq!(t.name, "kaggle");
        // Deterministic interning: re-reading yields the identical trace.
        assert_eq!(read_external_csv(&p).unwrap().requests, t.requests);
    }

    #[test]
    fn external_csv_merges_interleaved_and_interns_mixed_columns() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("mixed.csv");
        // Coarse timestamps interleave servers; the item column mixes a
        // literal numeric id with names, so the whole column is interned
        // (numeric passthrough would alias "0" with the first interned
        // name).
        std::fs::write(
            &p,
            "time,server,item\n\
             1.0,3,songA\n\
             1.0,7,songX\n\
             1.0,3,0\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        // Both server-3 rows merged despite the interleaved server-7 row.
        assert_eq!(t.requests.len(), 2);
        assert_eq!(t.requests[0].server, 3); // numeric column passes through
        assert_eq!(t.requests[0].items, vec![0, 2]); // songA=0, songX=1, "0"=2
        assert_eq!(t.n_items, 3);
        assert_eq!(t.n_servers, 8);
    }

    #[test]
    fn external_csv_handles_quoted_commas_and_rejects_empty_tokens() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("quoted.csv");
        std::fs::write(
            &p,
            "time,user_id,track_id\n\
             1.0,u0,\"Song, Pt. 2\"\n\
             2.0,u0,\"Song, Pt. 2\"\n\
             3.0,u1,\"He said \"\"hi\"\"\"\n",
        )
        .unwrap();
        let t = read_external_csv(&p).unwrap();
        // The quoted comma does not split: one title, re-seen = same id.
        assert_eq!(t.n_items, 2);
        assert_eq!(t.requests[0].items, t.requests[1].items);

        let bad = dir.file("empty-token.csv");
        std::fs::write(&bad, "time,user_id,track_id\n1.0,u0,12;;34\n").unwrap();
        let err = read_external_csv(&bad).unwrap_err().to_string();
        assert!(err.contains("line 2") && err.contains("empty item"), "{err}");
        // Byte offset of the bad row = the header line's length.
        assert!(err.contains("byte 22"), "{err}");
    }

    #[test]
    fn external_csv_rejects_missing_columns() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("nohdr.csv");
        std::fs::write(&p, "a,b\n1,2\n").unwrap();
        assert!(read_external_csv(&p).is_err());
        let empty = dir.file("empty.csv");
        std::fs::write(&empty, "").unwrap();
        assert!(read_external_csv(&empty).is_err());
    }

    #[test]
    fn binary_rejects_garbage_naming_expected_magic() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("bad.bin");
        std::fs::write(&p, b"not a trace").unwrap();
        let err = read_binary(&p).unwrap_err().to_string();
        assert!(err.contains("AKPT"), "magic error should name the format: {err}");
    }

    #[test]
    fn binary_rejects_unsupported_version_and_corrupt_frames() {
        let dir = TempDir::new("io").unwrap();
        let p = dir.file("v9.bin");
        let mut bytes = b"AKPT".to_vec();
        bytes.extend_from_slice(&9u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary(&p).unwrap_err().to_string();
        assert!(err.contains("unsupported version 9"), "{err}");

        // A v2 frame claiming more records than the header leaves.
        let t = netflix_like(10, 5, 20, 1);
        let p2 = dir.file("frame.akpt");
        write_binary_chunked(&t, &p2, 20).unwrap();
        let mut data = std::fs::read(&p2).unwrap();
        let frame_off = (4 + 4 + 4 + 4 + 4 + t.name.len() + 8) as usize;
        data[frame_off..frame_off + 4].copy_from_slice(&999u32.to_le_bytes());
        std::fs::write(&p2, &data).unwrap();
        let err = read_binary(&p2).unwrap_err().to_string();
        assert!(err.contains("corrupt chunk frame"), "{err}");
    }

    #[test]
    fn binary_rejects_truncated() {
        let t = netflix_like(10, 5, 100, 3);
        let dir = TempDir::new("io").unwrap();
        for (file, chunked) in [("t.bin", false), ("t.akpt", true)] {
            let p = dir.file(file);
            if chunked {
                write_binary_chunked(&t, &p, 32).unwrap();
            } else {
                write_binary(&t, &p).unwrap();
            }
            let data = std::fs::read(&p).unwrap();
            std::fs::write(&p, &data[..data.len() / 2]).unwrap();
            let err = read_binary(&p).unwrap_err().to_string();
            assert!(err.contains("truncated"), "{err}");
        }
    }
}
