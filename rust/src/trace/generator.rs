//! Synthetic trace generators standing in for the paper's Netflix and
//! Spotify Kaggle traces (unavailable in this environment — DESIGN.md §2).
//!
//! What the AKPC algorithm consumes is only the stream of
//! `⟨item-set, server, time⟩` tuples; the properties that drive every
//! result in the paper's evaluation are:
//!
//! 1. **Zipfian item popularity** (a small hot set dominates),
//! 2. **strong co-access structure**: requests draw from latent *bundles*
//!    (movie + trailer + stills; playlist neighbours) so that bundle
//!    members are co-requested far above chance,
//! 3. **temporal locality**: hot items are re-accessed within ~Δt at hot
//!    servers, making caching decisions non-trivial,
//! 4. **churn** (Spotify): bundle popularity rotates over time, stressing
//!    the incremental clique-adjustment path (Algorithm 4).
//!
//! The two presets differ exactly where the paper's datasets differ:
//! Netflix-like = steep Zipf, stable mid-size bundles; Spotify-like =
//! flatter Zipf, larger playlist-style bundles, periodic churn.

use super::model::{Request, Trace};
use crate::util::{Rng, ZipfSampler};

/// Which preset a generated trace follows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    Netflix,
    Spotify,
}

impl TraceKind {
    /// The provenance name generated traces carry (`Trace::name`).
    pub fn trace_name(&self) -> &'static str {
        match self {
            TraceKind::Netflix => "netflix-like",
            TraceKind::Spotify => "spotify-like",
        }
    }
}

/// All knobs of the synthetic generator.
#[derive(Debug, Clone)]
pub struct GeneratorParams {
    pub n_items: u32,
    pub n_servers: u32,
    pub n_requests: usize,
    /// Maximum items per request (paper d_max).
    pub d_max: usize,
    /// Zipf exponent over bundle popularity.
    pub zipf_bundles: f64,
    /// Zipf exponent over server popularity.
    pub zipf_servers: f64,
    /// Latent bundle size range (inclusive).
    pub bundle_min: usize,
    pub bundle_max: usize,
    /// Probability a requested item is replaced by a uniform random item
    /// (cross-bundle noise).
    pub noise: f64,
    /// Global request arrival rate per Δt unit (Poisson).
    pub req_rate: f64,
    /// Probability a session continues with another burst (geometric
    /// session length; 0 = single-request sessions).
    pub p_continue: f64,
    /// Maximum bursts (requests) per session.
    pub session_max: usize,
    /// Rotate bundle popularity every this many requests (0 = never).
    pub churn_every: usize,
    /// How many rank positions the popularity rotates per churn event.
    pub churn_shift: usize,
    pub seed: u64,
}

impl GeneratorParams {
    /// Netflix-like preset: steep popularity, stable bundles (a title's
    /// assets do not change), moderate bundle sizes.
    pub fn netflix(n_items: u32, n_servers: u32, n_requests: usize) -> Self {
        Self {
            n_items,
            n_servers,
            n_requests,
            d_max: 5,
            // n is already the dataset's top-10% hot slice (§V-A), so
            // popularity *within* the universe is moderately skewed.
            zipf_bundles: 0.7,
            zipf_servers: 0.9,
            bundle_min: 3,
            bundle_max: 5,
            noise: 0.02,
            req_rate: 2000.0,
            // Sessions walk (nearly) the whole bundle: the paper's premise
            // is highly predictable co-access ("over 93% of human behavior
            // ... is predictable" — §I), the regime where packed caching
            // pays off at alpha = 0.8.
            p_continue: 0.92,
            session_max: 8,
            churn_every: 0,
            churn_shift: 0,
            seed: 0x4E46_4C58, // "NFLX"
        }
    }

    /// Spotify-like preset: flatter popularity, larger playlist-style
    /// bundles, periodic chart churn.
    pub fn spotify(n_items: u32, n_servers: u32, n_requests: usize) -> Self {
        Self {
            n_items,
            n_servers,
            n_requests,
            d_max: 5,
            zipf_bundles: 0.55,
            zipf_servers: 0.7,
            bundle_min: 3,
            bundle_max: 6,
            noise: 0.04,
            req_rate: 2000.0,
            p_continue: 0.88,
            session_max: 9,
            churn_every: 50_000,
            churn_shift: 3,
            seed: 0x5350_4F54, // "SPOT"
        }
    }
}

impl GeneratorParams {
    /// Check every knob before sampling starts, so a bad configuration
    /// fails with a message at the API boundary instead of panicking deep
    /// in the Zipf sampler or the bundle partitioner.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_items >= 1, "n_items must be >= 1");
        anyhow::ensure!(self.n_servers >= 1, "n_servers must be >= 1");
        anyhow::ensure!(self.d_max >= 1, "d_max must be >= 1");
        anyhow::ensure!(
            self.zipf_bundles > 0.0,
            "zipf_bundles must be positive (got {})",
            self.zipf_bundles
        );
        anyhow::ensure!(
            self.zipf_servers > 0.0,
            "zipf_servers must be positive (got {})",
            self.zipf_servers
        );
        anyhow::ensure!(self.bundle_min >= 1, "bundle_min must be >= 1");
        anyhow::ensure!(
            self.bundle_max >= self.bundle_min,
            "bundle_max {} < bundle_min {}",
            self.bundle_max,
            self.bundle_min
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.noise),
            "noise must be in [0,1]"
        );
        anyhow::ensure!(self.req_rate > 0.0, "req_rate must be positive");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.p_continue),
            "p_continue must be in [0,1)"
        );
        anyhow::ensure!(self.session_max >= 1, "session_max must be >= 1");
        Ok(())
    }
}

/// Latent ground-truth bundles: a partition of the item universe into
/// groups of co-accessed items (what the CRM/clique machinery must
/// rediscover online).
#[derive(Debug, Clone)]
pub struct Bundles {
    /// `bundles[b]` = item ids of bundle `b`.
    pub groups: Vec<Vec<u32>>,
}

impl Bundles {
    fn generate(params: &GeneratorParams, rng: &mut Rng) -> Self {
        let mut ids: Vec<u32> = (0..params.n_items).collect();
        rng.shuffle(&mut ids);
        let mut groups = Vec::new();
        let mut i = 0usize;
        while i < ids.len() {
            let want = rng.range(params.bundle_min, params.bundle_max);
            let take = want.min(ids.len() - i);
            groups.push({
                let mut g = ids[i..i + take].to_vec();
                g.sort_unstable();
                g
            });
            i += take;
        }
        Self { groups }
    }
}

/// Generate a trace from explicit parameters, validating them first.
/// This is the fallible entry the CLI and the scenario compiler use;
/// [`generate`] panics on the same conditions for infallible callers.
pub fn try_generate(params: &GeneratorParams, kind: TraceKind) -> anyhow::Result<Trace> {
    params.validate()?;
    Ok(generate_unchecked(params, kind))
}

/// Generate a trace from explicit parameters.
///
/// Panics if `params` is invalid — use [`try_generate`] to get an error
/// instead.
pub fn generate(params: &GeneratorParams, kind: TraceKind) -> Trace {
    params
        .validate()
        .unwrap_or_else(|e| panic!("invalid GeneratorParams: {e}"));
    generate_unchecked(params, kind)
}

fn generate_unchecked(params: &GeneratorParams, kind: TraceKind) -> Trace {
    let mut gen = TraceGenerator::new_unchecked(params, kind);
    let mut requests = Vec::with_capacity(params.n_requests);
    while let Some(r) = gen.next_request() {
        requests.push(r);
    }
    Trace {
        requests,
        n_items: params.n_items,
        n_servers: params.n_servers,
        name: kind.trace_name().into(),
    }
}

/// Session state: a user browses one bundle at one server through a
/// short sequence of requests (the paper's motivating pattern — reels /
/// brief news: "accessing a news article often leads to viewing related
/// content shortly after"). The session *walks* the bundle's items
/// without replacement, mostly one item per view, occasionally a small
/// multi-item request (article + its pictures). This sequential
/// co-access within Δt at one server is exactly what makes anticipatory
/// packed caching profitable.
struct Session {
    server: u32,
    /// Bundle items not yet viewed, in viewing order.
    remaining: Vec<u32>,
    bursts_left: usize,
}

/// Resumable request generator — the streaming form of [`generate`].
///
/// Holds the full sampling state (RNG, latent bundles, churn offset, the
/// open session) between calls, so requests can be pulled one at a time
/// or chunk by chunk ([`crate::trace::stream::GeneratorSource`]) without
/// ever materializing the trace. Draining a fresh generator yields the
/// request stream of [`generate`] with the same parameters, bit for bit
/// (pinned by a unit test below).
pub struct TraceGenerator {
    params: GeneratorParams,
    kind: TraceKind,
    rng: Rng,
    bundles: Bundles,
    bundle_zipf: ZipfSampler,
    server_zipf: ZipfSampler,
    /// Popularity rotation (churn): bundle rank r maps to bundle
    /// (r + offset) % n_bundles.
    churn_offset: usize,
    t: f64,
    session: Option<Session>,
    /// Requests generated so far (the loop index of the batch form).
    emitted: usize,
}

impl TraceGenerator {
    /// Validate `params` and build a generator positioned at request 0.
    pub fn new(params: &GeneratorParams, kind: TraceKind) -> anyhow::Result<Self> {
        params.validate()?;
        Ok(Self::new_unchecked(params, kind))
    }

    fn new_unchecked(params: &GeneratorParams, kind: TraceKind) -> Self {
        let mut rng = Rng::new(params.seed);
        let bundles = Bundles::generate(params, &mut rng);
        let n_bundles = bundles.groups.len();
        let bundle_zipf = ZipfSampler::new(n_bundles, params.zipf_bundles);
        let server_zipf = ZipfSampler::new(params.n_servers as usize, params.zipf_servers);
        Self {
            params: params.clone(),
            kind,
            rng,
            bundles,
            bundle_zipf,
            server_zipf,
            churn_offset: 0,
            t: 0.0,
            session: None,
            emitted: 0,
        }
    }

    /// The preset this generator follows.
    pub fn kind(&self) -> TraceKind {
        self.kind
    }

    /// The generator's parameter set.
    pub fn params(&self) -> &GeneratorParams {
        &self.params
    }

    /// Requests not yet emitted.
    pub fn remaining(&self) -> usize {
        self.params.n_requests - self.emitted
    }

    /// Emit the next request, or `None` once `n_requests` have been
    /// produced.
    pub fn next_request(&mut self) -> Option<Request> {
        if self.emitted >= self.params.n_requests {
            return None;
        }
        // Scalar knobs copied out so `self.rng` can be borrowed mutably.
        let GeneratorParams {
            n_items,
            d_max,
            noise,
            req_rate,
            p_continue,
            session_max,
            churn_every,
            churn_shift,
            ..
        } = self.params;
        let n_bundles = self.bundles.groups.len();
        let i = self.emitted;
        if churn_every > 0 && i > 0 && i % churn_every == 0 {
            self.churn_offset = (self.churn_offset + churn_shift) % n_bundles;
            self.session = None;
        }
        self.t += self.rng.exp(1.0 / req_rate);

        let need_new = match &self.session {
            Some(s) => s.bursts_left == 0 || s.remaining.is_empty(),
            None => true,
        };
        if need_new {
            let rank = self.bundle_zipf.sample(&mut self.rng);
            let b = (rank + self.churn_offset) % n_bundles;
            let server = self.server_zipf.sample(&mut self.rng) as u32;
            let mut remaining = self.bundles.groups[b].clone();
            self.rng.shuffle(&mut remaining);
            let mut bursts = 1usize;
            while bursts < session_max && self.rng.chance(p_continue) {
                bursts += 1;
            }
            self.session = Some(Session {
                server,
                remaining,
                bursts_left: bursts,
            });
        }
        let s = self.session.as_mut().expect("session exists");
        s.bursts_left -= 1;

        // Burst size: usually 1 item, sometimes a small set.
        let mut k = 1usize;
        while k < d_max.min(s.remaining.len()) && self.rng.chance(0.25) {
            k += 1;
        }
        let mut items: Vec<u32> = s.remaining.drain(..k.min(s.remaining.len())).collect();
        let server = s.server;

        // Cross-bundle noise.
        for item in items.iter_mut() {
            if self.rng.chance(noise) {
                *item = self.rng.below(n_items as usize) as u32;
            }
        }

        self.emitted += 1;
        Some(Request::new(items, server, self.t))
    }
}

/// Netflix-like trace with Table-II shape defaults.
pub fn netflix_like(n_items: u32, n_servers: u32, n_requests: usize, seed: u64) -> Trace {
    let mut p = GeneratorParams::netflix(n_items, n_servers, n_requests);
    p.seed ^= seed;
    generate(&p, TraceKind::Netflix)
}

/// Spotify-like trace with Table-II shape defaults.
pub fn spotify_like(n_items: u32, n_servers: u32, n_requests: usize, seed: u64) -> Trace {
    let mut p = GeneratorParams::spotify(n_items, n_servers, n_requests);
    p.seed ^= seed;
    generate(&p, TraceKind::Spotify)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_trace() {
        let t = netflix_like(60, 600, 5_000, 1);
        assert_eq!(t.len(), 5_000);
        t.validate().unwrap();
    }

    #[test]
    fn deterministic_from_seed() {
        let a = netflix_like(60, 600, 1_000, 42);
        let b = netflix_like(60, 600, 1_000, 42);
        assert_eq!(a.requests, b.requests);
        let c = netflix_like(60, 600, 1_000, 43);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn respects_d_max() {
        let t = spotify_like(60, 600, 10_000, 2);
        assert!(t.requests.iter().all(|r| r.items.len() <= 5));
    }

    #[test]
    fn item_popularity_is_skewed() {
        let t = netflix_like(60, 600, 50_000, 3);
        let mut counts = vec![0usize; 60];
        for r in &t.requests {
            for &d in &r.items {
                counts[d as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(
            top10 as f64 > 0.35 * total as f64,
            "top-10 items carry {}/{total}",
            top10
        );
    }

    #[test]
    fn bundles_drive_coaccess() {
        // Items that share a bundle must be co-requested far above chance.
        let p = GeneratorParams::netflix(60, 10, 30_000);
        let mut rng = Rng::new(p.seed);
        let bundles = Bundles::generate(&p, &mut rng);
        let t = generate(&p, TraceKind::Netflix);

        let mut co = std::collections::HashMap::<(u32, u32), usize>::new();
        for r in &t.requests {
            for i in 0..r.items.len() {
                for j in (i + 1)..r.items.len() {
                    *co.entry((r.items[i], r.items[j])).or_default() += 1;
                }
            }
        }
        // Average co-count for within-bundle pairs vs a random cross pair.
        let mut within = 0usize;
        let mut n_within = 0usize;
        for g in &bundles.groups {
            for i in 0..g.len() {
                for j in (i + 1)..g.len() {
                    within += co.get(&(g[i], g[j])).copied().unwrap_or(0);
                    n_within += 1;
                }
            }
        }
        let total_co: usize = co.values().sum();
        let avg_within = within as f64 / n_within.max(1) as f64;
        let avg_all = total_co as f64 / co.len().max(1) as f64;
        assert!(
            avg_within > 2.0 * avg_all,
            "within {avg_within} vs overall {avg_all}"
        );
    }

    #[test]
    fn churn_rotates_popularity() {
        let mut p = GeneratorParams::spotify(100, 10, 60_000);
        p.churn_every = 10_000;
        p.churn_shift = 7;
        let t = generate(&p, TraceKind::Spotify);
        // Count item popularity in the first and last 10k requests — the
        // hot set must shift.
        let count = |reqs: &[Request]| {
            let mut c = vec![0usize; 100];
            for r in reqs {
                for &d in &r.items {
                    c[d as usize] += 1;
                }
            }
            c
        };
        let head = count(&t.requests[..10_000]);
        let tail = count(&t.requests[50_000..]);
        let top = |c: &[usize]| {
            let mut idx: Vec<usize> = (0..c.len()).collect();
            idx.sort_unstable_by(|&a, &b| c[b].cmp(&c[a]));
            idx[..10].to_vec()
        };
        let overlap = top(&head)
            .iter()
            .filter(|i| top(&tail).contains(i))
            .count();
        assert!(overlap < 10, "hot set did not move: overlap {overlap}");
    }

    #[test]
    fn validate_rejects_bad_params() {
        let good = GeneratorParams::netflix(60, 600, 100);
        good.validate().unwrap();
        for tweak in [
            |p: &mut GeneratorParams| p.n_items = 0,
            |p: &mut GeneratorParams| p.n_servers = 0,
            |p: &mut GeneratorParams| p.d_max = 0,
            |p: &mut GeneratorParams| p.zipf_bundles = 0.0,
            |p: &mut GeneratorParams| p.zipf_servers = -1.0,
            |p: &mut GeneratorParams| p.bundle_min = 0,
            |p: &mut GeneratorParams| p.bundle_max = 1,
            |p: &mut GeneratorParams| p.noise = 1.5,
            |p: &mut GeneratorParams| p.req_rate = 0.0,
            |p: &mut GeneratorParams| p.p_continue = 1.0,
            |p: &mut GeneratorParams| p.session_max = 0,
        ] {
            let mut p = good.clone();
            tweak(&mut p);
            assert!(p.validate().is_err(), "accepted bad params {p:?}");
            assert!(try_generate(&p, TraceKind::Netflix).is_err());
        }
    }

    #[test]
    fn resumable_generator_matches_batch_form() {
        // The streaming generator is the same sampler, restructured: a
        // full drain must be bit-identical to `generate`, and pulling
        // one request at a time must not disturb the stream.
        let mut p = GeneratorParams::spotify(60, 20, 5_000);
        p.churn_every = 1_000; // exercise the churn reset path too
        p.churn_shift = 3;
        let batch = generate(&p, TraceKind::Spotify);
        let mut gen = TraceGenerator::new(&p, TraceKind::Spotify).unwrap();
        assert_eq!(gen.remaining(), 5_000);
        assert_eq!(gen.kind(), TraceKind::Spotify);
        let mut streamed = Vec::new();
        while let Some(r) = gen.next_request() {
            streamed.push(r);
        }
        assert!(gen.next_request().is_none(), "exhausted generator yields None");
        assert_eq!(gen.remaining(), 0);
        assert_eq!(streamed, batch.requests);
    }

    #[test]
    fn try_generate_matches_generate() {
        let p = GeneratorParams::netflix(30, 10, 500);
        let a = try_generate(&p, TraceKind::Netflix).unwrap();
        let b = generate(&p, TraceKind::Netflix);
        assert_eq!(a.requests, b.requests);
    }

    #[test]
    fn time_is_monotone_and_rate_matches() {
        let p = GeneratorParams::netflix(60, 600, 20_000);
        let t = generate(&p, TraceKind::Netflix);
        let span = t.requests.last().unwrap().time - t.requests[0].time;
        let rate = t.len() as f64 / span;
        assert!(
            (rate - p.req_rate).abs() / p.req_rate < 0.1,
            "rate {rate} vs {}",
            p.req_rate
        );
    }
}
