//! Streaming trace sources (DESIGN.md §10): pull-based, bounded-memory
//! suppliers of time-ordered [`Request`] chunks.
//!
//! Every workload path used to materialize the full trace as a
//! `Trace { requests: Vec<Request> }` before the first window ran, so a
//! Netflix/Spotify-scale replay (10⁸ requests) was memory-bound before it
//! was compute-bound. A [`TraceSource`] replaces the vector with a
//! cursor: an up-front [`TraceMeta`] header (universe shape + estimated
//! length) and a `next_chunk` pump that refills a caller-owned buffer —
//! peak memory is one chunk plus whatever the consumer buffers (the
//! replay drivers keep one clique-generation window), independent of
//! trace length.
//!
//! Implementations:
//!
//! * [`MemorySource`] — adapter over an in-memory [`Trace`] (borrowed or
//!   `Arc`-shared); full backward compatibility for the materialized
//!   paths.
//! * [`GeneratorSource`] — on-the-fly synthetic generation via the
//!   resumable [`TraceGenerator`]; nothing is ever materialized.
//! * [`CsvStreamSource`] — line-streamed `akpc-trace` CSV (the
//!   [`write_csv`](super::io::write_csv) format; the `#` metadata header
//!   is mandatory here because the universe shape must be known up
//!   front).
//! * [`BinaryStreamSource`] — record-streamed binary traces, both the
//!   flat v1 layout and the chunk-framed v2 layout written by
//!   [`write_binary_chunked`](super::io::write_binary_chunked).
//! * [`ChannelSource`] — live chunks pushed over a bounded in-process
//!   channel; the adapter the serving daemon's admission layer
//!   (DESIGN.md §12) uses to feed socket arrivals into the same replay
//!   drivers the file sources feed.
//!
//! Sources validate incrementally (time order, universe bounds) so a
//! malformed tail fails at its chunk, not after an hour of replay. The
//! offline-policy caveat — `needs_offline_trace` policies must still see
//! the whole timeline and therefore collect the stream — lives in
//! [`crate::run::drive::drive_trace`] (DESIGN.md §10.4).
//!
//! ```
//! use akpc::trace::generator::netflix_like;
//! use akpc::trace::stream::{MemorySource, TraceSource};
//!
//! let trace = netflix_like(30, 12, 500, 7);
//! let mut src = MemorySource::new(&trace).with_chunk_len(128);
//! assert_eq!(src.meta().est_len, Some(500));
//! let (mut total, mut buf) = (0, Vec::new());
//! while src.next_chunk(&mut buf).unwrap() {
//!     assert!(buf.len() <= 128, "chunks are bounded");
//!     total += buf.len();
//! }
//! assert_eq!(total, 500);
//! ```

use std::borrow::Borrow;
use std::io::{BufRead, BufReader};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use super::generator::{GeneratorParams, TraceGenerator, TraceKind};
use super::io as trace_io;
use super::model::{Request, Trace};

/// Default requests per chunk. Small enough that a chunk of worst-case
/// requests stays well under a megabyte, large enough to amortize the
/// per-chunk call overhead.
pub const DEFAULT_CHUNK_LEN: usize = 8_192;

/// The up-front stream header: what a consumer may rely on before the
/// first chunk arrives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceMeta {
    /// Item-universe size n = |U|.
    pub n_items: u32,
    /// Server count m = |S|.
    pub n_servers: u32,
    /// Total requests the stream will yield, when known up front
    /// (generator: exact; binary: exact from the header; CSV: `None`).
    pub est_len: Option<usize>,
    /// Human-readable provenance (mirrors `Trace::name`).
    pub name: String,
}

impl TraceMeta {
    /// Copy the shape fields out of an in-memory trace.
    pub fn of_trace(t: &Trace) -> Self {
        Self {
            n_items: t.n_items,
            n_servers: t.n_servers,
            est_len: Some(t.len()),
            name: t.name.clone(),
        }
    }
}

/// A pull-based supplier of time-ordered request chunks.
///
/// Contract: `next_chunk` clears `buf`, fills it with the next chunk (at
/// least one request) and returns `Ok(true)`, or leaves it empty and
/// returns `Ok(false)` once the stream is exhausted. Chunks are
/// time-ordered within and across calls; the universe bounds of
/// [`meta`](TraceSource::meta) hold for every request. Callers reuse
/// `buf` across calls so steady-state replay allocates nothing per
/// chunk.
pub trait TraceSource {
    /// The stream header (available before any chunk is pulled).
    fn meta(&self) -> &TraceMeta;

    /// Pull the next chunk into `buf`. `Ok(false)` = end of stream.
    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool>;

    /// The in-memory trace behind this source, if there is one.
    ///
    /// Lets [`drive_trace`](crate::run::drive::drive_trace) hand offline
    /// policies (`needs_offline_trace`) the existing vector instead of
    /// collecting a second copy. File/generator sources return `None`.
    fn as_trace(&self) -> Option<&Trace> {
        None
    }

    /// Drain the remaining stream into a materialized [`Trace`].
    ///
    /// **This is the memory cliff the streaming engine exists to avoid**
    /// — O(total requests) resident. It is the documented fallback for
    /// offline policies and for small traces; never call it on a
    /// million-user stream you intend to replay online.
    fn collect(&mut self) -> anyhow::Result<Trace> {
        let meta = self.meta().clone();
        let mut requests = Vec::with_capacity(meta.est_len.unwrap_or(0));
        let mut buf = Vec::new();
        while self.next_chunk(&mut buf)? {
            requests.append(&mut buf);
        }
        Ok(Trace {
            requests,
            n_items: meta.n_items,
            n_servers: meta.n_servers,
            name: meta.name,
        })
    }
}

/// Incremental chunk validation shared by the file-backed sources: time
/// order across chunk boundaries, universe bounds, non-empty
/// strictly-ascending item sets — the `Trace::validate` invariants,
/// checked per chunk. Binary records arrive exactly as stored (no
/// `Request::new` re-sort), so the ascending check is what catches a
/// corrupt or foreign file before its items index out of bounds deep in
/// the replay.
fn check_chunk(
    meta: &TraceMeta,
    last_t: &mut f64,
    start_index: usize,
    buf: &[Request],
) -> anyhow::Result<()> {
    for (i, r) in buf.iter().enumerate() {
        let idx = start_index + i;
        anyhow::ensure!(!r.items.is_empty(), "request {idx}: empty item set");
        anyhow::ensure!(
            r.items.windows(2).all(|w| w[0] < w[1]),
            "request {idx}: items not strictly ascending"
        );
        anyhow::ensure!(
            r.time >= *last_t,
            "request {idx}: out of time order ({} after {})",
            r.time,
            last_t
        );
        anyhow::ensure!(
            r.server < meta.n_servers,
            "request {idx}: server {} out of range (n_servers={})",
            r.server,
            meta.n_servers
        );
        if meta.n_items > 0 {
            // Ascending already checked, so the last item is the max.
            let last = *r.items.last().unwrap();
            anyhow::ensure!(
                last < meta.n_items,
                "request {idx}: item {last} out of range (n_items={})",
                meta.n_items
            );
        }
        *last_t = r.time;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// In-memory adapter
// ---------------------------------------------------------------------

/// [`TraceSource`] over an in-memory trace — the backward-compatibility
/// adapter the materialized entry points (`sim::run`, `RunSpec`) wrap
/// around their `&Trace` / `Arc<Trace>`.
///
/// Generic over [`Borrow<Trace>`] so both borrowed and shared traces
/// work without copying the request vector.
#[derive(Debug)]
pub struct MemorySource<B: Borrow<Trace>> {
    trace: B,
    meta: TraceMeta,
    pos: usize,
    chunk_len: usize,
}

impl<B: Borrow<Trace>> MemorySource<B> {
    /// Wrap `trace` with the [`DEFAULT_CHUNK_LEN`].
    pub fn new(trace: B) -> Self {
        let meta = TraceMeta::of_trace(trace.borrow());
        Self {
            trace,
            meta,
            pos: 0,
            chunk_len: DEFAULT_CHUNK_LEN,
        }
    }

    /// Override the chunk length (clamped to ≥ 1).
    pub fn with_chunk_len(mut self, chunk_len: usize) -> Self {
        self.chunk_len = chunk_len.max(1);
        self
    }
}

impl<B: Borrow<Trace>> TraceSource for MemorySource<B> {
    fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        buf.clear();
        let reqs = &self.trace.borrow().requests;
        if self.pos >= reqs.len() {
            return Ok(false);
        }
        let end = (self.pos + self.chunk_len).min(reqs.len());
        buf.extend_from_slice(&reqs[self.pos..end]);
        self.pos = end;
        Ok(true)
    }

    fn as_trace(&self) -> Option<&Trace> {
        Some(self.trace.borrow())
    }
}

// ---------------------------------------------------------------------
// On-the-fly generation
// ---------------------------------------------------------------------

/// [`TraceSource`] over the resumable synthetic generator: requests are
/// sampled per chunk, so a 10⁸-request workload costs one chunk of
/// memory.
pub struct GeneratorSource {
    gen: TraceGenerator,
    meta: TraceMeta,
    chunk_len: usize,
}

impl GeneratorSource {
    /// Validate `params` and open the stream.
    pub fn new(params: &GeneratorParams, kind: TraceKind, chunk_len: usize) -> anyhow::Result<Self> {
        let gen = TraceGenerator::new(params, kind)?;
        let meta = TraceMeta {
            n_items: params.n_items,
            n_servers: params.n_servers,
            est_len: Some(params.n_requests),
            name: kind.trace_name().to_string(),
        };
        Ok(Self {
            gen,
            meta,
            chunk_len: chunk_len.max(1),
        })
    }
}

impl TraceSource for GeneratorSource {
    fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        buf.clear();
        while buf.len() < self.chunk_len {
            match self.gen.next_request() {
                Some(r) => buf.push(r),
                None => break,
            }
        }
        Ok(!buf.is_empty())
    }
}

// ---------------------------------------------------------------------
// Line-streamed CSV
// ---------------------------------------------------------------------

/// [`TraceSource`] over the `akpc-trace` CSV form, read line by line.
///
/// The `#` metadata header must be the first non-blank line and must
/// carry `n_items=`/`n_servers=` (a streaming consumer needs the
/// universe shape before the data arrives;
/// [`read_csv`](super::io::read_csv) stays lenient for legacy
/// header-less files). Later `#` lines are skipped as comments. Row
/// errors carry the 1-based line number *and* the row's starting byte
/// offset.
pub struct CsvStreamSource {
    rdr: BufReader<std::fs::File>,
    meta: TraceMeta,
    chunk_len: usize,
    /// 1-based number of the last line read.
    lineno: usize,
    /// Byte offset of the next unread line.
    byte_off: u64,
    /// Requests yielded so far (error indexing).
    yielded: usize,
    last_t: f64,
    line: String,
}

impl CsvStreamSource {
    /// Open `path` and parse the metadata header.
    pub fn open(path: impl AsRef<Path>, chunk_len: usize) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let mut rdr = BufReader::new(std::fs::File::open(path)?);
        let mut lineno = 0usize;
        let mut byte_off = 0u64;
        let mut line = String::new();
        let mut meta: Option<TraceMeta> = None;
        loop {
            line.clear();
            let n = rdr.read_line(&mut line)?;
            if n == 0 {
                break;
            }
            lineno += 1;
            let start = byte_off;
            byte_off += n as u64;
            let text = line.trim_end_matches(['\n', '\r']);
            if text.is_empty() {
                continue;
            }
            let Some(hdr) = text.strip_prefix('#') else {
                anyhow::bail!(
                    "line {lineno} (byte {start}): streaming CSV needs a leading \
                     `# akpc-trace ...` header with n_items/n_servers (got `{text}`)"
                );
            };
            let (name, n_items, n_servers) = trace_io::parse_csv_header(hdr, lineno, start)?;
            let n_items = n_items.ok_or_else(|| {
                anyhow::anyhow!("line {lineno} (byte {start}): header lacks n_items=")
            })?;
            let n_servers = n_servers.ok_or_else(|| {
                anyhow::anyhow!("line {lineno} (byte {start}): header lacks n_servers=")
            })?;
            meta = Some(TraceMeta {
                n_items,
                n_servers,
                est_len: None,
                name: name.unwrap_or_else(|| "csv".to_string()),
            });
            break;
        }
        let meta = meta.ok_or_else(|| anyhow::anyhow!("empty CSV trace: no header line"))?;
        Ok(Self {
            rdr,
            meta,
            chunk_len: chunk_len.max(1),
            lineno,
            byte_off,
            yielded: 0,
            last_t: f64::NEG_INFINITY,
            line,
        })
    }
}

impl TraceSource for CsvStreamSource {
    fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        buf.clear();
        while buf.len() < self.chunk_len {
            self.line.clear();
            let n = self.rdr.read_line(&mut self.line)?;
            if n == 0 {
                break;
            }
            self.lineno += 1;
            let start = self.byte_off;
            self.byte_off += n as u64;
            let text = self.line.trim_end_matches(['\n', '\r']);
            if text.is_empty() || text.starts_with('#') {
                continue;
            }
            buf.push(trace_io::parse_csv_data_row(
                text,
                self.lineno,
                start,
                self.meta.n_items,
            )?);
        }
        check_chunk(&self.meta, &mut self.last_t, self.yielded, buf)?;
        self.yielded += buf.len();
        Ok(!buf.is_empty())
    }
}

// ---------------------------------------------------------------------
// Record-streamed binary
// ---------------------------------------------------------------------

/// [`TraceSource`] over the binary trace forms: the flat v1 layout
/// streams `chunk_len` records per pull, the chunk-framed v2 layout
/// ([`write_binary_chunked`](super::io::write_binary_chunked)) streams
/// one frame per pull.
pub struct BinaryStreamSource {
    rdr: BufReader<std::fs::File>,
    meta: TraceMeta,
    version: u32,
    /// Records not yet yielded.
    remaining: u64,
    chunk_len: usize,
    yielded: usize,
    last_t: f64,
}

impl BinaryStreamSource {
    /// Open `path` and parse the versioned header.
    pub fn open(path: impl AsRef<Path>, chunk_len: usize) -> anyhow::Result<Self> {
        let mut rdr = BufReader::new(std::fs::File::open(path.as_ref())?);
        let hdr = trace_io::read_binary_header(&mut rdr)?;
        let meta = TraceMeta {
            n_items: hdr.n_items,
            n_servers: hdr.n_servers,
            est_len: Some(hdr.n_reqs as usize),
            name: hdr.name,
        };
        Ok(Self {
            rdr,
            meta,
            version: hdr.version,
            remaining: hdr.n_reqs,
            chunk_len: chunk_len.max(1),
            yielded: 0,
            last_t: f64::NEG_INFINITY,
        })
    }
}

impl TraceSource for BinaryStreamSource {
    fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        buf.clear();
        if self.remaining == 0 {
            return Ok(false);
        }
        let take = match self.version {
            trace_io::VERSION_FLAT => self.chunk_len.min(self.remaining as usize),
            _ => {
                // v2: one frame per pull, framed by its record count.
                let n = trace_io::read_frame_header(&mut self.rdr)? as usize;
                anyhow::ensure!(
                    n >= 1 && n as u64 <= self.remaining,
                    "corrupt chunk frame: {n} records framed, {} remaining",
                    self.remaining
                );
                n
            }
        };
        buf.reserve(take);
        for _ in 0..take {
            buf.push(trace_io::read_binary_record(&mut self.rdr)?);
        }
        self.remaining -= take as u64;
        check_chunk(&self.meta, &mut self.last_t, self.yielded, buf)?;
        self.yielded += buf.len();
        Ok(true)
    }
}

// ---------------------------------------------------------------------
// Live channel adapter
// ---------------------------------------------------------------------

/// [`TraceSource`] over a bounded in-process channel.
///
/// The producer side (the serving daemon's admission layer, DESIGN.md
/// §12.2) pushes time-ordered `Vec<Request>` chunks through the returned
/// [`mpsc::SyncSender`]; `next_chunk` blocks until a chunk arrives and
/// ends the stream cleanly (`Ok(false)`) once every sender is dropped.
/// The bounded depth is the backpressure contract: a slow consumer
/// blocks the producer after `depth` queued chunks instead of buffering
/// an unbounded live workload in memory.
///
/// Chunks are re-validated on the consumer side with the same
/// incremental checks the file sources use, so a buggy producer fails
/// the replay at its chunk rather than corrupting shard state.
#[derive(Debug)]
pub struct ChannelSource {
    rx: mpsc::Receiver<Vec<Request>>,
    meta: TraceMeta,
    yielded: usize,
    last_t: f64,
    depth: Arc<AtomicUsize>,
}

impl ChannelSource {
    /// Open a channel-backed source with room for `depth` in-flight
    /// chunks (clamped to ≥ 1). Returns the producer handle and the
    /// source; clone the sender for multiple producers, drop every
    /// clone to end the stream.
    pub fn bounded(meta: TraceMeta, depth: usize) -> (mpsc::SyncSender<Vec<Request>>, Self) {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        (
            tx,
            Self {
                rx,
                meta,
                yielded: 0,
                last_t: f64::NEG_INFINITY,
                depth: Arc::new(AtomicUsize::new(0)),
            },
        )
    }

    /// Shared queue-depth gauge: producers that bump it after each send
    /// (the daemon's admission layer does) get a live count of chunks
    /// waiting in the channel, which is what overload-degradation
    /// thresholds key on (DESIGN.md §14.4). `next_chunk` decrements it
    /// per consumed chunk; producers that never increment simply read 0.
    pub fn depth_gauge(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.depth)
    }
}

impl TraceSource for ChannelSource {
    fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    fn next_chunk(&mut self, buf: &mut Vec<Request>) -> anyhow::Result<bool> {
        buf.clear();
        loop {
            match self.rx.recv() {
                Ok(chunk) => {
                    // Saturating: producers that don't maintain the
                    // gauge leave it at zero.
                    let _ = self.depth.fetch_update(
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                        |v| v.checked_sub(1),
                    );
                    if chunk.is_empty() {
                        continue; // tolerate producer keep-alive flushes
                    }
                    *buf = chunk;
                    check_chunk(&self.meta, &mut self.last_t, self.yielded, buf)?;
                    self.yielded += buf.len();
                    return Ok(true);
                }
                // All senders dropped: the live stream is complete.
                Err(mpsc::RecvError) => return Ok(false),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;
    use crate::util::tempdir::TempDir;

    fn small() -> Trace {
        netflix_like(30, 12, 1_000, 5)
    }

    #[test]
    fn memory_source_roundtrips_and_exposes_trace() {
        let t = small();
        let mut src = MemorySource::new(&t).with_chunk_len(100);
        assert_eq!(src.meta(), &TraceMeta::of_trace(&t));
        assert!(src.as_trace().is_some());
        let back = src.collect().unwrap();
        assert_eq!(back.requests, t.requests);
        // Exhausted source keeps returning false.
        let mut buf = Vec::new();
        assert!(!src.next_chunk(&mut buf).unwrap());
    }

    #[test]
    fn arc_memory_source_shares_without_copy() {
        let t = std::sync::Arc::new(small());
        let mut src = MemorySource::new(std::sync::Arc::clone(&t));
        assert_eq!(src.collect().unwrap().requests, t.requests);
    }

    #[test]
    fn generator_source_matches_materialized_generation() {
        let p = GeneratorParams::netflix(30, 12, 2_000);
        let mut src = GeneratorSource::new(&p, TraceKind::Netflix, 300).unwrap();
        assert_eq!(src.meta().est_len, Some(2_000));
        let streamed = src.collect().unwrap();
        let batch = crate::trace::generator::generate(&p, TraceKind::Netflix);
        assert_eq!(streamed.requests, batch.requests);
        assert_eq!(streamed.name, "netflix-like");
    }

    #[test]
    fn csv_source_streams_written_file() {
        let t = small();
        let dir = TempDir::new("stream").unwrap();
        let p = dir.file("t.csv");
        crate::trace::io::write_csv(&t, &p).unwrap();
        let mut src = CsvStreamSource::open(&p, 128).unwrap();
        assert_eq!(src.meta().n_items, 30);
        assert_eq!(src.meta().est_len, None);
        let back = src.collect().unwrap();
        assert_eq!(back.requests.len(), t.requests.len());
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert_eq!(a.items, b.items);
            assert_eq!(a.server, b.server);
        }
    }

    #[test]
    fn csv_source_requires_header() {
        let dir = TempDir::new("stream").unwrap();
        let p = dir.file("nohdr.csv");
        std::fs::write(&p, "0.5,0,1;2\n").unwrap();
        let err = CsvStreamSource::open(&p, 16).unwrap_err().to_string();
        assert!(err.contains("header"), "{err}");
        let p2 = dir.file("nometa.csv");
        std::fs::write(&p2, "# akpc-trace v1 name=x\n0.5,0,1\n").unwrap();
        let err = CsvStreamSource::open(&p2, 16).unwrap_err().to_string();
        assert!(err.contains("n_items"), "{err}");
    }

    #[test]
    fn csv_source_rejects_disordered_tail_with_offset() {
        let dir = TempDir::new("stream").unwrap();
        let p = dir.file("dis.csv");
        std::fs::write(
            &p,
            "# akpc-trace v1 n_items=10 n_servers=2\n1.0,0,1\n0.5,0,2\n",
        )
        .unwrap();
        let mut src = CsvStreamSource::open(&p, 16).unwrap();
        let err = src.collect().unwrap_err().to_string();
        assert!(err.contains("out of time order"), "{err}");
    }

    #[test]
    fn binary_source_streams_v1_files() {
        let t = small();
        let dir = TempDir::new("stream").unwrap();
        let p = dir.file("t.bin");
        crate::trace::io::write_binary(&t, &p).unwrap();
        let mut src = BinaryStreamSource::open(&p, 100).unwrap();
        assert_eq!(src.meta().est_len, Some(t.len()));
        let mut buf = Vec::new();
        assert!(src.next_chunk(&mut buf).unwrap());
        assert_eq!(buf.len(), 100, "v1 streams chunk_len records per pull");
        let rest = src.collect().unwrap();
        assert_eq!(rest.requests.len(), t.len() - 100);
    }

    #[test]
    fn binary_source_rejects_unsorted_record_items() {
        // Binary records are read as stored (no Request::new re-sort), so
        // a corrupt file with descending items must die in chunk
        // validation, not as an index panic deep in replay.
        let dir = TempDir::new("stream").unwrap();
        let p = dir.file("unsorted.bin");
        let mut bytes = b"AKPT".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&10u32.to_le_bytes()); // n_items
        bytes.extend_from_slice(&2u32.to_le_bytes()); // n_servers
        bytes.extend_from_slice(&0u32.to_le_bytes()); // name_len
        bytes.extend_from_slice(&1u64.to_le_bytes()); // n_reqs
        bytes.extend_from_slice(&0.0f64.to_le_bytes()); // time
        bytes.extend_from_slice(&0u32.to_le_bytes()); // server
        bytes.extend_from_slice(&2u16.to_le_bytes()); // k
        bytes.extend_from_slice(&7u32.to_le_bytes()); // items[0]
        bytes.extend_from_slice(&2u32.to_le_bytes()); // items[1] < items[0]
        std::fs::write(&p, &bytes).unwrap();
        let mut src = BinaryStreamSource::open(&p, 16).unwrap();
        let err = src.collect().unwrap_err().to_string();
        assert!(err.contains("not strictly ascending"), "{err}");
    }

    #[test]
    fn channel_source_streams_pushed_chunks_in_order() {
        let meta = TraceMeta {
            n_items: 10,
            n_servers: 4,
            est_len: None,
            name: "live".into(),
        };
        let (tx, mut src) = ChannelSource::bounded(meta, 4);
        tx.send(vec![Request::new(vec![1, 2], 0, 0.5)]).unwrap();
        tx.send(Vec::new()).unwrap(); // keep-alive flush: skipped
        tx.send(vec![
            Request::new(vec![3], 1, 0.75),
            Request::new(vec![0, 9], 2, 1.0),
        ])
        .unwrap();
        drop(tx);
        let t = src.collect().unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.requests[2].items, vec![0, 9]);
        let mut buf = Vec::new();
        assert!(!src.next_chunk(&mut buf).unwrap(), "drained after drop");
    }

    #[test]
    fn channel_source_rejects_disorder_and_bounds() {
        let meta = TraceMeta {
            n_items: 10,
            n_servers: 4,
            est_len: None,
            name: "live".into(),
        };
        let (tx, mut src) = ChannelSource::bounded(meta.clone(), 4);
        tx.send(vec![Request::new(vec![1], 0, 1.0)]).unwrap();
        tx.send(vec![Request::new(vec![1], 0, 0.5)]).unwrap();
        drop(tx);
        let err = src.collect().unwrap_err().to_string();
        assert!(err.contains("out of time order"), "{err}");

        let (tx, mut src) = ChannelSource::bounded(meta, 4);
        tx.send(vec![Request::new(vec![42], 0, 0.0)]).unwrap();
        drop(tx);
        let err = src.collect().unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn chunk_validation_catches_universe_violations() {
        let mut meta = TraceMeta {
            n_items: 4,
            n_servers: 2,
            est_len: None,
            name: "x".into(),
        };
        let mut last_t = f64::NEG_INFINITY;
        let ok = [Request::new(vec![0, 3], 1, 0.5)];
        check_chunk(&meta, &mut last_t, 0, &ok).unwrap();
        let bad_item = [Request::new(vec![9], 0, 1.0)];
        assert!(check_chunk(&meta, &mut last_t, 1, &bad_item)
            .unwrap_err()
            .to_string()
            .contains("out of range"));
        let bad_server = [Request::new(vec![0], 7, 1.0)];
        assert!(check_chunk(&meta, &mut last_t, 1, &bad_server)
            .unwrap_err()
            .to_string()
            .contains("server"));
        // n_items == 0 disables the item bound (header-less provenance).
        meta.n_items = 0;
        meta.n_servers = 100;
        check_chunk(&meta, &mut last_t, 1, &[Request::new(vec![99], 0, 2.0)]).unwrap();
    }
}
