//! Coordinator metrics: point-in-time snapshots of the leader's state,
//! exported over the snapshot channel (Prometheus-style pull).

use crate::cache::CostLedger;
use crate::util::{Histogram, Json};

/// A consistent snapshot of the serving state.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Policy display name.
    pub policy: String,
    /// CRM engine in use ("xla" / "native").
    pub engine: String,
    pub ledger: CostLedger,
    /// Requests served since start.
    pub served: u64,
    /// Clique-generation windows executed.
    pub windows: u64,
    /// Live cliques after the last window tick.
    pub live_cliques: usize,
    /// Clique-size distribution (cumulative over windows).
    pub clique_hist: Histogram,
    /// Cumulative seconds spent in clique generation.
    pub clique_gen_secs: f64,
    /// Per-request service latency in microseconds.
    pub latency_us: Histogram,
}

impl MetricsSnapshot {
    /// Render a compact one-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "policy={} engine={} served={} windows={} cliques={} total_cost={:.1} (C_T={:.1} C_P={:.1}) hit={:.1}% p50={}us p99={}us",
            self.policy,
            self.engine,
            self.served,
            self.windows,
            self.live_cliques,
            self.ledger.total(),
            self.ledger.c_t,
            self.ledger.c_p,
            self.ledger.hit_rate() * 100.0,
            self.latency_us.quantile(0.5),
            self.latency_us.quantile(0.99),
        )
    }

    /// JSON export.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("engine", Json::Str(self.engine.clone())),
            ("ledger", self.ledger.to_json()),
            ("served", Json::Num(self.served as f64)),
            ("windows", Json::Num(self.windows as f64)),
            ("live_cliques", Json::Num(self.live_cliques as f64)),
            ("clique_hist", self.clique_hist.to_json()),
            ("clique_gen_secs", Json::Num(self.clique_gen_secs)),
            ("latency_us", self.latency_us.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_renders() {
        let s = MetricsSnapshot {
            policy: "AKPC".into(),
            engine: "xla".into(),
            ledger: CostLedger::default(),
            served: 10,
            windows: 2,
            live_cliques: 3,
            clique_hist: Histogram::new(),
            clique_gen_secs: 0.1,
            latency_us: Histogram::new(),
        };
        let line = s.summary();
        assert!(line.contains("policy=AKPC"));
        assert!(line.contains("engine=xla"));
        crate::util::json::parse(&s.to_json().to_string()).unwrap();
    }
}
