//! Coordinator metrics: per-shard snapshots, clique-generation worker
//! stats, and the cross-shard aggregation that folds them into one
//! [`MetricsSnapshot`] (Prometheus-style pull).

use crate::cache::CostLedger;
use crate::util::{Histogram, Json};

/// Point-in-time stats of one shard actor.
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Shard index (owns servers `s` with `s % n_shards == shard`).
    pub shard: usize,
    /// This shard's cost ledger (its disjoint ESS set only).
    pub ledger: CostLedger,
    /// Requests served by this shard.
    pub served: u64,
    /// Per-request service latency in microseconds.
    pub latency_us: Histogram,
    /// Forced Algorithm-6 retentions performed by this shard.
    pub retentions: u64,
    /// Live `(clique, server)` cache entries.
    pub live_entries: usize,
    /// Version of the installed clique snapshot.
    pub snapshot_version: u64,
    /// Largest request time processed (the shard's sweep clock);
    /// `NEG_INFINITY` until the first request.
    pub last_time: f64,
    /// In-flight `Serve` messages in this shard's mailbox at snapshot
    /// time (the autoscaler's and dashboards' backpressure signal).
    pub queue_depth: usize,
}

impl ShardStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("ledger", self.ledger.to_json()),
            ("served", Json::Num(self.served as f64)),
            ("retentions", Json::Num(self.retentions as f64)),
            ("live_entries", Json::Num(self.live_entries as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            (
                "snapshot_version",
                Json::Num(self.snapshot_version as f64),
            ),
            ("latency_us", self.latency_us.to_json()),
        ])
    }
}

/// Point-in-time stats of the background clique-generation worker.
#[derive(Debug, Clone)]
pub struct GenStats {
    /// Policy display name (e.g. "AKPC").
    pub policy: String,
    /// CRM engine in use ("xla" / "native").
    pub engine: String,
    /// Clique-generation windows executed.
    pub windows: u64,
    /// Live cliques after the last window tick.
    pub live_cliques: usize,
    /// Clique-size distribution (cumulative over windows).
    pub clique_hist: Histogram,
    /// Cumulative seconds spent in clique generation.
    pub clique_gen_secs: f64,
}

impl Default for GenStats {
    fn default() -> Self {
        Self {
            policy: "AKPC".to_string(),
            engine: "native".to_string(),
            windows: 0,
            live_cliques: 0,
            clique_hist: Histogram::new(),
            clique_gen_secs: 0.0,
        }
    }
}

/// A consistent snapshot of the serving state, aggregated over all shards.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// Policy display name.
    pub policy: String,
    /// CRM engine in use ("xla" / "native").
    pub engine: String,
    /// Cross-shard merged ledger (shards are disjoint, so this equals the
    /// single-leader ledger on the same ordered trace — DESIGN.md §2.3).
    pub ledger: CostLedger,
    /// Requests served since start.
    pub served: u64,
    /// Clique-generation windows executed.
    pub windows: u64,
    /// Live cliques after the last window tick.
    pub live_cliques: usize,
    /// Clique-size distribution (cumulative over windows).
    pub clique_hist: Histogram,
    /// Cumulative seconds spent in clique generation.
    pub clique_gen_secs: f64,
    /// Per-request service latency in microseconds (all shards merged).
    pub latency_us: Histogram,
    /// The unmerged per-shard view (empty only for hand-built snapshots).
    pub per_shard: Vec<ShardStats>,
}

impl MetricsSnapshot {
    /// Fold the worker's stats and every shard's stats into one snapshot.
    pub fn aggregate(gen: GenStats, mut per_shard: Vec<ShardStats>) -> Self {
        per_shard.sort_by_key(|s| s.shard);
        let mut ledger = CostLedger::default();
        let mut latency = Histogram::new();
        let mut served = 0u64;
        for s in &per_shard {
            ledger.merge(&s.ledger);
            latency.merge(&s.latency_us);
            served += s.served;
        }
        Self {
            policy: gen.policy,
            engine: gen.engine,
            ledger,
            served,
            windows: gen.windows,
            live_cliques: gen.live_cliques,
            clique_hist: gen.clique_hist,
            clique_gen_secs: gen.clique_gen_secs,
            latency_us: latency,
            per_shard,
        }
    }

    /// Total Algorithm-6 retentions across shards.
    pub fn retentions(&self) -> u64 {
        self.per_shard.iter().map(|s| s.retentions).sum()
    }

    /// Fold the final snapshots of retired coordinator epochs into the
    /// current one, so counters stay monotone across hot-reloads and
    /// elastic resizes (a Prometheus contract). Gauges (`live_cliques`,
    /// shard count, queue depth) keep the current epoch's value;
    /// counters and histograms accumulate. Shards present only in a
    /// retired epoch keep their counters in the merged view.
    pub fn merge_epochs(prior: &[MetricsSnapshot], mut last: MetricsSnapshot) -> MetricsSnapshot {
        for p in prior {
            last.ledger.merge(&p.ledger);
            last.served += p.served;
            last.windows += p.windows;
            last.clique_gen_secs += p.clique_gen_secs;
            last.clique_hist.merge(&p.clique_hist);
            last.latency_us.merge(&p.latency_us);
            for ps in &p.per_shard {
                if let Some(cur) = last.per_shard.iter_mut().find(|c| c.shard == ps.shard) {
                    cur.ledger.merge(&ps.ledger);
                    cur.served += ps.served;
                    cur.retentions += ps.retentions;
                    cur.latency_us.merge(&ps.latency_us);
                } else {
                    last.per_shard.push(ps.clone());
                }
            }
        }
        last.per_shard.sort_by_key(|s| s.shard);
        last
    }

    /// Normalize a retired epoch produced by a *stateful* handoff
    /// ([`Coordinator::decommission`](crate::coordinator::Coordinator::decommission))
    /// for [`merge_epochs`](Self::merge_epochs): the clique-gen counters
    /// (`windows`, `clique_gen_secs`, the clique histogram) travel
    /// *inside* the handoff and keep accumulating in the successor's
    /// pipeline, so leaving them in the retired snapshot would
    /// double-count them at merge time. Shard-side counters (ledger,
    /// served, retentions, latency) genuinely reset per epoch and are
    /// kept. Fresh-swap epochs (policy/engine change — no handoff) must
    /// NOT be normalized: their successor's pipeline restarts at zero.
    pub fn into_handoff_epoch(mut self) -> Self {
        self.windows = 0;
        self.clique_gen_secs = 0.0;
        self.clique_hist = Histogram::new();
        self
    }

    /// Cross-shard ledger delta vs an earlier snapshot of the same
    /// coordinator — the per-phase cost breakdown the scenario replay
    /// driver records at phase boundaries (DESIGN.md §7.3).
    pub fn ledger_delta(&self, earlier: &MetricsSnapshot) -> CostLedger {
        self.ledger.delta_from(&earlier.ledger)
    }

    /// Render a compact one-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "policy={} engine={} shards={} served={} windows={} cliques={} total_cost={:.1} (C_T={:.1} C_P={:.1}) hit={:.1}% p50={}us p99={}us",
            self.policy,
            self.engine,
            self.per_shard.len().max(1),
            self.served,
            self.windows,
            self.live_cliques,
            self.ledger.total(),
            self.ledger.c_t,
            self.ledger.c_p,
            self.ledger.hit_rate() * 100.0,
            self.latency_us.quantile(0.5),
            self.latency_us.quantile(0.99),
        )
    }

    /// Render the snapshot in the Prometheus text exposition format
    /// (version 0.0.4): `# HELP`/`# TYPE` preamble per family, one
    /// sample per line, `{shard="i"}` labels for the per-shard series.
    /// This is what the serving daemon's `GET /metrics` endpoint
    /// returns (DESIGN.md §12.3).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::with_capacity(1024);
        let mut counter = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter(
            "akpc_requests_served_total",
            "Requests served since start",
            self.served as f64,
        );
        counter(
            "akpc_cost_transfer_total",
            "Cumulative transfer cost C_T (paper Eq. 5)",
            self.ledger.c_t,
        );
        counter(
            "akpc_cost_caching_total",
            "Cumulative caching cost C_P (paper Eq. 5)",
            self.ledger.c_p,
        );
        counter(
            "akpc_full_hits_total",
            "Requests fully served from local cache",
            self.ledger.full_hits as f64,
        );
        counter(
            "akpc_misses_total",
            "Requests that triggered at least one transfer",
            self.ledger.misses as f64,
        );
        counter(
            "akpc_transfers_total",
            "Packed-group transfers performed",
            self.ledger.transfers as f64,
        );
        counter(
            "akpc_retentions_total",
            "Forced Algorithm-6 retentions across shards",
            self.retentions() as f64,
        );
        counter(
            "akpc_clique_windows_total",
            "Clique-generation windows executed",
            self.windows as f64,
        );
        counter(
            "akpc_clique_gen_seconds_total",
            "Cumulative seconds spent in clique generation",
            self.clique_gen_secs,
        );
        let mut gauge = |name: &str, help: &str, v: f64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        gauge(
            "akpc_live_cliques",
            "Live cliques after the last window tick",
            self.live_cliques as f64,
        );
        gauge(
            "akpc_shards",
            "Shard actors in the coordinator",
            self.per_shard.len().max(1) as f64,
        );
        for q in [0.5, 0.9, 0.99] {
            gauge(
                &format!("akpc_latency_us_q{}", (q * 100.0) as u32),
                "Per-request service latency quantile (microseconds)",
                f64::from(self.latency_us.quantile(q)),
            );
        }
        out.push_str(
            "# HELP akpc_shard_served_total Requests served by one shard\n\
             # TYPE akpc_shard_served_total counter\n",
        );
        for s in &self.per_shard {
            out.push_str(&format!(
                "akpc_shard_served_total{{shard=\"{}\"}} {}\n",
                s.shard, s.served
            ));
        }
        // Per-shard gauges the autoscaler (and the release-smoke scrape)
        // watches: live cache entries and mailbox depth per shard.
        out.push_str(
            "# HELP akpc_shard_occupancy Live (clique, server) cache entries on one shard\n\
             # TYPE akpc_shard_occupancy gauge\n",
        );
        for s in &self.per_shard {
            out.push_str(&format!(
                "akpc_shard_occupancy{{shard=\"{}\"}} {}\n",
                s.shard, s.live_entries
            ));
        }
        out.push_str(
            "# HELP akpc_shard_queue_depth In-flight serve messages in one shard's mailbox\n\
             # TYPE akpc_shard_queue_depth gauge\n",
        );
        for s in &self.per_shard {
            out.push_str(&format!(
                "akpc_shard_queue_depth{{shard=\"{}\"}} {}\n",
                s.shard, s.queue_depth
            ));
        }
        out
    }

    /// JSON export.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::Str(self.policy.clone())),
            ("engine", Json::Str(self.engine.clone())),
            ("ledger", self.ledger.to_json()),
            ("served", Json::Num(self.served as f64)),
            ("windows", Json::Num(self.windows as f64)),
            ("live_cliques", Json::Num(self.live_cliques as f64)),
            ("clique_hist", self.clique_hist.to_json()),
            ("clique_gen_secs", Json::Num(self.clique_gen_secs)),
            ("latency_us", self.latency_us.to_json()),
            (
                "per_shard",
                Json::Arr(self.per_shard.iter().map(ShardStats::to_json).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(i: usize, c_t: f64, served: u64) -> ShardStats {
        let mut s = ShardStats {
            shard: i,
            served,
            last_time: served as f64,
            ..Default::default()
        };
        s.ledger.c_t = c_t;
        s.ledger.requests = served;
        s.latency_us.record(10 * (i as u32 + 1));
        s
    }

    #[test]
    fn summary_renders() {
        let s = MetricsSnapshot {
            policy: "AKPC".into(),
            engine: "xla".into(),
            ledger: CostLedger::default(),
            served: 10,
            windows: 2,
            live_cliques: 3,
            clique_hist: Histogram::new(),
            clique_gen_secs: 0.1,
            latency_us: Histogram::new(),
            per_shard: Vec::new(),
        };
        let line = s.summary();
        assert!(line.contains("policy=AKPC"));
        assert!(line.contains("engine=xla"));
        crate::util::json::parse(&s.to_json().to_string()).unwrap();
    }

    #[test]
    fn aggregate_merges_shards() {
        let gen = GenStats {
            windows: 7,
            live_cliques: 4,
            ..Default::default()
        };
        // Out-of-order shard ids must be sorted in.
        let m = MetricsSnapshot::aggregate(
            gen,
            vec![shard(1, 2.0, 5), shard(0, 3.0, 7)],
        );
        assert_eq!(m.served, 12);
        assert_eq!(m.windows, 7);
        assert!((m.ledger.c_t - 5.0).abs() < 1e-12);
        assert_eq!(m.ledger.requests, 12);
        assert_eq!(m.latency_us.count(), 2);
        assert_eq!(m.per_shard[0].shard, 0);
        assert_eq!(m.per_shard[1].shard, 1);
        crate::util::json::parse(&m.to_json().to_string()).unwrap();
    }

    #[test]
    fn prometheus_export_renders_all_families() {
        let m = MetricsSnapshot::aggregate(
            GenStats {
                windows: 3,
                live_cliques: 2,
                ..Default::default()
            },
            vec![shard(0, 3.0, 7), shard(1, 2.0, 5)],
        );
        let text = m.to_prometheus();
        assert!(text.contains("# TYPE akpc_requests_served_total counter"));
        assert!(text.contains("akpc_requests_served_total 12"));
        assert!(text.contains("akpc_cost_transfer_total 5"));
        assert!(text.contains("akpc_shard_served_total{shard=\"1\"} 5"));
        assert!(text.contains("# TYPE akpc_live_cliques gauge"));
        assert!(text.contains("akpc_latency_us_q99"));
        assert!(text.contains("# TYPE akpc_shard_occupancy gauge"));
        assert!(text.contains("akpc_shard_occupancy{shard=\"0\"} "));
        assert!(text.contains("# TYPE akpc_shard_queue_depth gauge"));
        assert!(text.contains("akpc_shard_queue_depth{shard=\"1\"} "));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.split_whitespace();
            let (name, val) = (parts.next().unwrap(), parts.next().unwrap());
            assert!(name.starts_with("akpc_"), "{line}");
            val.parse::<f64>().unwrap();
            assert!(parts.next().is_none(), "{line}");
        }
    }

    #[test]
    fn handoff_epoch_merge_does_not_double_count_gen_counters() {
        let gen = GenStats {
            windows: 2,
            clique_gen_secs: 0.5,
            ..Default::default()
        };
        let retired =
            MetricsSnapshot::aggregate(gen, vec![shard(0, 1.0, 10)]).into_handoff_epoch();
        // The successor's pipeline carried the counters: its epoch
        // already reports windows=5 cumulative.
        let last = MetricsSnapshot::aggregate(
            GenStats {
                windows: 5,
                clique_gen_secs: 1.25,
                ..Default::default()
            },
            vec![shard(0, 0.5, 7)],
        );
        let m = MetricsSnapshot::merge_epochs(&[retired], last);
        assert_eq!(m.windows, 5, "gen counters must not double-count");
        assert!((m.clique_gen_secs - 1.25).abs() < 1e-12);
        assert_eq!(m.served, 17, "shard counters do accumulate");
        assert!((m.ledger.c_t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ledger_delta_between_snapshots() {
        let early =
            MetricsSnapshot::aggregate(GenStats::default(), vec![shard(0, 3.0, 7)]);
        let late = MetricsSnapshot::aggregate(
            GenStats::default(),
            vec![shard(0, 5.0, 9), shard(1, 2.0, 4)],
        );
        let d = late.ledger_delta(&early);
        assert!((d.c_t - 4.0).abs() < 1e-12);
        assert_eq!(d.requests, 6);
    }
}
