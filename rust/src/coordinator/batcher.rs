//! Window batcher: accumulates served requests into the clique-generation
//! window (Fig. 3). A window closes when `batch_size` requests have been
//! collected — the paper's batch semantics — or when explicitly flushed
//! (idle timeout on the service side). The batcher holds at most one
//! open window, so a coordinator fed from a streaming replay
//! (`sim::replay_sharded_stream`, DESIGN.md §10.5) keeps bounded memory
//! end to end: stream chunk → serve → this window buffer.

use crate::trace::model::Request;

#[derive(Debug)]
pub struct WindowBatcher {
    batch_size: usize,
    buf: Vec<Request>,
    /// Total windows closed.
    pub windows_closed: u64,
}

impl WindowBatcher {
    pub fn new(batch_size: usize) -> Self {
        Self {
            batch_size: batch_size.max(1),
            buf: Vec::with_capacity(batch_size.max(1)),
            windows_closed: 0,
        }
    }

    /// Add a served request; returns the closed window when full.
    pub fn push(&mut self, r: Request) -> Option<Vec<Request>> {
        self.buf.push(r);
        if self.buf.len() >= self.batch_size {
            self.windows_closed += 1;
            Some(std::mem::take(&mut self.buf))
        } else {
            None
        }
    }

    /// Force-close the current window (idle flush); `None` if empty.
    pub fn flush(&mut self) -> Option<Vec<Request>> {
        if self.buf.is_empty() {
            None
        } else {
            self.windows_closed += 1;
            Some(std::mem::take(&mut self.buf))
        }
    }

    pub fn pending(&self) -> usize {
        self.buf.len()
    }

    /// Take the open window's requests *without* closing it (no
    /// `windows_closed` bump, no clique-gen tick). The elastic handoff
    /// uses this: the carried-over requests refill the successor's
    /// batcher, so the window closes at exactly the same request index
    /// a never-resized run would close it at.
    pub fn take_pending(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.buf)
    }

    /// Clone the open window's requests without disturbing the batcher.
    /// The checkpoint path uses this: a snapshot must carry the pending
    /// window (so a restored run closes windows at the same request
    /// index) while the live fleet keeps serving into the same buffer.
    pub fn pending_clone(&self) -> Vec<Request> {
        self.buf.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64) -> Request {
        Request::new(vec![0], 0, t)
    }

    #[test]
    fn closes_at_batch_size() {
        let mut b = WindowBatcher::new(3);
        assert!(b.push(req(0.0)).is_none());
        assert!(b.push(req(1.0)).is_none());
        let w = b.push(req(2.0)).unwrap();
        assert_eq!(w.len(), 3);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.windows_closed, 1);
    }

    #[test]
    fn flush_closes_partial() {
        let mut b = WindowBatcher::new(10);
        b.push(req(0.0));
        b.push(req(1.0));
        let w = b.flush().unwrap();
        assert_eq!(w.len(), 2);
        assert!(b.flush().is_none());
    }

    #[test]
    fn take_pending_does_not_count_a_window() {
        let mut b = WindowBatcher::new(10);
        b.push(req(0.0));
        b.push(req(1.0));
        let pending = b.take_pending();
        assert_eq!(pending.len(), 2);
        assert_eq!(b.windows_closed, 0);
        assert_eq!(b.pending(), 0);
        assert!(b.flush().is_none(), "buffer is empty after take");
    }

    #[test]
    fn zero_batch_size_clamped() {
        let mut b = WindowBatcher::new(0);
        assert!(b.push(req(0.0)).is_some());
    }
}
