//! Online serving coordinator — the L3 runtime around the AKPC policy.
//!
//! Architecture (vLLM-router-like leader/worker split, sized for this
//! paper's contribution — the *policy*, not the data plane):
//!
//! ```text
//!   clients ──(mpsc)──► Coordinator ──(channel)──► leader thread
//!                          │  tokio side:             owns Akpc policy +
//!                          │  routing, admission,     PJRT runtime (thread-
//!                          │  oneshot responses       affine), batcher,
//!                          ▼                          window ticks
//!                       metrics snapshots ◄─────────── ledger/cliques
//! ```
//!
//! The PJRT client is `Rc`-backed (thread-affine), so the policy and the
//! XLA runtime are constructed *on* the leader thread and never move; the
//! async side communicates exclusively through channels. Python is never
//! involved: the leader executes the AOT artifact through
//! [`crate::runtime::XlaCrmBuilder`].

pub mod batcher;
pub mod metrics;
pub mod service;

pub use batcher::WindowBatcher;
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, CoordinatorClient, ServeRequest, ServeResponse};
