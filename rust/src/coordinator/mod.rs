//! Online serving coordinator — the L3 runtime around the AKPC policy.
//!
//! Architecture (sharded actor topology, DESIGN.md §2.3 — sized for this
//! paper's contribution, the *policy*, not the data plane):
//!
//! ```text
//!   clients ──(route by server % N)──► shard actors 0..N-1
//!                  │                     each owns PackedCacheCore:
//!                  │ served requests     per-ESS cache state + cost
//!                  ▼                     ledger for a disjoint ESS set
//!            window batcher
//!                  │ closed window            ▲ Install(Arc<CliqueSnapshot>)
//!                  ▼                          │
//!            clique-gen worker ───────────────┘
//!            (CliqueGenPipeline + CRM engine, thread-affine PJRT)
//! ```
//!
//! Each shard is a single-writer actor over its ESS group (the paper's
//! per-ESS event model); the clique set is regenerated once per window by
//! one background worker and published to every shard as an `Arc`-swapped
//! immutable snapshot. The only cross-shard state is the Algorithm-6
//! retention board ([`crate::cache::CopyBoard`]). The PJRT client is
//! `Rc`-backed (thread-affine), so the CRM engine is constructed *on* the
//! worker thread and never moves; Python is never involved at runtime.
//!
//! The fleet size N is *elastic*: [`Coordinator::resize`] tears the
//! actors down to a portable [`HandoffState`] and reboots at a new
//! shard count with cache, ledgers-as-epochs, clique-gen state, and the
//! open window carried over exactly (DESIGN.md §13; the routing rule is
//! [`crate::elastic::Placement`], shared with the handoff partitioner).
//!
//! The fleet is also *supervised* (DESIGN.md §14): every rendezvous
//! reply is deadline-bounded, a dead or stalled actor surfaces as a
//! typed [`ShardLost`], and [`Coordinator::recover`] rebuilds the fleet
//! from survivor exports plus the lost shard's shadow, charging honest
//! re-transfer for the copies that died with it.
//! [`Coordinator::checkpoint_state`] snapshots a [`HandoffState`]
//! without stopping the fleet (the crash-restart path,
//! [`crate::fault::checkpoint`]).

pub mod batcher;
pub mod metrics;
pub mod service;
pub mod snapshot;

pub use batcher::WindowBatcher;
pub use metrics::{GenStats, MetricsSnapshot, ShardStats};
pub use service::{
    set_reply_timeout_ms, Coordinator, CoordinatorClient, HandoffState, ServeRequest,
    ServeResponse, ShardLost, TickMode,
};
pub use snapshot::CliqueSnapshot;
