//! The serving service: client handle + leader thread owning the policy.
//!
//! The leader thread owns the (thread-affine) AKPC policy and PJRT
//! runtime; clients talk to it over an mpsc channel and receive responses
//! on per-call reply channels. The handle is `Clone + Send + Sync`, so any
//! number of client threads can submit concurrently — the leader serializes
//! policy access (single-writer, exactly the paper's per-ESS event model).
//!
//! (The offline build environment has no tokio; the async facade is a
//! blocking-channel actor instead — same topology, same single-leader
//! semantics. See DESIGN.md §2.)

use std::sync::mpsc;
use std::time::Instant;

use crate::algo::{Akpc, CachePolicy};
use crate::config::AkpcConfig;
use crate::runtime::CrmEngine;
use crate::trace::model::Request;

use super::batcher::WindowBatcher;
use super::metrics::MetricsSnapshot;
use crate::util::Histogram;

/// A request submitted to the coordinator.
#[derive(Debug)]
pub struct ServeRequest {
    pub items: Vec<u32>,
    pub server: u32,
    /// Logical request time; `None` = wall-clock seconds since service
    /// start (live mode). Trace replay supplies explicit times.
    pub time: Option<f64>,
}

/// What the coordinator returns to the client.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    /// Items delivered (the packed cliques covering the request —
    /// Observation 4: may exceed what was asked).
    pub delivered: Vec<u32>,
    /// True if no transfer was needed (full local hit).
    pub full_hit: bool,
    /// Cost delta (C_T + C_P) attributed to this request.
    pub cost_delta: f64,
}

enum Msg {
    Serve(ServeRequest, mpsc::Sender<ServeResponse>),
    Snapshot(mpsc::Sender<MetricsSnapshot>),
    FlushWindow,
    Shutdown,
}

/// Handle to the serving leader. Cloneable; dropping the last handle shuts
/// the leader down.
pub struct Coordinator {
    tx: mpsc::Sender<Msg>,
    join: Option<std::thread::JoinHandle<MetricsSnapshot>>,
}

impl Coordinator {
    /// Start the leader thread with the given config and CRM engine.
    pub fn start(cfg: AkpcConfig, engine: CrmEngine) -> Self {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("akpc-leader".into())
            .spawn(move || leader_loop(cfg, engine, rx))
            .expect("spawn leader");
        Self {
            tx,
            join: Some(join),
        }
    }

    /// A cloneable, `Send + Sync` client for submitting from many threads.
    pub fn client(&self) -> CoordinatorClient {
        CoordinatorClient {
            tx: self.tx.clone(),
        }
    }

    /// Serve one request (blocks until the leader responds).
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        self.client().serve(req)
    }

    /// Pull a metrics snapshot.
    pub fn metrics(&self) -> anyhow::Result<MetricsSnapshot> {
        let (otx, orx) = mpsc::channel();
        self.tx
            .send(Msg::Snapshot(otx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        Ok(orx.recv()?)
    }

    /// Force-close the current clique-generation window (idle flush).
    pub fn flush_window(&self) -> anyhow::Result<()> {
        self.tx
            .send(Msg::FlushWindow)
            .map_err(|_| anyhow::anyhow!("coordinator is down"))
    }

    /// Graceful shutdown; returns the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Msg::Shutdown);
        self.join
            .take()
            .expect("not yet joined")
            .join()
            .expect("leader panicked")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Cloneable submission handle (no lifecycle control).
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::Sender<Msg>,
}

impl CoordinatorClient {
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        let (otx, orx) = mpsc::channel();
        self.tx
            .send(Msg::Serve(req, otx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        Ok(orx.recv()?)
    }
}

fn leader_loop(
    cfg: AkpcConfig,
    engine: CrmEngine,
    rx: mpsc::Receiver<Msg>,
) -> MetricsSnapshot {
    // Thread-affine construction: the PJRT client never crosses threads.
    let builder = engine.builder(&cfg.artifacts_dir);
    let engine_name = builder.engine_name().to_string();
    let mut policy = Akpc::with_builder(&cfg, builder);
    let mut batcher = WindowBatcher::new(cfg.batch_size);
    let mut latency = Histogram::new();
    let mut served: u64 = 0;
    let start = Instant::now();

    let snapshot = |policy: &Akpc,
                    served: u64,
                    latency: &Histogram,
                    engine_name: &str| MetricsSnapshot {
        policy: policy.name(),
        engine: engine_name.to_string(),
        ledger: policy.ledger().clone(),
        served,
        windows: policy.windows,
        live_cliques: policy.cliques().len(),
        clique_hist: policy.clique_sizes(),
        clique_gen_secs: policy.clique_gen_secs,
        latency_us: latency.clone(),
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Serve(sreq, resp) => {
                let t0 = Instant::now();
                let time = sreq
                    .time
                    .unwrap_or_else(|| start.elapsed().as_secs_f64());
                let r = Request::new(sreq.items, sreq.server, time);

                // Response assembly: the packed cliques covering D_i
                // (Algorithm 5 line 13 — deliver whole cliques).
                let before_hits = policy.ledger().full_hits;
                let before_total = policy.ledger().total();
                let mut delivered: Vec<u32> = Vec::with_capacity(r.items.len());
                for &d in &r.items {
                    match policy.cliques().clique_of(d) {
                        Some(c) => delivered.extend_from_slice(c),
                        None => delivered.push(d),
                    }
                }
                delivered.sort_unstable();
                delivered.dedup();

                policy.handle_request(&r);
                let after = policy.ledger();
                let full_hit = after.full_hits > before_hits;
                let cost_delta = after.total() - before_total;

                served += 1;
                latency.record(t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32);
                let _ = resp.send(ServeResponse {
                    delivered,
                    full_hit,
                    cost_delta,
                });

                if let Some(window) = batcher.push(r) {
                    policy.end_batch(&window);
                }
            }
            Msg::Snapshot(resp) => {
                let _ = resp.send(snapshot(&policy, served, &latency, &engine_name));
            }
            Msg::FlushWindow => {
                if let Some(window) = batcher.flush() {
                    policy.end_batch(&window);
                }
            }
            Msg::Shutdown => break,
        }
    }
    snapshot(&policy, served, &latency, &engine_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            batch_size: 10,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_learns_cliques() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native);
        // Two windows of a strong {1,2} bundle.
        for i in 0..20 {
            let resp = coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: 0,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
            assert!(!resp.delivered.is_empty());
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 20);
        assert_eq!(m.windows, 2);
        assert!(m.live_cliques >= 1, "learned no cliques");
        // After learning, a request for item 1 delivers the {1,2} pack.
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let final_m = coord.shutdown();
        assert_eq!(final_m.served, 21);
    }

    #[test]
    fn flush_window_forces_tick() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native);
        for i in 0..5 {
            coord
                .serve(ServeRequest {
                    items: vec![3, 4],
                    server: 0,
                    time: Some(i as f64 * 0.01),
                })
                .unwrap();
        }
        coord.flush_window().unwrap();
        let m = coord.metrics().unwrap();
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn cost_deltas_accumulate_to_ledger() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native);
        let mut sum = 0.0;
        for i in 0..10u32 {
            let r = coord
                .serve(ServeRequest {
                    items: vec![i % 4, 8],
                    server: i % 2,
                    time: Some(i as f64 * 0.3),
                })
                .unwrap();
            sum += r.cost_delta;
        }
        let m = coord.metrics().unwrap();
        assert!((m.ledger.total() - sum).abs() < 1e-9);
    }

    #[test]
    fn concurrent_clients() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native);
        let mut handles = Vec::new();
        for c in 0..8u32 {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    client
                        .serve(ServeRequest {
                            items: vec![(c + i) % 16],
                            server: c % 4,
                            time: None, // wall clock
                        })
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 400);
        assert_eq!(m.ledger.requests, 400);
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native);
        coord
            .serve(ServeRequest {
                items: vec![1],
                server: 0,
                time: Some(0.0),
            })
            .unwrap();
        drop(coord); // must not hang or panic
    }
}
