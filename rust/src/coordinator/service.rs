//! The sharded serving service: shard actors own per-ESS cache state and
//! cost ledgers, one background worker owns clique generation.
//!
//! Topology (DESIGN.md §2.3):
//!
//! ```text
//!   clients ──route by server % N──► shard 0..N-1   (PackedCacheCore:
//!      │                                │             cache + ledger for a
//!      │ served requests                │ Install     disjoint ESS set)
//!      ▼                                ▲ (Arc<CliqueSnapshot>)
//!   window batcher ──closed window──► clique-gen worker
//!                                      (CliqueGenPipeline + CRM engine)
//! ```
//!
//! Every shard is a single-writer actor over its ESS group — exactly the
//! per-ESS event model Algorithms 1/5/6 assume — and the only cross-shard
//! state is the retention [`CopyBoard`] (cache/board.rs), which keeps
//! Algorithm 6's global `G[c]` rule exact. In [`TickMode::Sync`] a window
//! close blocks until the new snapshot is installed on every shard, which
//! makes an ordered replay deterministic: the per-shard ledgers sum to the
//! single-leader ledger on the same trace. [`TickMode::Async`] trades that
//! barrier for throughput (shards keep serving under the old snapshot
//! while the worker rebuilds).
//!
//! (The offline build environment has no tokio; the async facade is a
//! blocking-channel actor system instead — same topology, same
//! single-writer semantics. See DESIGN.md §2.) Every mailbox is a
//! **bounded** `sync_channel` (DESIGN.md §11, rule L4): a slow actor
//! pushes back on its producers instead of letting queues grow without
//! limit.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::algo::{CliqueGenPipeline, PackedCacheCore};
use crate::cache::{CopyBoard, CostModel};
use crate::config::AkpcConfig;
use crate::runtime::CrmEngine;
use crate::trace::model::Request;

use super::batcher::WindowBatcher;
use super::metrics::{GenStats, MetricsSnapshot, ShardStats};
use super::snapshot::CliqueSnapshot;
use crate::util::Histogram;

/// Depth of each shard actor's mailbox. Every coordinator channel is
/// bounded (akpc-lint L4): a slow shard applies backpressure to its
/// submitters instead of queueing unboundedly. Matches
/// [`crate::sim::replay`]'s `SHARD_CHANNEL_CAP` so the service and the
/// replay harness exert the same admission behavior.
const SHARD_QUEUE_DEPTH: usize = 1024;

/// Depth of the clique-generation worker's mailbox: one window in flight
/// plus one queued. In [`TickMode::Async`] a further window close blocks
/// the closing client until the worker catches up — bounded lag by
/// construction, instead of an unbounded backlog of stale windows.
const GEN_QUEUE_DEPTH: usize = 2;

/// A request submitted to the coordinator.
#[derive(Debug)]
pub struct ServeRequest {
    pub items: Vec<u32>,
    pub server: u32,
    /// Logical request time; `None` = wall-clock seconds since service
    /// start (live mode). Trace replay supplies explicit times.
    pub time: Option<f64>,
}

/// What the coordinator returns to the client.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    /// Items delivered (the packed cliques covering the request —
    /// Observation 4: may exceed what was asked).
    pub delivered: Vec<u32>,
    /// True if no transfer was needed (full local hit).
    pub full_hit: bool,
    /// Cost delta (C_T + C_P) attributed to this request.
    pub cost_delta: f64,
}

/// How window closes propagate to the shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickMode {
    /// The serve call that closes a window blocks until the regenerated
    /// snapshot is installed on every shard. Deterministic under ordered
    /// replay; the global tick barrier the single leader had implicitly.
    Sync,
    /// The worker rebuilds in the background and Arc-swaps the snapshot in
    /// when ready; shards keep serving under the previous packing.
    Async,
}

enum ShardMsg {
    Serve(Request, mpsc::SyncSender<ServeResponse>),
    /// Install a new snapshot. The `f64` is the closed window's end time:
    /// the shard first sweeps its expiry events up to it under the *old*
    /// clique set — exactly when the single leader processed them —
    /// before swapping in the new one (retention decisions depend on
    /// `current_keys` at sweep time, so a lagging shard must not process
    /// old events under a newer snapshot).
    Install(Arc<CliqueSnapshot>, f64, mpsc::SyncSender<f64>),
    Metrics(mpsc::SyncSender<ShardStats>),
    /// Advance expiry processing to the global end time (shutdown
    /// barrier): a shard sweeps only at its own request times, so without
    /// this, retention rent accrued on its servers after its last request
    /// would be missing from its ledger vs the single leader.
    Quiesce(f64),
    Shutdown,
}

enum GenMsg {
    Window(Vec<Request>, Option<mpsc::SyncSender<()>>),
    Metrics(mpsc::SyncSender<GenStats>),
    Shutdown,
}

/// State shared by every client handle.
struct Shared {
    window: Mutex<WindowBatcher>,
    tick_mode: TickMode,
    start: Instant,
}

/// Cloneable, `Send` submission handle (no lifecycle control). Each clone
/// carries its own channel senders; only the window batcher is shared.
pub struct CoordinatorClient {
    shard_txs: Vec<mpsc::SyncSender<ShardMsg>>,
    gen_tx: mpsc::SyncSender<GenMsg>,
    shared: Arc<Shared>,
}

impl Clone for CoordinatorClient {
    fn clone(&self) -> Self {
        Self {
            shard_txs: self.shard_txs.clone(),
            gen_tx: self.gen_tx.clone(),
            shared: self.shared.clone(),
        }
    }
}

impl CoordinatorClient {
    fn route(&self, server: u32) -> usize {
        server as usize % self.shard_txs.len()
    }

    /// Serve one request (blocks until the owning shard responds).
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        let time = req
            .time
            .unwrap_or_else(|| self.shared.start.elapsed().as_secs_f64());
        let r = Request::new(req.items, req.server, time);
        // Rendezvous-sized: the caller is already blocked on `recv`, so
        // the shard's send never waits.
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.shard_txs[self.route(r.server)]
            .send(ShardMsg::Serve(r.clone(), rtx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        let resp = rrx.recv()?;

        // Window accounting happens after the response, mirroring the
        // single leader (serve, then batch — Fig. 3 causality). The mutex
        // also serializes the tick barrier in Sync mode: whoever closes
        // the window holds it until every shard installed the snapshot.
        let mut window = self
            .shared
            .window
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(batch) = window.push(r) {
            self.dispatch_window(batch)?;
        }
        drop(window);
        Ok(resp)
    }

    /// Force-close the current clique-generation window (idle flush).
    pub fn flush_window(&self) -> anyhow::Result<()> {
        let mut window = self
            .shared
            .window
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(batch) = window.flush() {
            self.dispatch_window(batch)?;
        }
        Ok(())
    }

    fn dispatch_window(&self, batch: Vec<Request>) -> anyhow::Result<()> {
        match self.shared.tick_mode {
            TickMode::Sync => {
                let (dtx, drx) = mpsc::sync_channel(1);
                self.gen_tx
                    .send(GenMsg::Window(batch, Some(dtx)))
                    .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
                drx.recv()
                    .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
            }
            TickMode::Async => {
                self.gen_tx
                    .send(GenMsg::Window(batch, None))
                    .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
            }
        }
        Ok(())
    }

    /// Pull an aggregated metrics snapshot.
    pub fn metrics(&self) -> anyhow::Result<MetricsSnapshot> {
        let (gtx, grx) = mpsc::sync_channel(1);
        self.gen_tx
            .send(GenMsg::Metrics(gtx))
            .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
        let gen = grx.recv()?;
        let mut shards = Vec::with_capacity(self.shard_txs.len());
        for tx in &self.shard_txs {
            let (stx, srx) = mpsc::sync_channel(1);
            tx.send(ShardMsg::Metrics(stx))
                .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
            shards.push(srx.recv()?);
        }
        Ok(MetricsSnapshot::aggregate(gen, shards))
    }
}

/// Handle to the sharded service. Cloning clients is cheap; dropping the
/// `Coordinator` (or calling [`Coordinator::shutdown`]) stops every actor.
pub struct Coordinator {
    client: CoordinatorClient,
    shard_joins: Vec<Option<std::thread::JoinHandle<ShardStats>>>,
    gen_join: Option<std::thread::JoinHandle<GenStats>>,
}

impl Coordinator {
    /// Start `n_shards` shard actors plus the clique-generation worker,
    /// with the deterministic [`TickMode::Sync`] window barrier.
    ///
    /// # Errors
    ///
    /// Fails if the OS refuses to spawn an actor thread (resource
    /// exhaustion); already-spawned actors are torn down by `Drop`.
    pub fn start(
        cfg: AkpcConfig,
        engine: CrmEngine,
        n_shards: usize,
    ) -> anyhow::Result<Self> {
        Self::start_with(cfg, engine, n_shards, TickMode::Sync)
    }

    /// Start with an explicit [`TickMode`]. `n_shards` is clamped to ≥ 1;
    /// requests route to shard `server % n_shards`.
    ///
    /// # Errors
    ///
    /// Fails if the OS refuses to spawn an actor thread (resource
    /// exhaustion); already-spawned actors are torn down by `Drop`.
    pub fn start_with(
        cfg: AkpcConfig,
        engine: CrmEngine,
        n_shards: usize,
        tick_mode: TickMode,
    ) -> anyhow::Result<Self> {
        let n_shards = n_shards.max(1);
        // The retention board is cross-shard state; a lone shard's local
        // G[c] already *is* the global rule, so skip the mutex entirely.
        let board = (n_shards > 1).then(|| Arc::new(CopyBoard::new()));

        let mut shard_txs = Vec::with_capacity(n_shards);
        let mut shard_joins = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(SHARD_QUEUE_DEPTH);
            let cfg = cfg.clone();
            let board = board.clone();
            let join = std::thread::Builder::new()
                .name(format!("akpc-shard-{shard}"))
                .spawn(move || shard_loop(shard, &cfg, board, rx))
                .map_err(|e| anyhow::anyhow!("spawn shard {shard}: {e}"))?;
            shard_txs.push(tx);
            shard_joins.push(Some(join));
        }

        let (gen_tx, gen_rx) = mpsc::sync_channel::<GenMsg>(GEN_QUEUE_DEPTH);
        let gen_join = {
            let cfg = cfg.clone();
            let board = board.clone();
            let txs = shard_txs.clone();
            std::thread::Builder::new()
                .name("akpc-cliquegen".into())
                .spawn(move || gen_loop(&cfg, engine, board, txs, gen_rx))
                .map_err(|e| anyhow::anyhow!("spawn clique-gen worker: {e}"))?
        };

        let client = CoordinatorClient {
            shard_txs,
            gen_tx,
            shared: Arc::new(Shared {
                window: Mutex::new(WindowBatcher::new(cfg.batch_size)),
                tick_mode,
                start: Instant::now(),
            }),
        };
        Ok(Self {
            client,
            shard_joins,
            gen_join: Some(gen_join),
        })
    }

    /// Number of shard actors.
    pub fn n_shards(&self) -> usize {
        self.client.shard_txs.len()
    }

    /// A cloneable client for submitting from many threads.
    pub fn client(&self) -> CoordinatorClient {
        self.client.clone()
    }

    /// Serve one request (blocks until the owning shard responds).
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        self.client.serve(req)
    }

    /// Pull an aggregated metrics snapshot.
    pub fn metrics(&self) -> anyhow::Result<MetricsSnapshot> {
        self.client.metrics()
    }

    /// Force-close the current clique-generation window (idle flush).
    pub fn flush_window(&self) -> anyhow::Result<()> {
        self.client.flush_window()
    }

    /// The drain barrier: sweep every shard's expiry clock forward to the
    /// global maximum request time, so per-shard ledgers account
    /// retention rent exactly like a single leader whose clock advances
    /// on every request. [`shutdown`](Self::shutdown) runs it
    /// automatically; the serving daemon's graceful drain (DESIGN.md
    /// §12.4) can also invoke it before a final metrics pull — shard
    /// mailboxes are FIFO, so a `metrics()` issued afterwards observes
    /// the swept state.
    pub fn quiesce(&self) {
        Self::quiesce_shards(&self.client.shard_txs);
    }

    fn quiesce_shards(shard_txs: &[mpsc::SyncSender<ShardMsg>]) {
        let mut t_end = f64::NEG_INFINITY;
        for tx in shard_txs {
            let (stx, srx) = mpsc::sync_channel(1);
            if tx.send(ShardMsg::Metrics(stx)).is_ok() {
                if let Ok(s) = srx.recv() {
                    t_end = t_end.max(s.last_time);
                }
            }
        }
        if t_end.is_finite() {
            for tx in shard_txs {
                let _ = tx.send(ShardMsg::Quiesce(t_end));
            }
        }
    }

    /// Stop every actor; returns `None` when already stopped. With
    /// `tolerate_panics` (the Drop path — possibly already unwinding), a
    /// panicked actor yields default stats instead of re-raising; the
    /// explicit shutdown path re-raises so the panic is not swallowed.
    fn stop(&mut self, tolerate_panics: bool) -> Option<MetricsSnapshot> {
        let gen_join = self.gen_join.take()?;
        // Worker first: any queued window is processed (and its Install
        // acked by the still-running shards) before the Shutdown drains.
        let _ = self.client.gen_tx.send(GenMsg::Shutdown);
        let gen = match gen_join.join() {
            Ok(g) => g,
            Err(_) if tolerate_panics => GenStats::default(),
            Err(payload) => std::panic::resume_unwind(payload),
        };

        Self::quiesce_shards(&self.client.shard_txs);

        let mut shards = Vec::with_capacity(self.shard_joins.len());
        for (tx, join) in self.client.shard_txs.iter().zip(&mut self.shard_joins) {
            let _ = tx.send(ShardMsg::Shutdown);
            if let Some(j) = join.take() {
                match j.join() {
                    Ok(s) => shards.push(s),
                    Err(_) if tolerate_panics => {}
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
        Some(MetricsSnapshot::aggregate(gen, shards))
    }

    /// Graceful shutdown; returns the final aggregated metrics. Re-raises
    /// if an actor thread panicked.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        // `stop` returns None only after a prior stop, which consuming
        // `self` makes unreachable; fall back to empty metrics anyway
        // rather than panicking in a teardown path (akpc-lint L3).
        match self.stop(false) {
            Some(m) => m,
            None => MetricsSnapshot::aggregate(GenStats::default(), Vec::new()),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Idempotent (no-op after shutdown()); never panics — Drop may run
        // during an unwind, and a double panic would abort and mask the
        // original failure.
        let _ = self.stop(true);
    }
}

/// One shard actor: single writer over the cache state and ledger of the
/// ESS group `{ s | s % n_shards == shard }`.
fn shard_loop(
    shard: usize,
    cfg: &AkpcConfig,
    board: Option<Arc<CopyBoard>>,
    rx: mpsc::Receiver<ShardMsg>,
) -> ShardStats {
    let mut core = PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy);
    if let Some(board) = board {
        core.cache.attach_board(board);
    }
    let mut snapshot = Arc::new(CliqueSnapshot::empty());
    let mut latency = Histogram::new();
    let mut served: u64 = 0;
    let mut last_time = f64::NEG_INFINITY;

    let stats = |core: &PackedCacheCore,
                 snapshot_version: u64,
                 served: u64,
                 last_time: f64,
                 latency: &Histogram| ShardStats {
        shard,
        ledger: core.ledger.clone(),
        served,
        latency_us: latency.clone(),
        retentions: core.cache.retentions,
        live_entries: core.cache.live_entries(),
        snapshot_version,
        last_time,
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Serve(r, resp) => {
                let t0 = Instant::now();
                // Response assembly: the packed cliques covering D_i
                // (Algorithm 5 line 13 — deliver whole cliques).
                let before_hits = core.ledger.full_hits;
                let before_total = core.ledger.total();
                let mut delivered: Vec<u32> = Vec::with_capacity(r.items.len());
                for &d in &r.items {
                    match snapshot.members_of(d) {
                        Some(c) => delivered.extend_from_slice(c),
                        None => delivered.push(d),
                    }
                }
                delivered.sort_unstable();
                delivered.dedup();

                core.handle_request(&r);
                let full_hit = core.ledger.full_hits > before_hits;
                let cost_delta = core.ledger.total() - before_total;

                served += 1;
                if r.time > last_time {
                    last_time = r.time;
                }
                latency.record(t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32);
                let _ = resp.send(ServeResponse {
                    delivered,
                    full_hit,
                    cost_delta,
                });
            }
            ShardMsg::Install(snap, window_end, clock) => {
                core.advance_time(window_end);
                if window_end > last_time {
                    last_time = window_end;
                }
                core.set_cliques(snap.iter());
                snapshot = snap;
                let _ = clock.send(last_time);
            }
            ShardMsg::Metrics(resp) => {
                let _ =
                    resp.send(stats(&core, snapshot.version, served, last_time, &latency));
            }
            ShardMsg::Quiesce(t_end) => {
                core.advance_time(t_end);
                if t_end > last_time {
                    last_time = t_end;
                }
            }
            ShardMsg::Shutdown => break,
        }
    }
    stats(&core, snapshot.version, served, last_time, &latency)
}

/// The background clique-generation worker: owns the (thread-affine) CRM
/// engine and the Algorithm-1-Event-1 pipeline; publishes snapshots.
fn gen_loop(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    board: Option<Arc<CopyBoard>>,
    shard_txs: Vec<mpsc::SyncSender<ShardMsg>>,
    rx: mpsc::Receiver<GenMsg>,
) -> GenStats {
    // Thread-affine construction: a PJRT client never crosses threads.
    let builder = engine.builder(&cfg.artifacts_dir);
    let engine_name = builder.engine_name().to_string();
    let mut pipeline = CliqueGenPipeline::new(cfg, builder);

    let stats = |pipeline: &CliqueGenPipeline, engine_name: &str| GenStats {
        policy: pipeline.policy_name(),
        engine: engine_name.to_string(),
        windows: pipeline.windows,
        live_cliques: pipeline.cliques().len(),
        clique_hist: pipeline.clique_sizes(),
        clique_gen_secs: pipeline.clique_gen_secs,
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            GenMsg::Window(batch, done) => {
                let window_end = batch
                    .last()
                    .map(|r| r.time)
                    .unwrap_or(f64::NEG_INFINITY);
                pipeline.tick(&batch);
                let snap = Arc::new(CliqueSnapshot::from_cliques(
                    pipeline.windows,
                    pipeline.cliques(),
                ));
                // Broadcast; collect every shard's sweep clock so stale
                // board tombstones can be pruned behind the global
                // watermark (see CopyBoard::prune). Capacity = shard
                // count: each shard acks exactly once, so no send blocks.
                let (ctx, crx) = mpsc::sync_channel(shard_txs.len().max(1));
                let mut expected = 0usize;
                for tx in &shard_txs {
                    if tx
                        .send(ShardMsg::Install(snap.clone(), window_end, ctx.clone()))
                        .is_ok()
                    {
                        expected += 1;
                    }
                }
                drop(ctx);
                let mut min_clock = f64::INFINITY;
                let mut acked = 0usize;
                while let Ok(clock) = crx.recv() {
                    min_clock = min_clock.min(clock);
                    acked += 1;
                }
                if acked == shard_txs.len() && acked == expected {
                    if let Some(b) = &board {
                        b.prune(min_clock);
                    }
                }
                if let Some(d) = done {
                    let _ = d.send(());
                }
            }
            GenMsg::Metrics(resp) => {
                let _ = resp.send(stats(&pipeline, &engine_name));
            }
            GenMsg::Shutdown => break,
        }
    }
    stats(&pipeline, &engine_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            batch_size: 10,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_learns_cliques() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 1).unwrap();
        // Two windows of a strong {1,2} bundle.
        for i in 0..20 {
            let resp = coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: 0,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
            assert!(!resp.delivered.is_empty());
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 20);
        assert_eq!(m.windows, 2);
        assert!(m.live_cliques >= 1, "learned no cliques");
        // After learning, a request for item 1 delivers the {1,2} pack.
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let final_m = coord.shutdown();
        assert_eq!(final_m.served, 21);
    }

    #[test]
    fn sharded_serving_learns_across_shards() {
        // Same bundle workload, but spread over 4 shards: the snapshot is
        // published to all of them, so a shard that never saw the bundle
        // still serves the whole pack.
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 4).unwrap();
        assert_eq!(coord.n_shards(), 4);
        for i in 0..20 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 2, // shards 1 and 2 stay cold
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
        }
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3, // cold shard
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 21);
        assert_eq!(m.windows, 2);
        assert_eq!(m.per_shard.len(), 4);
        let per_shard_served: u64 = m.per_shard.iter().map(|s| s.served).sum();
        assert_eq!(per_shard_served, 21);
        for s in &m.per_shard {
            assert_eq!(s.snapshot_version, 2, "shard missed an install");
        }
    }

    #[test]
    fn flush_window_forces_tick() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        for i in 0..5 {
            coord
                .serve(ServeRequest {
                    items: vec![3, 4],
                    server: 0,
                    time: Some(i as f64 * 0.01),
                })
                .unwrap();
        }
        coord.flush_window().unwrap();
        let m = coord.metrics().unwrap();
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn cost_deltas_accumulate_to_ledger() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        let mut sum = 0.0;
        for i in 0..10u32 {
            let r = coord
                .serve(ServeRequest {
                    items: vec![i % 4, 8],
                    server: i % 2,
                    time: Some(i as f64 * 0.3),
                })
                .unwrap();
            sum += r.cost_delta;
        }
        let m = coord.metrics().unwrap();
        assert!((m.ledger.total() - sum).abs() < 1e-9);
    }

    #[test]
    fn concurrent_clients() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        let mut handles = Vec::new();
        for c in 0..8u32 {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    client
                        .serve(ServeRequest {
                            items: vec![(c + i) % 16],
                            server: c % 4,
                            time: None, // wall clock
                        })
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 400);
        assert_eq!(m.ledger.requests, 400);
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 3).unwrap();
        coord
            .serve(ServeRequest {
                items: vec![1],
                server: 0,
                time: Some(0.0),
            })
            .unwrap();
        drop(coord); // must not hang or panic
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 0).unwrap();
        assert_eq!(coord.n_shards(), 1);
        coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(0.0),
            })
            .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.served, 1);
    }

    #[test]
    fn async_tick_mode_still_installs() {
        let coord =
            Coordinator::start_with(cfg(), CrmEngine::Native, 2, TickMode::Async)
                .unwrap();
        for i in 0..30 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 4,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
        }
        // Metrics goes through the worker's queue, so by the time it
        // answers, all three async window ticks have been processed.
        let m = coord.metrics().unwrap();
        assert_eq!(m.windows, 3);
        assert!(m.live_cliques >= 1);
    }
}
