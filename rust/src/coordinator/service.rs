//! The sharded serving service: shard actors own per-ESS cache state and
//! cost ledgers, one background worker owns clique generation.
//!
//! Topology (DESIGN.md §2.3):
//!
//! ```text
//!   clients ──route by server % N──► shard 0..N-1   (PackedCacheCore:
//!      │                                │             cache + ledger for a
//!      │ served requests                │ Install     disjoint ESS set)
//!      ▼                                ▲ (Arc<CliqueSnapshot>)
//!   window batcher ──closed window──► clique-gen worker
//!                                      (CliqueGenPipeline + CRM engine)
//! ```
//!
//! Every shard is a single-writer actor over its ESS group — exactly the
//! per-ESS event model Algorithms 1/5/6 assume — and the only cross-shard
//! state is the retention [`CopyBoard`] (cache/board.rs), which keeps
//! Algorithm 6's global `G[c]` rule exact. In [`TickMode::Sync`] a window
//! close blocks until the new snapshot is installed on every shard, which
//! makes an ordered replay deterministic: the per-shard ledgers sum to the
//! single-leader ledger on the same trace. [`TickMode::Async`] trades that
//! barrier for throughput (shards keep serving under the old snapshot
//! while the worker rebuilds).
//!
//! (The offline build environment has no tokio; the async facade is a
//! blocking-channel actor system instead — same topology, same
//! single-writer semantics. See DESIGN.md §2.) Every mailbox is a
//! **bounded** `sync_channel` (DESIGN.md §11, rule L4): a slow actor
//! pushes back on its producers instead of letting queues grow without
//! limit.
//!
//! ## Elastic resharding (DESIGN.md §13)
//!
//! The fleet size is no longer fixed for the process lifetime:
//! [`Coordinator::resize`] tears an N-shard coordinator down to a
//! portable [`HandoffState`] ([`Coordinator::decommission`]) and boots
//! an M-shard one from it ([`Coordinator::resume`]). The handoff is
//! *exact*: every shard quiesces to the same global `t_end`, exports
//! its live copies ([`CopyRecord`]s), the worker exports its learned
//! clique-generation state ([`GenState`]), and the pending
//! (not-yet-closed) window batch carries over unserved — so the resumed
//! fleet's ledger deltas match a static-M run from genesis within 1e-9
//! relative (`tests/elastic.rs` pins it over ~50 seeds).
//!
//! ## Fault tolerance (DESIGN.md §14)
//!
//! Every rendezvous reply is bounded by [`set_reply_timeout_ms`]: a dead
//! or stalled actor surfaces as a typed [`ShardLost`] error instead of a
//! permanent hang. A supervisor (fault/supervisor.rs, or the serving
//! daemon) detects the loss via [`Coordinator::lost_shard`] (join-handle
//! watch) or a `ShardLost` from a serve (heartbeat timeout), then calls
//! [`Coordinator::recover`]: survivors quiesce and export exactly as in
//! a [`Coordinator::decommission`], the lost shard is replaced by its
//! last shadow export, and the ledger is charged Eq. (3) re-transfer for
//! every copy that was live on the dead shard — an honest cost account
//! of the recovery. [`Coordinator::checkpoint_state`] snapshots the same
//! [`HandoffState`] without tearing the fleet down (the checkpoint path,
//! fault/checkpoint.rs).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::algo::{CliqueGenPipeline, GenState, PackedCacheCore};
use crate::cache::{CopyBoard, CopyRecord, CostModel};
use crate::config::AkpcConfig;
use crate::elastic::Placement;
use crate::runtime::CrmEngine;
use crate::trace::model::Request;

use super::batcher::WindowBatcher;
use super::metrics::{GenStats, MetricsSnapshot, ShardStats};
use super::snapshot::CliqueSnapshot;
use crate::util::Histogram;

/// Depth of each shard actor's mailbox. Every coordinator channel is
/// bounded (akpc-lint L4): a slow shard applies backpressure to its
/// submitters instead of queueing unboundedly. Matches
/// [`crate::sim::replay`]'s `SHARD_CHANNEL_CAP` so the service and the
/// replay harness exert the same admission behavior.
const SHARD_QUEUE_DEPTH: usize = 1024;

/// Depth of the clique-generation worker's mailbox: one window in flight
/// plus one queued. In [`TickMode::Async`] a further window close blocks
/// the closing client until the worker catches up — bounded lag by
/// construction, instead of an unbounded backlog of stale windows.
const GEN_QUEUE_DEPTH: usize = 2;

/// Rendezvous reply timeout in milliseconds (DESIGN.md §14). Every
/// coordinator `recv` on a reply channel is bounded by this, so a dead
/// or stalled actor surfaces as [`ShardLost`] instead of hanging the
/// caller forever. 30 s default: generous enough that a loaded CI shard
/// never trips it, short enough that a supervisor reacts.
static REPLY_TIMEOUT_MS: AtomicU64 = AtomicU64::new(30_000);

/// Set the rendezvous reply timeout (the shard "heartbeat" deadline);
/// returns the previous value. Tests drop it to tens of milliseconds so
/// an injected stall is detected quickly. Clamped to ≥ 1 ms.
pub fn set_reply_timeout_ms(ms: u64) -> u64 {
    REPLY_TIMEOUT_MS.swap(ms.max(1), Ordering::Relaxed)
}

fn reply_timeout() -> Duration {
    Duration::from_millis(REPLY_TIMEOUT_MS.load(Ordering::Relaxed))
}

/// Typed fault: an actor the caller was waiting on died (its channel
/// disconnected — thread panicked or was shut down) or stalled (no reply
/// within the [`set_reply_timeout_ms`] deadline). Recoverable by a
/// supervisor via [`Coordinator::recover`]; callers downcast with
/// `err.downcast_ref::<ShardLost>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardLost {
    /// Index of the lost shard actor; `None` = the clique-gen worker.
    pub shard: Option<usize>,
    /// What the caller observed: `"stalled (reply timeout)"` or
    /// `"died (channel disconnected)"`.
    pub reason: &'static str,
}

impl ShardLost {
    fn stalled(shard: Option<usize>) -> Self {
        Self { shard, reason: "stalled (reply timeout)" }
    }

    fn died(shard: Option<usize>) -> Self {
        Self { shard, reason: "died (channel disconnected)" }
    }
}

impl std::fmt::Display for ShardLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.shard {
            Some(i) => write!(f, "shard {i} {}", self.reason),
            None => write!(f, "clique-gen worker {}", self.reason),
        }
    }
}

impl std::error::Error for ShardLost {}

/// Bounded rendezvous receive: the one place a coordinator thread waits
/// on an actor reply (akpc-lint L6 — no bare `recv()` in this module).
fn recv_reply<T>(rx: &mpsc::Receiver<T>, shard: Option<usize>) -> Result<T, ShardLost> {
    match rx.recv_timeout(reply_timeout()) {
        Ok(v) => Ok(v),
        Err(mpsc::RecvTimeoutError::Timeout) => Err(ShardLost::stalled(shard)),
        Err(mpsc::RecvTimeoutError::Disconnected) => Err(ShardLost::died(shard)),
    }
}

/// A request submitted to the coordinator.
#[derive(Debug)]
pub struct ServeRequest {
    pub items: Vec<u32>,
    pub server: u32,
    /// Logical request time; `None` = wall-clock seconds since service
    /// start (live mode). Trace replay supplies explicit times.
    pub time: Option<f64>,
}

/// What the coordinator returns to the client.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    /// Items delivered (the packed cliques covering the request —
    /// Observation 4: may exceed what was asked).
    pub delivered: Vec<u32>,
    /// True if no transfer was needed (full local hit).
    pub full_hit: bool,
    /// Cost delta (C_T + C_P) attributed to this request.
    pub cost_delta: f64,
}

/// How window closes propagate to the shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickMode {
    /// The serve call that closes a window blocks until the regenerated
    /// snapshot is installed on every shard. Deterministic under ordered
    /// replay; the global tick barrier the single leader had implicitly.
    Sync,
    /// The worker rebuilds in the background and Arc-swaps the snapshot in
    /// when ready; shards keep serving under the previous packing.
    Async,
}

enum ShardMsg {
    Serve(Request, mpsc::SyncSender<ServeResponse>),
    /// Install a new snapshot. The `f64` is the closed window's end time:
    /// the shard first sweeps its expiry events up to it under the *old*
    /// clique set — exactly when the single leader processed them —
    /// before swapping in the new one (retention decisions depend on
    /// `current_keys` at sweep time, so a lagging shard must not process
    /// old events under a newer snapshot).
    Install(Arc<CliqueSnapshot>, f64, mpsc::SyncSender<f64>),
    Metrics(mpsc::SyncSender<ShardStats>),
    /// Advance expiry processing to the global end time (shutdown
    /// barrier): a shard sweeps only at its own request times, so without
    /// this, retention rent accrued on its servers after its last request
    /// would be missing from its ledger vs the single leader.
    Quiesce(f64),
    /// Export every live cache copy (elastic handoff). Sent after a
    /// `Quiesce` on the same FIFO mailbox, so the export observes the
    /// fully swept state.
    Export(mpsc::SyncSender<Vec<CopyRecord>>),
    Shutdown,
}

enum GenMsg {
    Window(Vec<Request>, Option<mpsc::SyncSender<()>>),
    Metrics(mpsc::SyncSender<GenStats>),
    /// Export the learned pipeline state (elastic handoff). FIFO with
    /// `Window`, so any queued async windows tick first.
    Export(mpsc::SyncSender<GenState>),
    Shutdown,
}

/// State shared by every client handle.
struct Shared {
    window: Mutex<WindowBatcher>,
    tick_mode: TickMode,
    start: Instant,
}

/// Cloneable, `Send` submission handle (no lifecycle control). Each clone
/// carries its own channel senders; only the window batcher is shared.
pub struct CoordinatorClient {
    shard_txs: Vec<mpsc::SyncSender<ShardMsg>>,
    gen_tx: mpsc::SyncSender<GenMsg>,
    /// The one `server → shard` ownership rule, shared with the elastic
    /// handoff partitioner so routing can never desync from state
    /// ownership (elastic/placement.rs).
    placement: Placement,
    /// Per-shard in-flight `Serve` counts: incremented by clients before
    /// the mailbox send, decremented by the shard on receipt — i.e. the
    /// observable mailbox depth, exported as the
    /// `akpc_shard_queue_depth` gauge.
    queue_depths: Vec<Arc<AtomicUsize>>,
    shared: Arc<Shared>,
}

impl Clone for CoordinatorClient {
    fn clone(&self) -> Self {
        Self {
            shard_txs: self.shard_txs.clone(),
            gen_tx: self.gen_tx.clone(),
            placement: self.placement,
            queue_depths: self.queue_depths.clone(),
            shared: self.shared.clone(),
        }
    }
}

impl CoordinatorClient {
    fn route(&self, server: u32) -> usize {
        self.placement.shard_of(server)
    }

    /// The placement rule this client routes by.
    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Serve one request (blocks until the owning shard responds).
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        let time = req
            .time
            .unwrap_or_else(|| self.shared.start.elapsed().as_secs_f64());
        let r = Request::new(req.items, req.server, time);
        // Rendezvous-sized: the caller is already blocked on `recv`, so
        // the shard's send never waits.
        let (rtx, rrx) = mpsc::sync_channel(1);
        let shard = self.route(r.server);
        self.queue_depths[shard].fetch_add(1, Ordering::Relaxed);
        if self.shard_txs[shard]
            .send(ShardMsg::Serve(r.clone(), rtx))
            .is_err()
        {
            self.queue_depths[shard].fetch_sub(1, Ordering::Relaxed);
            return Err(ShardLost::died(Some(shard)).into());
        }
        let resp = recv_reply(&rrx, Some(shard))?;

        // Window accounting happens after the response, mirroring the
        // single leader (serve, then batch — Fig. 3 causality). The mutex
        // also serializes the tick barrier in Sync mode: whoever closes
        // the window holds it until every shard installed the snapshot.
        let mut window = self
            .shared
            .window
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(batch) = window.push(r) {
            self.dispatch_window(batch)?;
        }
        drop(window);
        Ok(resp)
    }

    /// Force-close the current clique-generation window (idle flush).
    pub fn flush_window(&self) -> anyhow::Result<()> {
        let mut window = self
            .shared
            .window
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(batch) = window.flush() {
            self.dispatch_window(batch)?;
        }
        Ok(())
    }

    fn dispatch_window(&self, batch: Vec<Request>) -> anyhow::Result<()> {
        match self.shared.tick_mode {
            TickMode::Sync => {
                let (dtx, drx) = mpsc::sync_channel(1);
                self.gen_tx
                    .send(GenMsg::Window(batch, Some(dtx)))
                    .map_err(|_| ShardLost::died(None))?;
                recv_reply(&drx, None)?;
            }
            TickMode::Async => {
                self.gen_tx
                    .send(GenMsg::Window(batch, None))
                    .map_err(|_| anyhow::anyhow!("coordinator is down"))?;
            }
        }
        Ok(())
    }

    /// Pull an aggregated metrics snapshot.
    pub fn metrics(&self) -> anyhow::Result<MetricsSnapshot> {
        let (gtx, grx) = mpsc::sync_channel(1);
        self.gen_tx
            .send(GenMsg::Metrics(gtx))
            .map_err(|_| ShardLost::died(None))?;
        let gen = recv_reply(&grx, None)?;
        let mut shards = Vec::with_capacity(self.shard_txs.len());
        for (i, tx) in self.shard_txs.iter().enumerate() {
            let (stx, srx) = mpsc::sync_channel(1);
            tx.send(ShardMsg::Metrics(stx))
                .map_err(|_| ShardLost::died(Some(i)))?;
            shards.push(recv_reply(&srx, Some(i))?);
        }
        Ok(MetricsSnapshot::aggregate(gen, shards))
    }
}

/// Everything a decommissioned coordinator hands to its successor —
/// the portable half of an elastic resize (DESIGN.md §13). Produced by
/// [`Coordinator::decommission`], consumed by [`Coordinator::resume`];
/// the new fleet size is chosen at resume time, so the same state can
/// be repartitioned to any shard count.
pub struct HandoffState {
    pub(crate) cfg: AkpcConfig,
    pub(crate) engine: CrmEngine,
    pub(crate) tick_mode: TickMode,
    /// Learned clique-generation state (CRM diff base, clique set,
    /// sliding batch window, counters).
    pub(crate) gen: GenState,
    /// Every live cache copy across all donor shards, post-quiesce.
    pub(crate) copies: Vec<CopyRecord>,
    /// The global quiesce point `t_end` (`-∞` if no request was ever
    /// served): every copy's expiry is `> clock`, and the resumed
    /// shards' sweep clocks start here.
    pub(crate) clock: f64,
    /// Requests already served but not yet in a closed window; the
    /// resumed batcher refills with them so window boundaries stay
    /// identical to a never-resized run.
    pub(crate) pending: Vec<Request>,
    /// Wall-clock epoch of the original `start()`, carried over so
    /// live-mode (`time: None`) timestamps stay monotone across resizes.
    pub(crate) start: Instant,
}

impl HandoffState {
    /// The global quiesce point (`-∞` if the donor never served).
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Live cache copies being handed off.
    pub fn n_copies(&self) -> usize {
        self.copies.len()
    }

    /// Requests carried over in the open window.
    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }
}

/// Per-shard boot seed for a resumed coordinator: the snapshot to serve
/// under, the donor's quiesce clock, and this shard's partition of the
/// handed-off copies.
struct ShardSeed {
    snapshot: Arc<CliqueSnapshot>,
    clock: f64,
    copies: Vec<CopyRecord>,
}

/// Handle to the sharded service. Cloning clients is cheap; dropping the
/// `Coordinator` (or calling [`Coordinator::shutdown`]) stops every actor.
pub struct Coordinator {
    client: CoordinatorClient,
    shard_joins: Vec<Option<std::thread::JoinHandle<ShardStats>>>,
    gen_join: Option<std::thread::JoinHandle<GenStats>>,
    // Remembered so a decommission can hand them to the successor.
    cfg: AkpcConfig,
    engine: CrmEngine,
    tick_mode: TickMode,
}

impl Coordinator {
    /// Start `n_shards` shard actors plus the clique-generation worker,
    /// with the deterministic [`TickMode::Sync`] window barrier.
    ///
    /// # Errors
    ///
    /// Fails if the OS refuses to spawn an actor thread (resource
    /// exhaustion); already-spawned actors are torn down by `Drop`.
    pub fn start(
        cfg: AkpcConfig,
        engine: CrmEngine,
        n_shards: usize,
    ) -> anyhow::Result<Self> {
        Self::start_with(cfg, engine, n_shards, TickMode::Sync)
    }

    /// Start with an explicit [`TickMode`]. `n_shards` is clamped to ≥ 1;
    /// requests route to shard `server % n_shards`.
    ///
    /// # Errors
    ///
    /// Fails if the OS refuses to spawn an actor thread (resource
    /// exhaustion); already-spawned actors are torn down by `Drop`.
    pub fn start_with(
        cfg: AkpcConfig,
        engine: CrmEngine,
        n_shards: usize,
        tick_mode: TickMode,
    ) -> anyhow::Result<Self> {
        Self::boot(cfg, engine, n_shards, tick_mode, None, Vec::new(), Instant::now())
    }

    /// The one spawn path behind both [`start_with`](Self::start_with)
    /// (fresh state) and [`resume`](Self::resume) (handed-off state).
    fn boot(
        cfg: AkpcConfig,
        engine: CrmEngine,
        n_shards: usize,
        tick_mode: TickMode,
        seed: Option<(GenState, Vec<CopyRecord>, f64)>,
        pending: Vec<Request>,
        start: Instant,
    ) -> anyhow::Result<Self> {
        let n_shards = n_shards.max(1);
        let placement = Placement::new(n_shards);
        // The retention board is cross-shard state; a lone shard's local
        // G[c] already *is* the global rule, so skip the mutex entirely.
        let board = (n_shards > 1).then(|| Arc::new(CopyBoard::new()));

        // Partition the handed-off state by the *new* placement: the
        // snapshot every shard serves under, plus each shard's slice of
        // the live copies. Routing uses the same `Placement`, so a copy
        // can only land where its server's requests will.
        let (gen_seed, mut shard_seeds) = match seed {
            None => (None, (0..n_shards).map(|_| None).collect::<Vec<_>>()),
            Some((gen, copies, clock)) => {
                let snap = Arc::new(CliqueSnapshot::from_cliques(gen.windows, &gen.cliques));
                let mut per_shard: Vec<Vec<CopyRecord>> =
                    (0..n_shards).map(|_| Vec::new()).collect();
                for r in copies {
                    per_shard[placement.shard_of(r.server)].push(r);
                }
                let seeds = per_shard
                    .into_iter()
                    .map(|copies| {
                        Some(ShardSeed {
                            snapshot: snap.clone(),
                            clock,
                            copies,
                        })
                    })
                    .collect();
                (Some(gen), seeds)
            }
        };

        let queue_depths: Vec<Arc<AtomicUsize>> = (0..n_shards)
            .map(|_| Arc::new(AtomicUsize::new(0)))
            .collect();
        let mut shard_txs = Vec::with_capacity(n_shards);
        let mut shard_joins = Vec::with_capacity(n_shards);
        for shard in 0..n_shards {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(SHARD_QUEUE_DEPTH);
            let cfg = cfg.clone();
            let board = board.clone();
            let depth = queue_depths[shard].clone();
            let seed = shard_seeds[shard].take();
            let join = std::thread::Builder::new()
                .name(format!("akpc-shard-{shard}"))
                .spawn(move || shard_loop(shard, &cfg, board, seed, depth, rx))
                .map_err(|e| anyhow::anyhow!("spawn shard {shard}: {e}"))?;
            shard_txs.push(tx);
            shard_joins.push(Some(join));
        }

        let (gen_tx, gen_rx) = mpsc::sync_channel::<GenMsg>(GEN_QUEUE_DEPTH);
        let gen_join = {
            let cfg = cfg.clone();
            let board = board.clone();
            let txs = shard_txs.clone();
            std::thread::Builder::new()
                .name("akpc-cliquegen".into())
                .spawn(move || gen_loop(&cfg, engine, board, txs, gen_seed, gen_rx))
                .map_err(|e| anyhow::anyhow!("spawn clique-gen worker: {e}"))?
        };

        let client = CoordinatorClient {
            shard_txs,
            gen_tx,
            placement,
            queue_depths,
            shared: Arc::new(Shared {
                window: Mutex::new(WindowBatcher::new(cfg.batch_size)),
                tick_mode,
                start,
            }),
        };
        // Refill the open window with the donor's carried-over requests
        // (already served there — only the batching state migrates).
        // Going through push + dispatch keeps window boundaries exact
        // even if a smaller batch_size closes a window right here.
        {
            let mut window = client
                .shared
                .window
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            for r in pending {
                if let Some(batch) = window.push(r) {
                    client.dispatch_window(batch)?;
                }
            }
        }
        Ok(Self {
            client,
            shard_joins,
            gen_join: Some(gen_join),
            cfg,
            engine,
            tick_mode,
        })
    }

    /// Number of shard actors.
    pub fn n_shards(&self) -> usize {
        self.client.shard_txs.len()
    }

    /// The placement rule requests route by (and handoffs partition by).
    pub fn placement(&self) -> Placement {
        self.client.placement
    }

    /// A cloneable client for submitting from many threads.
    pub fn client(&self) -> CoordinatorClient {
        self.client.clone()
    }

    /// Serve one request (blocks until the owning shard responds).
    pub fn serve(&self, req: ServeRequest) -> anyhow::Result<ServeResponse> {
        self.client.serve(req)
    }

    /// Pull an aggregated metrics snapshot.
    pub fn metrics(&self) -> anyhow::Result<MetricsSnapshot> {
        self.client.metrics()
    }

    /// Force-close the current clique-generation window (idle flush).
    pub fn flush_window(&self) -> anyhow::Result<()> {
        self.client.flush_window()
    }

    /// The drain barrier: sweep every shard's expiry clock forward to the
    /// global maximum request time, so per-shard ledgers account
    /// retention rent exactly like a single leader whose clock advances
    /// on every request. [`shutdown`](Self::shutdown) runs it
    /// automatically; the serving daemon's graceful drain (DESIGN.md
    /// §12.4) can also invoke it before a final metrics pull — shard
    /// mailboxes are FIFO, so a `metrics()` issued afterwards observes
    /// the swept state.
    pub fn quiesce(&self) {
        Self::quiesce_shards(&self.client.shard_txs, None, f64::NEG_INFINITY);
    }

    /// Sweep every shard to the global max request time; returns that
    /// `t_end`, or `None` when no shard ever saw a request (nothing to
    /// sweep — sweep clocks stay at `-∞`). `skip` excludes a lost shard
    /// from the barrier (recovery path — its channel may be dead or its
    /// actor wedged); `floor` folds an external lower bound into `t_end`
    /// (the lost shard's shadow clock), so survivors still sweep past the
    /// global maximum even when the dead shard saw the latest request.
    /// Best-effort per shard: a shard that fails the metrics rendezvous
    /// is skipped rather than failing the barrier.
    fn quiesce_shards(
        shard_txs: &[mpsc::SyncSender<ShardMsg>],
        skip: Option<usize>,
        floor: f64,
    ) -> Option<f64> {
        let mut t_end = floor;
        for (i, tx) in shard_txs.iter().enumerate() {
            if skip == Some(i) {
                continue;
            }
            let (stx, srx) = mpsc::sync_channel(1);
            if tx.send(ShardMsg::Metrics(stx)).is_ok() {
                if let Ok(s) = recv_reply(&srx, Some(i)) {
                    t_end = t_end.max(s.last_time);
                }
            }
        }
        if t_end.is_finite() {
            for (i, tx) in shard_txs.iter().enumerate() {
                if skip == Some(i) {
                    continue;
                }
                let _ = tx.send(ShardMsg::Quiesce(t_end));
            }
            Some(t_end)
        } else {
            None
        }
    }

    /// Tear the fleet down to portable state (elastic handoff step 1).
    ///
    /// Sequence: take the open window's pending requests (without
    /// dispatching them — they carry over), export the worker's learned
    /// state and stop it (so no Install can race the barrier), quiesce
    /// every shard to the same global `t_end`, export each shard's live
    /// copies, and join. Returns the retired fleet's final metrics (one
    /// closed epoch — the serving daemon accumulates these across
    /// reloads) plus the [`HandoffState`] for [`resume`](Self::resume).
    ///
    /// # Errors
    ///
    /// Fails if the coordinator was already stopped; re-raises if an
    /// actor thread panicked.
    pub fn decommission(mut self) -> anyhow::Result<(MetricsSnapshot, HandoffState)> {
        let pending = {
            let mut window = self
                .client
                .shared
                .window
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            window.take_pending()
        };

        // FIFO with Window: queued async ticks land before the export.
        let gen_state = {
            let (tx, rx) = mpsc::sync_channel(1);
            self.client
                .gen_tx
                .send(GenMsg::Export(tx))
                .map_err(|_| ShardLost::died(None))?;
            recv_reply(&rx, None)?
        };
        let gen_join = self
            .gen_join
            .take()
            .ok_or_else(|| anyhow::anyhow!("coordinator already stopped"))?;
        let _ = self.client.gen_tx.send(GenMsg::Shutdown);
        let gen = match gen_join.join() {
            Ok(g) => g,
            Err(payload) => std::panic::resume_unwind(payload),
        };

        let clock = Self::quiesce_shards(&self.client.shard_txs, None, f64::NEG_INFINITY)
            .unwrap_or(f64::NEG_INFINITY);
        let mut copies = Vec::new();
        let mut shards = Vec::with_capacity(self.shard_joins.len());
        for (i, (tx, join)) in self
            .client
            .shard_txs
            .iter()
            .zip(&mut self.shard_joins)
            .enumerate()
        {
            let (ctx, crx) = mpsc::sync_channel(1);
            if tx.send(ShardMsg::Export(ctx)).is_ok() {
                if let Ok(mut recs) = recv_reply(&crx, Some(i)) {
                    copies.append(&mut recs);
                }
            }
            let _ = tx.send(ShardMsg::Shutdown);
            if let Some(j) = join.take() {
                match j.join() {
                    Ok(s) => shards.push(s),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
        let retired = MetricsSnapshot::aggregate(gen, shards);
        let handoff = HandoffState {
            cfg: self.cfg.clone(),
            engine: self.engine,
            tick_mode: self.tick_mode,
            gen: gen_state,
            copies,
            clock,
            pending,
            start: self.client.shared.start,
        };
        Ok((retired, handoff))
    }

    /// Boot a fleet of `n_shards` from a handoff (elastic step 2). The
    /// copies are repartitioned by the new [`Placement`], each shard
    /// seeds its cache through `CacheState::import_live` (board mirror
    /// included), the worker imports the donor's pipeline state, and the
    /// open window resumes with the carried-over requests — so serving
    /// continues exactly where the donor stopped, at the new fleet size.
    ///
    /// # Errors
    ///
    /// Fails if the OS refuses to spawn an actor thread.
    pub fn resume(handoff: HandoffState, n_shards: usize) -> anyhow::Result<Self> {
        let HandoffState {
            cfg,
            engine,
            tick_mode,
            gen,
            copies,
            clock,
            pending,
            start,
        } = handoff;
        Self::boot(
            cfg,
            engine,
            n_shards,
            tick_mode,
            Some((gen, copies, clock)),
            pending,
            start,
        )
    }

    /// Resize the fleet N→M in one step: decommission, then resume at
    /// `n_shards`. Returns the new coordinator plus the retired fleet's
    /// metrics epoch (callers accumulate epochs across resizes; a no-op
    /// resize M==N still closes an epoch, which keeps the accounting
    /// uniform). Existing [`CoordinatorClient`]s of the old fleet are
    /// invalidated — their serves fail with "coordinator is down".
    pub fn resize(self, n_shards: usize) -> anyhow::Result<(Self, MetricsSnapshot)> {
        let (retired, handoff) = self.decommission()?;
        let next = Self::resume(handoff, n_shards)?;
        Ok((next, retired))
    }

    /// Join-handle watch (DESIGN.md §14.2): index of the first shard
    /// whose actor thread has already exited — i.e. panicked, since a
    /// live coordinator never shuts a shard down. `None` = all running.
    /// A *stalled* shard is not detected here (its thread is alive);
    /// that fault surfaces as a [`ShardLost`] with `reason` "stalled"
    /// from the serve that hit the reply timeout.
    pub fn lost_shard(&self) -> Option<usize> {
        self.shard_joins.iter().position(|j| {
            j.as_ref().is_some_and(std::thread::JoinHandle::is_finished)
        })
    }

    /// Shadow capture (DESIGN.md §14.2): export one shard's live copies
    /// without disturbing it. A supervisor calls this at every window
    /// boundary so that, when the shard is later lost, its state at the
    /// last boundary is known exactly (the fault hooks fire before any
    /// serve mutates state, so boundary shadows are fault-time truth).
    pub fn export_shard_copies(&self, shard: usize) -> anyhow::Result<Vec<CopyRecord>> {
        let tx = self
            .client
            .shard_txs
            .get(shard)
            .ok_or_else(|| anyhow::anyhow!("no shard {shard}"))?;
        let (ctx, crx) = mpsc::sync_channel(1);
        tx.send(ShardMsg::Export(ctx))
            .map_err(|_| ShardLost::died(Some(shard)))?;
        Ok(recv_reply(&crx, Some(shard))?)
    }

    /// Snapshot the full fleet state as a [`HandoffState`] *without*
    /// tearing the fleet down — the checkpoint path (DESIGN.md §14.3,
    /// fault/checkpoint.rs). Identical content to what
    /// [`decommission`](Self::decommission) would hand off at this
    /// instant: open-window pending, learned gen state, a global
    /// quiesce, and every shard's live copies.
    ///
    /// The caller must guarantee no serve is in flight (the daemon holds
    /// its submission lock; offline drivers are single-threaded) —
    /// otherwise the pending/gen/copies captures could straddle a window
    /// close and disagree with each other.
    ///
    /// # Errors
    ///
    /// [`ShardLost`] if an actor is dead or stalled.
    pub fn checkpoint_state(&self) -> anyhow::Result<HandoffState> {
        let pending = {
            let window = self
                .client
                .shared
                .window
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            window.pending_clone()
        };
        // FIFO with Window: queued async ticks land before the export,
        // exactly as in `decommission`.
        let gen = {
            let (tx, rx) = mpsc::sync_channel(1);
            self.client
                .gen_tx
                .send(GenMsg::Export(tx))
                .map_err(|_| ShardLost::died(None))?;
            recv_reply(&rx, None)?
        };
        let clock = Self::quiesce_shards(&self.client.shard_txs, None, f64::NEG_INFINITY)
            .unwrap_or(f64::NEG_INFINITY);
        let mut copies = Vec::new();
        for (i, tx) in self.client.shard_txs.iter().enumerate() {
            let (ctx, crx) = mpsc::sync_channel(1);
            tx.send(ShardMsg::Export(ctx))
                .map_err(|_| ShardLost::died(Some(i)))?;
            copies.append(&mut recv_reply(&crx, Some(i))?);
        }
        Ok(HandoffState {
            cfg: self.cfg.clone(),
            engine: self.engine,
            tick_mode: self.tick_mode,
            gen,
            copies,
            clock,
            pending,
            start: self.client.shared.start,
        })
    }

    /// Rebuild the fleet after losing shard `lost` (DESIGN.md §14.2).
    ///
    /// The survivors go through the exact decommission barrier (gen
    /// export, quiesce, copy export, join); the lost shard contributes
    /// its supervisor-held shadow instead: `shadow_copies` from the last
    /// [`export_shard_copies`](Self::export_shard_copies) and
    /// `shadow_stats` from the last per-shard metrics pull. Copies still
    /// live on the dead shard at the quiesce point are restored to the
    /// new fleet **and charged as fresh Eq. (3) packed transfers** on
    /// the retired epoch's ledger — the cache content is recovered from
    /// the shadow, but the bytes would have to cross the network again,
    /// and the ledger stays an honest account of that. A panicked actor
    /// is reaped without re-raising (the panic *is* the fault being
    /// handled); a stalled actor is detached — its channels disconnect
    /// when the old fleet's senders drop, and it exits on wake-up.
    ///
    /// Returns the new same-size fleet, the retired epoch's metrics
    /// (shadow stats standing in for the lost shard), and the total
    /// re-transfer charge.
    ///
    /// # Errors
    ///
    /// Fails if `lost` is out of range, the coordinator was already
    /// stopped, or a *survivor* is also dead/stalled ([`ShardLost`]).
    pub fn recover(
        mut self,
        lost: usize,
        shadow_copies: Vec<CopyRecord>,
        shadow_stats: ShardStats,
    ) -> anyhow::Result<(Self, MetricsSnapshot, f64)> {
        let n_shards = self.client.shard_txs.len();
        anyhow::ensure!(lost < n_shards, "recover: no shard {lost}");
        let pending = {
            let mut window = self
                .client
                .shared
                .window
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            window.take_pending()
        };

        // Worker first, as in `decommission`: export learned state and
        // stop it so no Install can race the quiesce barrier.
        let gen_state = {
            let (tx, rx) = mpsc::sync_channel(1);
            self.client
                .gen_tx
                .send(GenMsg::Export(tx))
                .map_err(|_| ShardLost::died(None))?;
            recv_reply(&rx, None)?
        };
        let gen_join = self
            .gen_join
            .take()
            .ok_or_else(|| anyhow::anyhow!("coordinator already stopped"))?;
        let _ = self.client.gen_tx.send(GenMsg::Shutdown);
        let gen = match gen_join.join() {
            Ok(g) => g,
            Err(payload) => std::panic::resume_unwind(payload),
        };

        // Quiesce survivors to the global max request time *including*
        // the dead shard's shadow clock — it may have seen the latest
        // request, and survivors must still sweep retention rent to it.
        let clock =
            Self::quiesce_shards(&self.client.shard_txs, Some(lost), shadow_stats.last_time)
                .unwrap_or(f64::NEG_INFINITY);

        // Re-transfer charge: every copy still live on the lost shard at
        // the quiesce point is restored to the rebuilt fleet and billed
        // as a fresh packed transfer (Eq. 3) on the retired epoch.
        let restored: Vec<CopyRecord> = shadow_copies
            .into_iter()
            .filter(|c| c.expiry > clock)
            .collect();
        let model = CostModel::from_config(&self.cfg);
        let recharge: f64 = restored.iter().map(|c| model.transfer_packed(c.size)).sum();
        let mut shadow_stats = shadow_stats;
        shadow_stats.ledger.c_t += recharge;
        shadow_stats.ledger.transfers += restored.len() as u64;

        let mut copies = restored;
        let mut shards = Vec::with_capacity(n_shards);
        for (i, (tx, join)) in self
            .client
            .shard_txs
            .iter()
            .zip(&mut self.shard_joins)
            .enumerate()
        {
            if i == lost {
                let _ = tx.send(ShardMsg::Shutdown);
                if let Some(j) = join.take() {
                    if j.is_finished() {
                        // Reap the panic payload without re-raising —
                        // the panic is the fault being recovered from.
                        let _ = j.join();
                    }
                    // else: stalled — detach (see doc comment above).
                }
                shards.push(shadow_stats.clone());
            } else {
                let (ctx, crx) = mpsc::sync_channel(1);
                tx.send(ShardMsg::Export(ctx))
                    .map_err(|_| ShardLost::died(Some(i)))?;
                copies.append(&mut recv_reply(&crx, Some(i))?);
                let _ = tx.send(ShardMsg::Shutdown);
                if let Some(j) = join.take() {
                    match j.join() {
                        Ok(s) => shards.push(s),
                        Err(payload) => std::panic::resume_unwind(payload),
                    }
                }
            }
        }
        let retired = MetricsSnapshot::aggregate(gen, shards);
        let next = Self::boot(
            self.cfg.clone(),
            self.engine,
            n_shards,
            self.tick_mode,
            Some((gen_state, copies, clock)),
            pending,
            self.client.shared.start,
        )?;
        Ok((next, retired, recharge))
    }

    /// Stop every actor; returns `None` when already stopped. With
    /// `tolerate_panics` (the Drop path — possibly already unwinding), a
    /// panicked actor yields default stats instead of re-raising; the
    /// explicit shutdown path re-raises so the panic is not swallowed.
    fn stop(&mut self, tolerate_panics: bool) -> Option<MetricsSnapshot> {
        let gen_join = self.gen_join.take()?;
        // Worker first: any queued window is processed (and its Install
        // acked by the still-running shards) before the Shutdown drains.
        let _ = self.client.gen_tx.send(GenMsg::Shutdown);
        let gen = match gen_join.join() {
            Ok(g) => g,
            Err(_) if tolerate_panics => GenStats::default(),
            Err(payload) => std::panic::resume_unwind(payload),
        };

        Self::quiesce_shards(&self.client.shard_txs, None, f64::NEG_INFINITY);

        let mut shards = Vec::with_capacity(self.shard_joins.len());
        for (tx, join) in self.client.shard_txs.iter().zip(&mut self.shard_joins) {
            let _ = tx.send(ShardMsg::Shutdown);
            if let Some(j) = join.take() {
                match j.join() {
                    Ok(s) => shards.push(s),
                    Err(_) if tolerate_panics => {}
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
        Some(MetricsSnapshot::aggregate(gen, shards))
    }

    /// Graceful shutdown; returns the final aggregated metrics. Re-raises
    /// if an actor thread panicked.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        // `stop` returns None only after a prior stop, which consuming
        // `self` makes unreachable; fall back to empty metrics anyway
        // rather than panicking in a teardown path (akpc-lint L3).
        match self.stop(false) {
            Some(m) => m,
            None => MetricsSnapshot::aggregate(GenStats::default(), Vec::new()),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Idempotent (no-op after shutdown()); never panics — Drop may run
        // during an unwind, and a double panic would abort and mask the
        // original failure.
        let _ = self.stop(true);
    }
}

/// One shard actor: single writer over the cache state and ledger of the
/// ESS group `{ s | s % n_shards == shard }` (see [`Placement`]). A
/// `seed` (elastic resume) preloads the donor's snapshot, sweep clock,
/// and this shard's partition of the handed-off copies before the first
/// message is taken.
fn shard_loop(
    shard: usize,
    cfg: &AkpcConfig,
    board: Option<Arc<CopyBoard>>,
    seed: Option<ShardSeed>,
    depth: Arc<AtomicUsize>,
    rx: mpsc::Receiver<ShardMsg>,
) -> ShardStats {
    let mut core = PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy);
    if let Some(board) = board {
        core.cache.attach_board(board);
    }
    let mut snapshot = Arc::new(CliqueSnapshot::empty());
    let mut latency = Histogram::new();
    let mut served: u64 = 0;
    let mut last_time = f64::NEG_INFINITY;
    if let Some(seed) = seed {
        // Order matters: the board must be attached (above, while the
        // state is empty) before import_live mirrors the copies into it,
        // and the clique set must be current before any sweep so
        // retention judges against the donor's `Clique(W)`.
        core.set_cliques(seed.snapshot.iter());
        core.cache.import_live(seed.clock, &seed.copies);
        if seed.clock > last_time {
            last_time = seed.clock;
        }
        snapshot = seed.snapshot;
    }

    let stats = |core: &PackedCacheCore,
                 snapshot_version: u64,
                 served: u64,
                 last_time: f64,
                 latency: &Histogram,
                 queue_depth: usize| ShardStats {
        shard,
        ledger: core.ledger.clone(),
        served,
        latency_us: latency.clone(),
        retentions: core.cache.retentions,
        live_entries: core.cache.live_entries(),
        snapshot_version,
        last_time,
        queue_depth,
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Serve(r, resp) => {
                depth.fetch_sub(1, Ordering::Relaxed);
                // Deterministic fault injection (DESIGN.md §14.1): a
                // no-op single atomic load unless a test or `akpc exp
                // faults` armed a plan. Fires *before* any state
                // mutation, so a panicked/stalled shard's core equals
                // its last shadow export exactly.
                crate::fault::fire("shard-serve", Some(shard));
                let t0 = Instant::now();
                // Response assembly: the packed cliques covering D_i
                // (Algorithm 5 line 13 — deliver whole cliques).
                let before_hits = core.ledger.full_hits;
                let before_total = core.ledger.total();
                let mut delivered: Vec<u32> = Vec::with_capacity(r.items.len());
                for &d in &r.items {
                    match snapshot.members_of(d) {
                        Some(c) => delivered.extend_from_slice(c),
                        None => delivered.push(d),
                    }
                }
                delivered.sort_unstable();
                delivered.dedup();

                core.handle_request(&r);
                let full_hit = core.ledger.full_hits > before_hits;
                let cost_delta = core.ledger.total() - before_total;

                served += 1;
                if r.time > last_time {
                    last_time = r.time;
                }
                latency.record(t0.elapsed().as_micros().min(u128::from(u32::MAX)) as u32);
                let _ = resp.send(ServeResponse {
                    delivered,
                    full_hit,
                    cost_delta,
                });
            }
            ShardMsg::Install(snap, window_end, clock) => {
                core.advance_time(window_end);
                if window_end > last_time {
                    last_time = window_end;
                }
                core.set_cliques(snap.iter());
                snapshot = snap;
                let _ = clock.send(last_time);
            }
            ShardMsg::Metrics(resp) => {
                let _ = resp.send(stats(
                    &core,
                    snapshot.version,
                    served,
                    last_time,
                    &latency,
                    depth.load(Ordering::Relaxed),
                ));
            }
            ShardMsg::Quiesce(t_end) => {
                core.advance_time(t_end);
                if t_end > last_time {
                    last_time = t_end;
                }
            }
            ShardMsg::Export(resp) => {
                let _ = resp.send(core.cache.export_live());
            }
            ShardMsg::Shutdown => break,
        }
    }
    stats(
        &core,
        snapshot.version,
        served,
        last_time,
        &latency,
        depth.load(Ordering::Relaxed),
    )
}

/// The background clique-generation worker: owns the (thread-affine) CRM
/// engine and the Algorithm-1-Event-1 pipeline; publishes snapshots.
fn gen_loop(
    cfg: &AkpcConfig,
    engine: CrmEngine,
    board: Option<Arc<CopyBoard>>,
    shard_txs: Vec<mpsc::SyncSender<ShardMsg>>,
    seed: Option<GenState>,
    rx: mpsc::Receiver<GenMsg>,
) -> GenStats {
    // Thread-affine construction: a PJRT client never crosses threads —
    // which is why a handoff ships [`GenState`] (data only) and the
    // resumed worker builds a fresh engine here before importing it.
    let builder = engine.builder(&cfg.artifacts_dir);
    let engine_name = builder.engine_name().to_string();
    let mut pipeline = CliqueGenPipeline::new(cfg, builder);
    if let Some(s) = seed {
        pipeline.import_state(s);
    }

    let stats = |pipeline: &CliqueGenPipeline, engine_name: &str| GenStats {
        policy: pipeline.policy_name(),
        engine: engine_name.to_string(),
        windows: pipeline.windows,
        live_cliques: pipeline.cliques().len(),
        clique_hist: pipeline.clique_sizes(),
        clique_gen_secs: pipeline.clique_gen_secs,
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            GenMsg::Window(batch, done) => {
                let window_end = batch
                    .last()
                    .map(|r| r.time)
                    .unwrap_or(f64::NEG_INFINITY);
                pipeline.tick(&batch);
                let snap = Arc::new(CliqueSnapshot::from_cliques(
                    pipeline.windows,
                    pipeline.cliques(),
                ));
                // Broadcast; collect every shard's sweep clock so stale
                // board tombstones can be pruned behind the global
                // watermark (see CopyBoard::prune). Capacity = shard
                // count: each shard acks exactly once, so no send blocks.
                let (ctx, crx) = mpsc::sync_channel(shard_txs.len().max(1));
                let mut expected = 0usize;
                for tx in &shard_txs {
                    if tx
                        .send(ShardMsg::Install(snap.clone(), window_end, ctx.clone()))
                        .is_ok()
                    {
                        expected += 1;
                    }
                }
                drop(ctx);
                let mut min_clock = f64::INFINITY;
                let mut acked = 0usize;
                // Bounded ack wait: a lost shard never acks, so a
                // timeout just skips the board prune for this window
                // (safe — pruning is an optimization) and keeps the
                // worker alive for the supervisor's export.
                while acked < expected {
                    match crx.recv_timeout(reply_timeout()) {
                        Ok(clock) => {
                            min_clock = min_clock.min(clock);
                            acked += 1;
                        }
                        Err(_) => break,
                    }
                }
                if acked == shard_txs.len() && acked == expected {
                    if let Some(b) = &board {
                        b.prune(min_clock);
                    }
                }
                if let Some(d) = done {
                    let _ = d.send(());
                }
            }
            GenMsg::Metrics(resp) => {
                let _ = resp.send(stats(&pipeline, &engine_name));
            }
            GenMsg::Export(resp) => {
                let _ = resp.send(pipeline.export_state());
            }
            GenMsg::Shutdown => break,
        }
    }
    stats(&pipeline, &engine_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            batch_size: 10,
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn serves_and_learns_cliques() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 1).unwrap();
        // Two windows of a strong {1,2} bundle.
        for i in 0..20 {
            let resp = coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: 0,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
            assert!(!resp.delivered.is_empty());
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 20);
        assert_eq!(m.windows, 2);
        assert!(m.live_cliques >= 1, "learned no cliques");
        // After learning, a request for item 1 delivers the {1,2} pack.
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let final_m = coord.shutdown();
        assert_eq!(final_m.served, 21);
    }

    #[test]
    fn sharded_serving_learns_across_shards() {
        // Same bundle workload, but spread over 4 shards: the snapshot is
        // published to all of them, so a shard that never saw the bundle
        // still serves the whole pack.
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 4).unwrap();
        assert_eq!(coord.n_shards(), 4);
        for i in 0..20 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 2, // shards 1 and 2 stay cold
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
        }
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3, // cold shard
                time: Some(10.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 21);
        assert_eq!(m.windows, 2);
        assert_eq!(m.per_shard.len(), 4);
        let per_shard_served: u64 = m.per_shard.iter().map(|s| s.served).sum();
        assert_eq!(per_shard_served, 21);
        for s in &m.per_shard {
            assert_eq!(s.snapshot_version, 2, "shard missed an install");
        }
    }

    #[test]
    fn flush_window_forces_tick() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        for i in 0..5 {
            coord
                .serve(ServeRequest {
                    items: vec![3, 4],
                    server: 0,
                    time: Some(i as f64 * 0.01),
                })
                .unwrap();
        }
        coord.flush_window().unwrap();
        let m = coord.metrics().unwrap();
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn cost_deltas_accumulate_to_ledger() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        let mut sum = 0.0;
        for i in 0..10u32 {
            let r = coord
                .serve(ServeRequest {
                    items: vec![i % 4, 8],
                    server: i % 2,
                    time: Some(i as f64 * 0.3),
                })
                .unwrap();
            sum += r.cost_delta;
        }
        let m = coord.metrics().unwrap();
        assert!((m.ledger.total() - sum).abs() < 1e-9);
    }

    #[test]
    fn concurrent_clients() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        let mut handles = Vec::new();
        for c in 0..8u32 {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    client
                        .serve(ServeRequest {
                            items: vec![(c + i) % 16],
                            server: c % 4,
                            time: None, // wall clock
                        })
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let m = coord.metrics().unwrap();
        assert_eq!(m.served, 400);
        assert_eq!(m.ledger.requests, 400);
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 3).unwrap();
        coord
            .serve(ServeRequest {
                items: vec![1],
                server: 0,
                time: Some(0.0),
            })
            .unwrap();
        drop(coord); // must not hang or panic
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 0).unwrap();
        assert_eq!(coord.n_shards(), 1);
        coord
            .serve(ServeRequest {
                items: vec![1],
                server: 3,
                time: Some(0.0),
            })
            .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.served, 1);
    }

    /// A two-window bundle workload used by the resize tests.
    fn learn_bundle(coord: &Coordinator, n: u32) {
        for i in 0..n {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 4,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
        }
    }

    #[test]
    fn resize_grow_preserves_cliques_and_cache() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 1).unwrap();
        learn_bundle(&coord, 20);
        let (coord, retired) = coord.resize(4).unwrap();
        assert_eq!(coord.n_shards(), 4);
        assert_eq!(retired.served, 20);
        assert_eq!(retired.windows, 2);
        // The learned packing survived: a cold shard still serves the
        // whole {1,2} pack, and it hits the migrated cache copy.
        let before = coord.metrics().unwrap().ledger.full_hits;
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 1,
                time: Some(1.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let m = coord.shutdown();
        assert!(
            m.ledger.full_hits > before || resp.full_hit,
            "migrated copy should produce a hit"
        );
        assert_eq!(m.served, 1, "new epoch counts only post-resize serves");
    }

    #[test]
    fn resize_shrink_merges_copies() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 4).unwrap();
        learn_bundle(&coord, 20);
        let live_before: usize = coord
            .metrics()
            .unwrap()
            .per_shard
            .iter()
            .map(|s| s.live_entries)
            .sum();
        let (coord, _retired) = coord.resize(1).unwrap();
        assert_eq!(coord.n_shards(), 1);
        let m = coord.metrics().unwrap();
        let live_after: usize = m.per_shard.iter().map(|s| s.live_entries).sum();
        assert_eq!(live_before, live_after, "no copy lost in the merge");
        drop(coord);
    }

    #[test]
    fn resize_noop_closes_an_epoch() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        learn_bundle(&coord, 20);
        let (coord, retired) = coord.resize(2).unwrap();
        assert_eq!(retired.served, 20);
        let resp = coord
            .serve(ServeRequest {
                items: vec![1],
                server: 0,
                time: Some(1.0),
            })
            .unwrap();
        assert_eq!(resp.delivered, vec![1, 2]);
        let m = coord.shutdown();
        assert_eq!(m.served, 1);
    }

    #[test]
    fn resize_carries_pending_window() {
        // 10-request batch size; serve 25 → 5 pending at resize time.
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        learn_bundle(&coord, 25);
        let (coord, retired) = coord.resize(3).unwrap();
        assert_eq!(retired.windows, 2, "pending window must NOT tick early");
        // 5 more serves complete the carried-over window.
        for i in 0..5 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 4,
                    time: Some(2.0 + i as f64 * 0.05),
                })
                .unwrap();
        }
        let m = coord.shutdown();
        assert_eq!(m.windows, 3, "window closed after 5 post-resize serves");
        assert_eq!(m.served, 5);
    }

    #[test]
    fn decommission_on_fresh_coordinator_is_empty() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        let (retired, handoff) = coord.decommission().unwrap();
        assert_eq!(retired.served, 0);
        assert_eq!(handoff.n_copies(), 0);
        assert_eq!(handoff.n_pending(), 0);
        assert!(!handoff.clock().is_finite());
        let coord = Coordinator::resume(handoff, 3).unwrap();
        assert_eq!(coord.n_shards(), 3);
        learn_bundle(&coord, 20);
        assert_eq!(coord.shutdown().served, 20);
    }

    #[test]
    fn queue_depth_gauge_reports_in_metrics() {
        let coord = Coordinator::start(cfg(), CrmEngine::Native, 2).unwrap();
        learn_bundle(&coord, 5);
        let m = coord.metrics().unwrap();
        // Serves are synchronous here, so the settled depth is 0 — the
        // field exists and is exported per shard.
        for s in &m.per_shard {
            assert_eq!(s.queue_depth, 0);
        }
    }

    #[test]
    fn async_tick_mode_still_installs() {
        let coord =
            Coordinator::start_with(cfg(), CrmEngine::Native, 2, TickMode::Async)
                .unwrap();
        for i in 0..30 {
            coord
                .serve(ServeRequest {
                    items: vec![1, 2],
                    server: i % 4,
                    time: Some(i as f64 * 0.05),
                })
                .unwrap();
        }
        // Metrics goes through the worker's queue, so by the time it
        // answers, all three async window ticks have been processed.
        let m = coord.metrics().unwrap();
        assert_eq!(m.windows, 3);
        assert!(m.live_cliques >= 1);
    }
}
