//! Immutable clique snapshots published by the background clique-generation
//! worker to every shard (DESIGN.md §2.3).
//!
//! A snapshot is built once per window tick from the worker's
//! [`CliqueSet`] and shared via `Arc`: shards swap their pointer on
//! `Install` and keep serving lock-free; the previous snapshot is freed
//! when the last shard lets go of it.

use std::collections::HashMap;

use crate::clique::CliqueSet;

/// Frozen clique assignment for one window.
#[derive(Debug, Default)]
pub struct CliqueSnapshot {
    /// Monotone tick counter (0 = the empty pre-first-window snapshot).
    pub version: u64,
    cliques: Vec<Vec<u32>>,
    item_idx: HashMap<u32, u32>,
}

impl CliqueSnapshot {
    /// The empty snapshot every shard starts from (no packing yet).
    pub fn empty() -> Self {
        Self::default()
    }

    /// Freeze a clique set as the snapshot for tick `version`.
    pub fn from_cliques(version: u64, set: &CliqueSet) -> Self {
        let cliques: Vec<Vec<u32>> = set.iter().map(<[u32]>::to_vec).collect();
        let mut item_idx = HashMap::new();
        for (i, c) in cliques.iter().enumerate() {
            for &d in c {
                item_idx.insert(d, i as u32);
            }
        }
        Self {
            version,
            cliques,
            item_idx,
        }
    }

    /// Members of the packed clique containing `item`, if any.
    pub fn members_of(&self, item: u32) -> Option<&[u32]> {
        self.item_idx
            .get(&item)
            .map(|&i| self.cliques[i as usize].as_slice())
    }

    /// Iterate the cliques (shards feed this to
    /// [`PackedCacheCore::set_cliques`](crate::algo::PackedCacheCore::set_cliques)).
    pub fn iter(&self) -> impl Iterator<Item = &[u32]> {
        self.cliques.iter().map(Vec::as_slice)
    }

    /// Number of cliques.
    pub fn len(&self) -> usize {
        self.cliques.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cliques.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freezes_and_looks_up() {
        let mut set = CliqueSet::new();
        set.insert(vec![1, 2, 3]);
        set.insert(vec![7, 9]);
        let snap = CliqueSnapshot::from_cliques(4, &set);
        assert_eq!(snap.version, 4);
        assert_eq!(snap.len(), 2);
        assert_eq!(snap.members_of(2), Some(&[1, 2, 3][..]));
        assert_eq!(snap.members_of(9), Some(&[7, 9][..]));
        assert_eq!(snap.members_of(5), None);
        assert_eq!(snap.iter().count(), 2);
    }

    #[test]
    fn empty_snapshot() {
        let snap = CliqueSnapshot::empty();
        assert_eq!(snap.version, 0);
        assert!(snap.is_empty());
        assert_eq!(snap.members_of(0), None);
    }
}
