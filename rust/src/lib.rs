//! # Adaptive K-PackCache (AKPC)
//!
//! Production-grade reproduction of *"Adaptive K-PackCache: Cost-Centric
//! Data Caching in Cloud"* (Sarkar, Sah, Reddy, Sahu — CS.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: an online
//!   clique-packed caching layer for a CDN of edge storage servers (ESSs),
//!   with request routing, batching, per-server cache state, clique
//!   splitting / approximate merging / incremental adjustment, expiry
//!   handling and the full cost model; plus all four baselines and the
//!   event-driven CDN simulator used by the paper's evaluation.
//! * **L2/L1 (build-time Python)** — the Clique Generation Module's numeric
//!   hot-spot (request-incidence → co-occurrence → normalized, thresholded
//!   CRM) authored in JAX with a Pallas matmul kernel and AOT-lowered to
//!   HLO text; executed at runtime through `runtime::XlaRuntime`
//!   (PJRT CPU via the `xla` crate, behind the `xla` cargo feature — the
//!   offline build falls back to the native CRM engine). Python is never
//!   on the request path.
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`util`] | deterministic RNG, Zipf sampler, histograms, total float orderings |
//! | [`analysis`] | akpc-lint: the repo's own invariant checker (`akpc lint`, DESIGN.md §11) |
//! | [`config`] | full config system (paper Table II defaults) |
//! | [`trace`] | request model, synthetic Netflix/Spotify-like generators, trace IO, streaming [`TraceSource`](trace::stream::TraceSource) engine |
//! | [`crm`] | correlation-matrix construction (native path) + window diffing |
//! | [`clique`] | disjoint clique store; split / approximate-merge / adjust |
//! | [`cache`] | per-ESS cache state, expiry queue, cost model & ledger |
//! | [`algo`] | `CachePolicy` trait: AKPC + NoPacking, PackCache, DP_Greedy, OPT |
//! | [`policy`] | extended policy families: Predictive (EWMA co-access forecast), BundleOpt (Qin–Etesami baseline) (DESIGN.md §15) |
//! | [`scenario`] | Scenario Lab: declarative workload scenarios, trace transformers (materialized + streamed), phased replay |
//! | [`run`] | unified Run API: policy registry, `RunSpec` builder, `RunOutcome`, streaming observers |
//! | [`serve`] | live serving daemon: TCP ingest, admission/reorder, `/metrics`, hot-reload, graceful drain (DESIGN.md §12) |
//! | [`sim`] | event-driven CDN simulator, sharded replay drivers (materialized + streamed) + reports |
//! | [`runtime`] | PJRT artifact loading/execution, `CrmEngine` (Xla \| Native) |
//! | [`coordinator`] | online sharded service: N shard actors, window batcher, background clique-gen worker, elastic resize |
//! | [`elastic`] | shard autoscaler: placement rule, volume-tracking controller, shard-second billing, elastic replay driver (DESIGN.md §13) |
//! | [`fault`] | fault tolerance: seeded fault-injection harness, shard supervision/recovery, checkpoint/restore (DESIGN.md §14) |
//! | [`bench`] | the paper's evaluation harness (every table & figure, shard scaling, memory baseline) |
//!
//! ## Bounded-memory replays (DESIGN.md §10)
//!
//! Million-user workloads replay through a streaming
//! [`TraceSource`](trace::stream::TraceSource) — chunked binary files,
//! line-streamed CSV, or on-the-fly generation — so peak memory is one
//! chunk plus one clique-generation window, independent of trace length:
//!
//! ```
//! use akpc::config::AkpcConfig;
//! use akpc::algo::Akpc;
//! use akpc::run::{drive_trace, generated_source, NullObserver};
//! use akpc::trace::generator::TraceKind;
//!
//! let cfg = AkpcConfig { n_items: 30, n_servers: 12, ..Default::default() };
//! // 10_000 requests sampled chunk by chunk — never materialized.
//! let mut source = generated_source(TraceKind::Netflix, &cfg, 10_000, 2_048).unwrap();
//! let report = drive_trace(
//!     &mut Akpc::new(&cfg),
//!     &mut source,
//!     cfg.batch_size,
//!     &mut NullObserver,
//! )
//! .unwrap();
//! assert_eq!(report.ledger.requests, 10_000);
//! ```

pub mod algo;
pub mod analysis;
pub mod bench;
pub mod cache;
pub mod clique;
pub mod config;
pub mod coordinator;
pub mod crm;
pub mod elastic;
pub mod fault;
pub mod policy;
pub mod run;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

pub use config::AkpcConfig;
pub use run::{PolicyRegistry, RunOutcome, RunSpec};
pub use trace::model::{Request, Trace};
