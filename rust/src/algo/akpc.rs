//! **Adaptive K-PackCache (AKPC)** — the paper's proposed policy
//! (Algorithm 1), composed from the substrates:
//!
//! * *Event 1* (every window / `T^CG`): rebuild the CRM
//!   ([`CrmBuilder`] — the AOT XLA artifact in production, native in
//!   fallback), diff against the previous window, and regenerate the
//!   disjoint clique set via adjust → form → split → approximate-merge
//!   (Algorithms 2-4);
//! * *Event 2* (request arrival): Algorithm 5 via [`PackedCacheCore`];
//! * *Event 3* (copy expiry): Algorithm 6 inside the core's cache state.
//!
//! The `clique_splitting` / `approx_merging` flags produce the paper's
//! ablation variants (*AKPC w/o CS, w/o ACM* and *AKPC w/o ACM*).

use super::{CachePolicy, PackedCacheCore};
use crate::cache::{CostLedger, CostModel};
use crate::clique::CliqueSet;
use crate::config::AkpcConfig;
use crate::crm::{diff_windows, CrmBuilder, CrmWindow, NativeCrmBuilder};
use crate::trace::model::Request;
use crate::util::Histogram;

/// The Event-1 machinery of Algorithm 1 — CRM windowing, diffing and
/// clique regeneration — factored out of [`Akpc`] so the sharded
/// coordinator's background clique-generation worker (DESIGN.md §2.3) runs
/// the *identical* pipeline over the *identical* state and the per-shard
/// ledgers stay bit-equivalent to a single-leader run.
pub struct CliqueGenPipeline {
    cfg: AkpcConfig,
    builder: Box<dyn CrmBuilder>,
    prev_crm: CrmWindow,
    cliques: CliqueSet,
    hist: Histogram,
    /// Sliding CRM window: the last `crm_window_batches` batches, stored
    /// *pre-sessionized* (perf: sessionizing each batch once on arrival
    /// instead of re-sessionizing the whole multi-batch window every tick
    /// cut the tick cost ~2× — EXPERIMENTS.md §Perf. Sessions spanning a
    /// batch boundary are split; with ~3-request sessions and 200-request
    /// batches this affects <2% of sessions).
    recent: std::collections::VecDeque<Vec<Request>>,
    /// Cumulative time spent in clique generation (Fig. 9b).
    pub clique_gen_secs: f64,
    /// Window ticks executed.
    pub windows: u64,
}

/// Portable snapshot of a [`CliqueGenPipeline`]'s learned state — the
/// clique-generation half of an elastic handoff (DESIGN.md §13). The
/// CRM builder itself is *not* captured (it may hold thread-affine XLA
/// executables); the receiving coordinator constructs a fresh builder
/// for the same engine and `import_state` restores everything the
/// builder feeds on: the previous CRM window (diff base), the live
/// clique set, the sliding pre-sessionized batch window, the histogram,
/// and the tick counters, plus the one mutable config knob (ω).
#[derive(Debug, Clone)]
pub struct GenState {
    /// Current maximum clique size ω (runtime-adjustable via
    /// [`CliqueGenPipeline::set_omega`]).
    pub omega: u32,
    /// Diff base: the CRM of the last completed window.
    pub prev_crm: CrmWindow,
    /// The live clique set being served.
    pub cliques: CliqueSet,
    /// Cumulative clique-size histogram (Fig. 9a).
    pub hist: Histogram,
    /// Sliding window of pre-sessionized batches (`crm_window_batches`).
    pub recent: std::collections::VecDeque<Vec<Request>>,
    /// Cumulative clique-generation wall time (Fig. 9b).
    pub clique_gen_secs: f64,
    /// Window ticks executed.
    pub windows: u64,
}

impl CliqueGenPipeline {
    pub fn new(cfg: &AkpcConfig, builder: Box<dyn CrmBuilder>) -> Self {
        Self {
            cfg: cfg.clone(),
            builder,
            prev_crm: CrmWindow::default(),
            cliques: CliqueSet::new(),
            hist: Histogram::new(),
            recent: std::collections::VecDeque::new(),
            clique_gen_secs: 0.0,
            windows: 0,
        }
    }

    /// Current clique set.
    pub fn cliques(&self) -> &CliqueSet {
        &self.cliques
    }

    /// CRM engine in use.
    pub fn engine_name(&self) -> &'static str {
        self.builder.engine_name()
    }

    /// Display name of the policy this pipeline generates for.
    pub fn policy_name(&self) -> String {
        format!("AKPC{}", self.variant_suffix())
    }

    /// Adjust the maximum clique size ω; takes effect at the next tick.
    pub fn set_omega(&mut self, omega: u32) {
        self.cfg.omega = omega.max(1);
    }

    /// Cumulative clique-size distribution over ticks (Fig. 9a).
    pub fn clique_sizes(&self) -> Histogram {
        self.hist.clone()
    }

    /// Export the learned state for an elastic handoff. The pipeline
    /// keeps running; the export is a consistent copy as of now.
    pub fn export_state(&self) -> GenState {
        GenState {
            omega: self.cfg.omega,
            prev_crm: self.prev_crm.clone(),
            cliques: self.cliques.clone(),
            hist: self.hist.clone(),
            recent: self.recent.clone(),
            clique_gen_secs: self.clique_gen_secs,
            windows: self.windows,
        }
    }

    /// Restore an exported state into this (freshly constructed)
    /// pipeline. The next `tick` then diffs against the donor's last
    /// CRM window over the donor's sliding batch window — i.e. it
    /// produces the exact clique set a never-resized pipeline would
    /// have produced.
    pub fn import_state(&mut self, s: GenState) {
        self.cfg.omega = s.omega;
        self.prev_crm = s.prev_crm;
        self.cliques = s.cliques;
        self.hist = s.hist;
        self.recent = s.recent;
        self.clique_gen_secs = s.clique_gen_secs;
        self.windows = s.windows;
    }

    fn variant_suffix(&self) -> &'static str {
        match (self.cfg.clique_splitting, self.cfg.approx_merging) {
            (true, true) => "",
            (true, false) => " w/o ACM",
            (false, true) => " w/o CS",
            (false, false) => " w/o CS, w/o ACM",
        }
    }

    /// One window tick (Algorithm 1 Event 1): slide the correlation
    /// window, rebuild the CRM, diff, regenerate cliques. Returns the new
    /// clique set for installation into the serving state(s).
    pub fn tick(&mut self, batch: &[Request]) -> &CliqueSet {
        let t0 = std::time::Instant::now();

        // Slide the correlation window (last `crm_window_batches` T^CG
        // periods); co-utilization spans consecutive same-server requests
        // within the session gap (crm::sessionize, applied per batch on
        // arrival); then run Algorithm 2 (XLA artifact or native engine).
        let gap = self.cfg.session_gap_frac * self.cfg.delta_t();
        self.recent.push_back(crate::crm::sessionize(batch, gap));
        while self.recent.len() > self.cfg.crm_window_batches.max(1) {
            self.recent.pop_front();
        }
        let transactions: Vec<Request> =
            self.recent.iter().flatten().cloned().collect();
        let crm = self.builder.build(
            &transactions,
            self.cfg.n_items,
            self.cfg.theta,
            self.cfg.crm_top_frac,
        );
        // Algorithm 4 input — edge diff vs the previous window.
        let delta = diff_windows(&self.prev_crm, &crm);
        // Algorithm 3 — adjust, form, split, merge.
        self.cliques = CliqueSet::generate(
            &self.cliques,
            &crm,
            &delta,
            self.cfg.omega,
            self.cfg.gamma_approx,
            self.cfg.clique_splitting,
            self.cfg.approx_merging,
        );
        self.prev_crm = crm;

        for c in self.cliques.iter() {
            self.hist.record(c.len() as u32);
        }
        self.clique_gen_secs += t0.elapsed().as_secs_f64();
        self.windows += 1;
        &self.cliques
    }
}

pub struct Akpc {
    core: PackedCacheCore,
    gen: CliqueGenPipeline,
    /// Cumulative time spent in clique generation (Fig. 9b); mirrors the
    /// pipeline after every tick.
    pub clique_gen_secs: f64,
    /// Window ticks executed; mirrors the pipeline after every tick.
    pub windows: u64,
}

impl Akpc {
    /// AKPC with the native CRM engine.
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self::with_builder(cfg, Box::new(NativeCrmBuilder))
    }

    /// AKPC with an explicit CRM engine (the runtime injects the XLA one).
    pub fn with_builder(cfg: &AkpcConfig, builder: Box<dyn CrmBuilder>) -> Self {
        Self {
            core: PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy),
            gen: CliqueGenPipeline::new(cfg, builder),
            clique_gen_secs: 0.0,
            windows: 0,
        }
    }

    /// Current clique set (inspection / tests).
    pub fn cliques(&self) -> &CliqueSet {
        self.gen.cliques()
    }

    /// CRM engine in use.
    pub fn engine_name(&self) -> &'static str {
        self.gen.engine_name()
    }

    /// Adjust the maximum clique size ω in place (used by the AdaptiveK
    /// controller — future-work item (i)). Takes effect at the next
    /// window tick; cache state and ledger carry across.
    pub fn set_omega(&mut self, omega: u32) {
        self.gen.set_omega(omega);
    }
}

impl CachePolicy for Akpc {
    fn name(&self) -> String {
        self.gen.policy_name()
    }

    fn handle_request(&mut self, r: &Request) {
        self.core.handle_request(r);
    }

    fn end_batch(&mut self, batch: &[Request]) {
        let cliques = self.gen.tick(batch);
        // Install for subsequent requests (Algorithm 1 line 5).
        self.core.set_cliques(cliques.iter());
        self.clique_gen_secs = self.gen.clique_gen_secs;
        self.windows = self.gen.windows;
    }

    fn ledger(&self) -> &CostLedger {
        &self.core.ledger
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        Some(self.gen.clique_sizes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(items: &[u32], server: u32, t: f64) -> Request {
        Request::new(items.to_vec(), server, t)
    }

    /// A window that makes {0,1,2} a strong bundle.
    fn bundle_window(t0: f64) -> Vec<Request> {
        let mut w = Vec::new();
        for i in 0..20 {
            w.push(req(&[0, 1, 2], 0, t0 + i as f64 * 0.01));
            w.push(req(&[5, 6], 1, t0 + i as f64 * 0.01));
        }
        w
    }

    fn test_cfg() -> AkpcConfig {
        AkpcConfig {
            n_items: 16,
            n_servers: 4,
            crm_top_frac: 1.0,
            // Unit tests reason about single windows.
            crm_window_batches: 1,
            ..Default::default()
        }
    }

    #[test]
    fn learns_cliques_from_window() {
        let cfg = test_cfg();
        let mut p = Akpc::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        assert_eq!(p.cliques().clique_of(0).unwrap(), &[0, 1, 2]);
        assert_eq!(p.cliques().clique_of(5).unwrap(), &[5, 6]);
    }

    #[test]
    fn serves_whole_clique_on_single_item_request() {
        let cfg = test_cfg();
        let mut p = Akpc::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        p.handle_request(&req(&[0], 2, 10.0));
        // Observation 4: delivered 3 items for 1 requested.
        assert_eq!(p.ledger().items_delivered, 3);
        assert_eq!(p.ledger().items_requested, 1);
        // Packed transfer (1+2α)λ = 2.6.
        assert!((p.ledger().c_t - 2.6).abs() < 1e-12);
        // Follow-up for a co-bundled item within Δt is a pure hit.
        let t_before = p.ledger().c_t;
        p.handle_request(&req(&[1], 2, 10.5));
        assert_eq!(p.ledger().c_t, t_before);
        assert_eq!(p.ledger().full_hits, 1);
    }

    #[test]
    fn variant_names() {
        let cfg = test_cfg();
        assert_eq!(Akpc::new(&cfg).name(), "AKPC");
        assert_eq!(
            Akpc::new(&cfg.without_cs_acm()).name(),
            "AKPC w/o CS, w/o ACM"
        );
        assert_eq!(Akpc::new(&cfg.without_acm()).name(), "AKPC w/o ACM");
    }

    #[test]
    fn incremental_update_across_windows() {
        let cfg = test_cfg();
        let mut p = Akpc::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        let first = p.cliques().clique_of(0).unwrap().to_vec();
        // Second window: bundle splits — 0 now pairs with 9 only. The two
        // streams run on different servers so sessionization does not
        // merge them into one transaction.
        let mut w2 = Vec::new();
        for i in 0..20 {
            w2.push(req(&[0, 9], 0, 100.0 + i as f64 * 0.01));
            w2.push(req(&[1, 2], 1, 100.0 + i as f64 * 0.01));
        }
        p.end_batch(&w2);
        let second = p.cliques().clique_of(0).unwrap().to_vec();
        assert_ne!(first, second);
        assert_eq!(second, vec![0, 9]);
        p.cliques().check_invariants().unwrap();
        assert_eq!(p.windows, 2);
    }

    #[test]
    fn omega_bounds_clique_size_with_cs() {
        let cfg = AkpcConfig {
            omega: 3,
            ..test_cfg()
        };
        let mut p = Akpc::new(&cfg);
        // One big 6-bundle.
        let mut w = Vec::new();
        for i in 0..30 {
            w.push(req(&[0, 1, 2, 3, 4], 0, i as f64 * 0.01));
            w.push(req(&[3, 4, 5], 0, i as f64 * 0.01));
        }
        p.end_batch(&w);
        for c in p.cliques().iter() {
            assert!(c.len() <= 3, "clique {c:?} exceeds ω");
        }
    }

    #[test]
    fn pipeline_export_import_resumes_identically() {
        use crate::crm::NativeCrmBuilder;
        let cfg = test_cfg();
        // Donor runs two windows, exports, and keeps going; the clone
        // imports into a fresh pipeline with a fresh builder. Both tick
        // the same third window — clique sets must be identical.
        let mut donor = CliqueGenPipeline::new(&cfg, Box::new(NativeCrmBuilder));
        donor.tick(&bundle_window(0.0));
        let mut w2 = Vec::new();
        for i in 0..20 {
            w2.push(req(&[0, 9], 0, 100.0 + i as f64 * 0.01));
            w2.push(req(&[1, 2], 1, 100.0 + i as f64 * 0.01));
        }
        donor.tick(&w2);
        let state = donor.export_state();
        assert_eq!(state.windows, 2);

        let mut clone = CliqueGenPipeline::new(&cfg, Box::new(NativeCrmBuilder));
        clone.import_state(state);
        let w3 = bundle_window(200.0);
        donor.tick(&w3);
        clone.tick(&w3);
        assert_eq!(donor.windows, clone.windows);
        let d: Vec<_> = donor.cliques().iter().collect();
        let c: Vec<_> = clone.cliques().iter().collect();
        assert_eq!(d, c, "resumed pipeline must regenerate identically");
    }

    #[test]
    fn export_import_carries_omega() {
        use crate::crm::NativeCrmBuilder;
        let cfg = test_cfg();
        let mut donor = CliqueGenPipeline::new(&cfg, Box::new(NativeCrmBuilder));
        donor.set_omega(3);
        let mut clone = CliqueGenPipeline::new(&cfg, Box::new(NativeCrmBuilder));
        clone.import_state(donor.export_state());
        let mut w = Vec::new();
        for i in 0..30 {
            w.push(req(&[0, 1, 2, 3, 4], 0, i as f64 * 0.01));
            w.push(req(&[3, 4, 5], 0, i as f64 * 0.01));
        }
        clone.tick(&w);
        for cl in clone.cliques().iter() {
            assert!(cl.len() <= 3, "imported ω must bound clique {cl:?}");
        }
    }

    #[test]
    fn histogram_records_sizes() {
        let cfg = test_cfg();
        let mut p = Akpc::new(&cfg);
        p.end_batch(&bundle_window(0.0));
        let h = p.clique_sizes().expect("AKPC tracks clique sizes");
        assert!(h.count() >= 2);
        assert!(h.max() >= 2);
    }
}
