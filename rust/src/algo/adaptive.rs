//! **AdaptiveK** — the paper's future-work item (i) implemented: online
//! tuning of the maximum clique size K (= ω) based on workload dynamics.
//!
//! The trade-off ω controls (paper §V-D-3): small ω forfeits packing
//! opportunities, large ω inflates transfer cost through unused pack
//! members. Neither the right value nor its drift over time (e.g. Spotify
//! chart churn shrinking useful bundles) is known a priori.
//!
//! Strategy: epoch-based hill climbing on the *observed cost rate*.
//! An epoch is `EPOCH_WINDOWS` clique-generation windows. At each epoch
//! boundary the controller compares the mean cost-per-request of the two
//! most recent epochs at the current ω against the stored score of the
//! neighbouring ω values, and moves ω by ±1 within `[2, omega_max]`
//! towards the cheaper neighbour (ε-greedy: occasionally probes anyway,
//! so the controller keeps adapting after churn).
//!
//! The controller wraps [`Akpc`] and rebuilds its clique pipeline
//! parameters in place — cache state and ledger carry across, so the
//! reported totals are a true single-run cost.

use super::{Akpc, CachePolicy};
use crate::cache::CostLedger;
use crate::config::AkpcConfig;
use crate::crm::CrmBuilder;
use crate::trace::model::Request;
use crate::util::{Histogram, Rng};

/// Windows per adaptation epoch.
const EPOCH_WINDOWS: u64 = 10;
/// Probability of probing a random direction instead of exploiting.
const EPSILON: f64 = 0.15;

pub struct AdaptiveK {
    inner: Akpc,
    cfg: AkpcConfig,
    /// Upper bound for the search (the configured ω).
    omega_max: u32,
    /// Cost/requests at the last epoch boundary.
    mark_cost: f64,
    mark_requests: u64,
    windows_in_epoch: u64,
    /// Last measured cost-per-request per ω (index = ω).
    scores: Vec<Option<f64>>,
    rng: Rng,
    /// Trajectory of (epoch, ω) decisions — inspection/tests.
    pub trajectory: Vec<u32>,
}

impl AdaptiveK {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self::with_builder(cfg, Box::new(crate::crm::NativeCrmBuilder))
    }

    pub fn with_builder(cfg: &AkpcConfig, builder: Box<dyn CrmBuilder>) -> Self {
        let omega_max = cfg.omega.max(2);
        Self {
            inner: Akpc::with_builder(cfg, builder),
            cfg: cfg.clone(),
            omega_max,
            mark_cost: 0.0,
            mark_requests: 0,
            windows_in_epoch: 0,
            scores: vec![None; omega_max as usize + 2],
            rng: Rng::new(cfg.seed ^ 0xADA9_71CE),
            trajectory: vec![cfg.omega],
        }
    }

    /// Current ω.
    pub fn omega(&self) -> u32 {
        self.cfg.omega
    }

    fn epoch_boundary(&mut self) {
        let l = self.inner.ledger();
        let d_req = l.requests - self.mark_requests;
        if d_req < 50 {
            return; // not enough evidence this epoch
        }
        let rate = (l.total() - self.mark_cost) / d_req as f64;
        self.mark_cost = l.total();
        self.mark_requests = l.requests;

        let omega = self.cfg.omega;
        self.scores[omega as usize] = Some(rate);

        // Candidate moves.
        let down = omega.saturating_sub(1).max(2);
        let up = (omega + 1).min(self.omega_max);
        let score_of = |w: u32, scores: &Vec<Option<f64>>| scores[w as usize];

        let next = if self.rng.chance(EPSILON) {
            // Explore: random neighbour.
            if self.rng.chance(0.5) {
                down
            } else {
                up
            }
        } else {
            // Exploit: pick the best known among {down, ω, up}; unknown
            // neighbours are optimistically probed first.
            let mut best = omega;
            let mut best_rate = rate;
            for w in [down, up] {
                match score_of(w, &self.scores) {
                    None => {
                        best = w; // optimism under uncertainty
                        break;
                    }
                    Some(r) if r < best_rate => {
                        best = w;
                        best_rate = r;
                    }
                    _ => {}
                }
            }
            best
        };

        if next != omega {
            self.cfg.omega = next;
            self.inner.set_omega(next);
        }
        self.trajectory.push(self.cfg.omega);
    }
}

impl CachePolicy for AdaptiveK {
    fn name(&self) -> String {
        "AKPC AdaptiveK".into()
    }

    fn handle_request(&mut self, r: &Request) {
        self.inner.handle_request(r);
    }

    fn end_batch(&mut self, batch: &[Request]) {
        self.inner.end_batch(batch);
        self.windows_in_epoch += 1;
        if self.windows_in_epoch >= EPOCH_WINDOWS {
            self.windows_in_epoch = 0;
            self.epoch_boundary();
        }
    }

    fn ledger(&self) -> &CostLedger {
        self.inner.ledger()
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        self.inner.clique_sizes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim;
    use crate::trace::generator::{netflix_like, spotify_like};

    fn cfg() -> AkpcConfig {
        AkpcConfig {
            n_servers: 200,
            ..Default::default()
        }
    }

    #[test]
    fn adapts_and_stays_in_bounds() {
        let cfg = cfg();
        let trace = netflix_like(cfg.n_items, cfg.n_servers, 40_000, 5);
        let mut p = AdaptiveK::new(&cfg);
        let rep = sim::run(&mut p, &trace, cfg.batch_size);
        assert_eq!(rep.ledger.requests, 40_000);
        assert!(p.trajectory.len() > 3, "controller never adapted");
        for &w in &p.trajectory {
            assert!((2..=cfg.omega).contains(&w), "omega {w} out of bounds");
        }
    }

    #[test]
    fn competitive_with_static_omega() {
        // AdaptiveK must end within 15% of the static Table-II ω on a
        // stationary workload (it spends some budget exploring).
        let cfg = cfg();
        let trace = netflix_like(cfg.n_items, cfg.n_servers, 40_000, 6);
        let mut fixed = Akpc::new(&cfg);
        let r_fixed = sim::run(&mut fixed, &trace, cfg.batch_size);
        let mut adaptive = AdaptiveK::new(&cfg);
        let r_adaptive = sim::run(&mut adaptive, &trace, cfg.batch_size);
        assert!(
            r_adaptive.total() <= r_fixed.total() * 1.15,
            "adaptive {} vs fixed {}",
            r_adaptive.total(),
            r_fixed.total()
        );
    }

    #[test]
    fn survives_churny_workload() {
        let cfg = cfg();
        let trace = spotify_like(cfg.n_items, cfg.n_servers, 40_000, 7);
        let mut p = AdaptiveK::new(&cfg);
        let rep = sim::run(&mut p, &trace, cfg.batch_size);
        assert!(rep.ledger.hit_rate() > 0.3);
    }

    #[test]
    fn omega_getter_tracks_moves() {
        let cfg = cfg();
        let p = AdaptiveK::new(&cfg);
        assert_eq!(p.omega(), cfg.omega);
    }
}
