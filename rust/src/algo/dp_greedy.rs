//! *DP_Greedy* baseline — Huang et al. [4]: offline two-phase 2-packing.
//!
//! The original combines dynamic programming with a greedy pairing over the
//! *complete, known* request trace. The decision structure we reproduce:
//! from full-trace co-occurrence counts, select the maximum-weight disjoint
//! pairing greedily (the greedy phase; their DP phase orders intra-pair
//! caching intervals, which the shared Δt-renewal machinery already fixes
//! under this paper's cost model). The pairing is installed once and never
//! changes — its offline advantage is knowing the whole trace's co-access
//! structure; its limitation (the paper's point) is pairwise-only packing.

use std::collections::HashMap;

use super::{CachePolicy, PackedCacheCore};
use crate::cache::{CostLedger, CostModel};
use crate::config::AkpcConfig;
use crate::trace::model::{Request, Trace};
use crate::util::Histogram;

#[derive(Debug)]
pub struct DpGreedy {
    core: PackedCacheCore,
    hist: Histogram,
    prepared: bool,
}

impl DpGreedy {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self {
            core: PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy),
            hist: Histogram::new(),
            prepared: false,
        }
    }

    /// Offline pairing over the full trace (sessionized with the same
    /// 0.05·Δt co-utilization gap the online miners use, at Δt = 1).
    pub fn pair_offline(trace: &Trace) -> Vec<[u32; 2]> {
        let sessions = crate::crm::sessionize(&trace.requests, 0.05);
        let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
        for r in &sessions {
            for i in 0..r.items.len() {
                for j in (i + 1)..r.items.len() {
                    *counts.entry((r.items[i], r.items[j])).or_default() += 1;
                }
            }
        }
        let mut pairs: Vec<((u32, u32), u64)> = counts.into_iter().collect();
        pairs.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut used = std::collections::HashSet::new();
        let mut matching = Vec::new();
        for ((a, b), c) in pairs {
            if c < 2 {
                break; // co-occurred once: no evidence of co-utilization
            }
            if !used.contains(&a) && !used.contains(&b) {
                used.insert(a);
                used.insert(b);
                matching.push([a, b]);
            }
        }
        matching
    }
}

impl CachePolicy for DpGreedy {
    fn name(&self) -> String {
        "DP_Greedy".into()
    }

    fn needs_offline_trace(&self) -> bool {
        true
    }

    fn prepare(&mut self, trace: &Trace) {
        let pairs = Self::pair_offline(trace);
        for _ in &pairs {
            self.hist.record(2);
        }
        self.core.set_cliques(pairs.iter().map(|p| p.as_slice()));
        self.prepared = true;
    }

    fn handle_request(&mut self, r: &Request) {
        debug_assert!(self.prepared, "DP_Greedy requires prepare(trace)");
        self.core.handle_request(r);
    }

    fn ledger(&self) -> &CostLedger {
        &self.core.ledger
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        Some(self.hist.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_of(reqs: Vec<Request>) -> Trace {
        Trace {
            n_items: 64,
            n_servers: 4,
            name: "t".into(),
            requests: reqs,
        }
    }

    #[test]
    fn offline_pairing_uses_whole_trace() {
        // Spaced > Δt so sessionization keeps transactions separate.
        let mut reqs = vec![];
        for i in 0..10 {
            reqs.push(Request::new(vec![1, 2], 0, i as f64 * 5.0));
        }
        for i in 0..8 {
            reqs.push(Request::new(vec![5, 6], 1, i as f64 * 5.0 + 1.0));
        }
        reqs.sort_by(|a, b| a.time.total_cmp(&b.time));
        let t = trace_of(reqs);
        let pairs = DpGreedy::pair_offline(&t);
        assert!(pairs.contains(&[1, 2]));
        assert!(pairs.contains(&[5, 6]));
    }

    #[test]
    fn pairing_is_fixed_through_run() {
        // Distinct servers so the Alg.-6 last-copy retention (which keeps
        // one copy alive at the *expiring* server) cannot turn later
        // accesses into hits.
        let mut reqs = vec![];
        for i in 0..4u32 {
            reqs.push(Request::new(vec![1, 2], i, i as f64 * 10.0));
        }
        // Pairing evidence at one more server.
        reqs.insert(0, Request::new(vec![1, 2], 0, 0.0));
        let t = trace_of(reqs.clone());
        let mut p = DpGreedy::new(&AkpcConfig::default());
        p.prepare(&t);
        for r in &reqs {
            p.handle_request(r);
        }
        // First two land on server 0 together (hit), then three fresh
        // servers -> 4 transfers of the {1,2} pack at (1+α)λ = 1.8.
        assert_eq!(p.ledger().transfers, 4);
        assert!((p.ledger().c_t - 4.0 * 1.8).abs() < 1e-12);
    }

    #[test]
    fn below_support_pairs_not_packed() {
        let t = trace_of(vec![Request::new(vec![1, 2], 0, 0.0)]);
        assert!(DpGreedy::pair_offline(&t).is_empty());
    }
}
