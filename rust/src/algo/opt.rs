//! *OPT* — the offline reference with complete future knowledge the paper
//! normalizes every figure against.
//!
//! The paper does not specify OPT's construction beyond "the optimal
//! strategy that achieves the minimum possible cost using complete future
//! knowledge", and its Theorem 1 argument grants OPT two abilities:
//!
//! * **anticipatory exact packing** — when a transfer to a server is
//!   needed, OPT may pack *any* items into it, in particular items it
//!   knows will be requested at that server shortly (this is the ability
//!   AKPC approximates with cliques — Observation 4);
//! * **clairvoyant caching** — an item is held only when holding is
//!   cheaper than refetching (Observation 2).
//!
//! We implement both greedily with full lookahead (DESIGN.md §2):
//!
//! 1. When a request at server `s`, time `t` misses items, OPT opens one
//!    packed transfer containing the missed set **plus** every item whose
//!    next access at `s` falls within `(t, t + Δt]` and is not already
//!    cached — prefetching it costs a marginal `α·λ` plus holding
//!    `μ·(t_next − t)`, which is compared against the `λ` a dedicated
//!    later transfer would cost.
//! 2. After serving/prefetching, each item is held to its next access iff
//!    `μ·gap ≤ α·λ` (cheapest conceivable refetch), else dropped.
//!
//! This is a strong clairvoyant baseline, not a provable optimum; the
//! paper's own OPT is equally unspecified, and every figure normalizes to
//! it the same way.

use std::collections::HashMap;

use super::CachePolicy;
use crate::cache::{CostLedger, CostModel};
use crate::config::AkpcConfig;
use crate::trace::model::{Request, Trace};

#[derive(Debug)]
pub struct Opt {
    cost: CostModel,
    ledger: CostLedger,
    /// Future access times per (item, server), ascending.
    accesses: HashMap<(u32, u32), Vec<f64>>,
    cursor: HashMap<(u32, u32), usize>,
    /// Items of each server's stream in first-future-access order is
    /// recovered through `accesses`; `per_server` lists items ever touched
    /// at a server (for prefetch scanning).
    per_server: HashMap<u32, Vec<u32>>,
    /// (item, server) held in cache until the stored time.
    cached_until: HashMap<(u32, u32), f64>,
    prepared: bool,
}

impl Opt {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self {
            cost: CostModel::from_config(cfg),
            ledger: CostLedger::default(),
            accesses: HashMap::new(),
            cursor: HashMap::new(),
            per_server: HashMap::new(),
            cached_until: HashMap::new(),
            prepared: false,
        }
    }

    /// Next access of `item` at `server` strictly after `now`.
    fn next_access(&mut self, item: u32, server: u32, now: f64) -> Option<f64> {
        let times = self.accesses.get(&(item, server))?;
        let cur = self.cursor.entry((item, server)).or_insert(0);
        while *cur < times.len() && times[*cur] <= now {
            *cur += 1;
        }
        times.get(*cur).copied()
    }

    /// Hold-vs-drop (ski rental with future knowledge) for an item that is
    /// present at `server` at `now`.
    fn decide_hold(&mut self, item: u32, server: u32, now: f64) {
        if let Some(t_next) = self.next_access(item, server, now) {
            let gap = t_next - now;
            if self.cost.mu * gap <= self.cost.alpha * self.cost.lambda {
                self.ledger.c_p += self.cost.mu * gap;
                self.cached_until.insert((item, server), t_next);
                return;
            }
        }
        self.cached_until.remove(&(item, server));
    }
}

impl CachePolicy for Opt {
    fn name(&self) -> String {
        "OPT".into()
    }

    fn needs_offline_trace(&self) -> bool {
        true
    }

    fn prepare(&mut self, trace: &Trace) {
        self.accesses.clear();
        self.per_server.clear();
        for r in &trace.requests {
            for &d in &r.items {
                let e = self.accesses.entry((d, r.server)).or_default();
                if e.is_empty() {
                    self.per_server.entry(r.server).or_default().push(d);
                }
                e.push(r.time);
            }
        }
        self.prepared = true;
    }

    fn handle_request(&mut self, r: &Request) {
        debug_assert!(self.prepared, "OPT requires prepare(trace)");
        let now = r.time;
        let server = r.server;

        let mut pack: Vec<u32> = Vec::new();
        for &d in &r.items {
            let hit = self
                .cached_until
                .get(&(d, server))
                .is_some_and(|&u| u >= now);
            if !hit && !pack.contains(&d) {
                pack.push(d);
            }
        }

        if !pack.is_empty() {
            // Anticipatory packing: add upcoming items at this server whose
            // prefetch (marginal αλ + holding) beats a later dedicated
            // transfer (λ). Scan this server's item universe — small by
            // construction (items ever requested at s).
            let candidates: Vec<u32> = self
                .per_server
                .get(&server)
                .map(|v| v.clone())
                .unwrap_or_default();
            for d in candidates {
                if pack.contains(&d) {
                    continue;
                }
                if self
                    .cached_until
                    .get(&(d, server))
                    .is_some_and(|&u| u >= now)
                {
                    continue; // already held
                }
                if let Some(t_next) = self.next_access(d, server, now) {
                    let gap = t_next - now;
                    let prefetch = self.cost.alpha * self.cost.lambda
                        + self.cost.mu * gap;
                    if gap <= self.cost.delta_t && prefetch <= self.cost.lambda {
                        pack.push(d);
                        // Charge holding up to the prefetched access; the
                        // marginal transfer α·λ is charged via pack size.
                        self.ledger.c_p += self.cost.mu * gap;
                        self.cached_until.insert((d, server), t_next);
                    }
                }
            }

            self.ledger.c_t += self.cost.transfer_packed(pack.len() as u32);
            self.ledger.transfers += 1;
            self.ledger.misses += 1;
            self.ledger.items_delivered += pack.len() as u64;
        } else {
            self.ledger.full_hits += 1;
        }
        self.ledger.requests += 1;
        self.ledger.items_requested += r.items.len() as u64;
        self.ledger.items_delivered += (r.items.len() as u64)
            .saturating_sub(pack.iter().filter(|d| r.items.contains(d)).count() as u64);

        // Hold-vs-drop for the items just served (requested ones).
        for &d in &r.items {
            self.decide_hold(d, server, now);
        }
    }

    fn ledger(&self) -> &CostLedger {
        &self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_of(reqs: Vec<Request>) -> Trace {
        Trace {
            n_items: 64,
            n_servers: 4,
            name: "t".into(),
            requests: reqs,
        }
    }

    fn run(reqs: Vec<Request>, alpha: f64) -> CostLedger {
        let cfg = AkpcConfig {
            alpha,
            ..Default::default()
        };
        let t = trace_of(reqs.clone());
        let mut o = Opt::new(&cfg);
        o.prepare(&t);
        for r in &reqs {
            o.handle_request(r);
        }
        o.ledger().clone()
    }

    #[test]
    fn theorem1_case11_opt_pays_only_transfer() {
        // Single item, never re-accessed: OPT cost = λ.
        let l = run(vec![Request::new(vec![1], 0, 0.0)], 0.8);
        assert!((l.c_t - 1.0).abs() < 1e-12);
        assert_eq!(l.c_p, 0.0);
    }

    #[test]
    fn packs_missed_set_exactly() {
        // Theorem 1 Case 2.1: S=3 missed -> (1 + 2α)λ in ONE transfer.
        let l = run(vec![Request::new(vec![1, 2, 3], 0, 0.0)], 0.8);
        assert_eq!(l.transfers, 1);
        assert!((l.c_t - 2.6).abs() < 1e-12);
    }

    #[test]
    fn holds_across_short_gap() {
        // Gap 0.5: μ·0.5 = 0.5 ≤ αλ = 0.8 -> hold, pay 0.5 caching,
        // second access is a hit.
        let l = run(
            vec![
                Request::new(vec![1], 0, 0.0),
                Request::new(vec![1], 0, 0.5),
            ],
            0.8,
        );
        assert_eq!(l.transfers, 1);
        assert!((l.c_p - 0.5).abs() < 1e-12);
        assert_eq!(l.full_hits, 1);
    }

    #[test]
    fn refetches_across_long_gap() {
        // Gap 5: μ·5 > αλ -> drop and refetch.
        let l = run(
            vec![
                Request::new(vec![1], 0, 0.0),
                Request::new(vec![1], 0, 5.0),
            ],
            0.8,
        );
        assert_eq!(l.transfers, 2);
        assert_eq!(l.c_p, 0.0);
        assert!((l.c_t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn anticipatory_prefetch_of_sequential_session() {
        // A session walks items 1,2,3 at server 0 within Δt: OPT packs all
        // three into the first transfer — one (1+2α)λ = 2.6 transfer plus
        // tiny holds, instead of 3λ.
        let l = run(
            vec![
                Request::new(vec![1], 0, 0.0),
                Request::new(vec![2], 0, 0.1),
                Request::new(vec![3], 0, 0.2),
            ],
            0.8,
        );
        assert_eq!(l.transfers, 1, "prefetch did not pack the session");
        assert!((l.c_t - 2.6).abs() < 1e-12);
        // Holding: item 2 for 0.1 + item 3 for 0.2.
        assert!((l.c_p - 0.3).abs() < 1e-9);
        assert_eq!(l.full_hits, 2);
    }

    #[test]
    fn no_prefetch_beyond_delta_t() {
        // Item 2's access is 5Δt away: prefetching would cost αλ + 5μ > λ.
        let l = run(
            vec![
                Request::new(vec![1], 0, 0.0),
                Request::new(vec![2], 0, 5.0),
            ],
            0.8,
        );
        assert_eq!(l.transfers, 2);
        assert!((l.c_t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn opt_beats_naive_on_mixed_workload() {
        // Sanity: OPT ≤ NoPacking on any trace.
        use crate::algo::no_packing::NoPacking;
        let reqs: Vec<Request> = (0..100)
            .map(|i| {
                Request::new(
                    vec![(i % 7) as u32, ((i + 1) % 7) as u32],
                    (i % 3) as u32,
                    i as f64 * 0.3,
                )
            })
            .collect();
        let lo = run(reqs.clone(), 0.8);
        let cfg = AkpcConfig::default();
        let mut np = NoPacking::new(&cfg);
        for r in &reqs {
            np.handle_request(r);
        }
        assert!(
            lo.total() <= np.ledger().total() + 1e-9,
            "OPT {} vs NoPacking {}",
            lo.total(),
            np.ledger().total()
        );
    }

    #[test]
    fn server_isolation() {
        // Same item on two servers: no shared cache.
        let l = run(
            vec![
                Request::new(vec![1], 0, 0.0),
                Request::new(vec![1], 1, 0.1),
            ],
            0.8,
        );
        assert_eq!(l.transfers, 2);
    }
}
