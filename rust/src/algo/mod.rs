//! Caching policies: the paper's AKPC and all four evaluation baselines.
//!
//! | policy | packing | knowledge | paper ref |
//! |---|---|---|---|
//! | [`Akpc`](akpc::Akpc) | K-cliques (≤ ω), CS + ACM | online | §IV (proposed) |
//! | [`Akpc`] w/o CS, w/o ACM | K-cliques, no split/merge | online | Fig. 5/7/9 variant |
//! | [`PackCache2`](packcache2::PackCache2) | pairs | online | Wu et al. [2] |
//! | [`DpGreedy`](dp_greedy::DpGreedy) | pairs | offline trace | Huang et al. [4] |
//! | [`NoPacking`](no_packing::NoPacking) | none | online | Wang et al. [6] |
//! | [`Opt`](opt::Opt) | per-request exact | full future | OPT lower bound |
//! | [`Predictive`](crate::policy::Predictive) | K-cliques from EWMA forecast | online | DESIGN.md §15.1 |
//! | [`BundleOpt`](crate::policy::BundleOpt) | per-request missing bundle | online | DESIGN.md §15.2 |
//!
//! All clique-based policies share [`PackedCacheCore`], the Algorithm 5 + 6
//! request/expiry machinery; they differ only in *how the clique set is
//! produced*. The extended families in the last two rows live in
//! [`crate::policy`] and register through the same
//! [`PolicyRegistry`](crate::run::PolicyRegistry) as everything here.

pub mod adaptive;
pub mod akpc;
pub mod dp_greedy;
pub mod no_packing;
pub mod opt;
pub mod packcache2;

pub use adaptive::AdaptiveK;
pub use akpc::{Akpc, CliqueGenPipeline, GenState};
pub use dp_greedy::DpGreedy;
pub use no_packing::NoPacking;
pub use opt::Opt;
pub use packcache2::PackCache2;

use std::collections::{HashMap, HashSet};

use crate::cache::{CacheState, CostLedger, CostModel};
use crate::config::ChargePolicy;
use crate::trace::model::{Request, Trace};
use crate::util::{clique_key, Histogram};

/// A cache/transfer policy under evaluation.
pub trait CachePolicy {
    /// Display name (used in reports/figures).
    fn name(&self) -> String;

    /// Offline-knowledge hook: called once with the full trace before the
    /// run. Online policies must ignore it.
    fn prepare(&mut self, _trace: &Trace) {}

    /// Whether [`prepare`](CachePolicy::prepare) must see the complete
    /// trace (clairvoyant/offline policies: OPT, DP_Greedy). The
    /// streaming driver consults this: online policies replay from a
    /// bounded [`TraceSource`](crate::trace::stream::TraceSource)
    /// buffer, while offline policies force the stream to be collected —
    /// the documented memory cliff (DESIGN.md §10.4). Must agree with
    /// the registry's `PolicyCaps::needs_offline_trace` (pinned by a
    /// registry test).
    fn needs_offline_trace(&self) -> bool {
        false
    }

    /// Serve one request (Algorithm 1 Event 2 → Algorithm 5), charging the
    /// ledger.
    fn handle_request(&mut self, r: &Request);

    /// End-of-batch hook (Algorithm 1 Event 1): the clique-generation
    /// window closed; online policies may rebuild their packing from the
    /// batch just processed (applies to *subsequent* requests — causal).
    fn end_batch(&mut self, _batch: &[Request]) {}

    /// Accumulated costs.
    fn ledger(&self) -> &CostLedger;

    /// Distribution of active clique sizes over window ticks (Fig. 9a).
    /// `None` means the policy does not track packing at all (NoPacking,
    /// OPT) — distinct from an empty histogram, so reports can say "not
    /// tracked" instead of rendering a genuinely-empty distribution.
    fn clique_sizes(&self) -> Option<Histogram> {
        None
    }
}

/// Reference to the packed group an item currently belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CliqueRef {
    /// Content hash of the sorted member list (cache key).
    pub key: u64,
    /// Packed size |c|.
    pub size: u32,
}

/// Shared Algorithm 5/6 executor: per-ESS cache state + cost accounting
/// over an arbitrary (externally supplied) disjoint clique assignment.
#[derive(Debug)]
pub struct PackedCacheCore {
    pub cost: CostModel,
    pub charge: ChargePolicy,
    pub ledger: CostLedger,
    pub cache: CacheState,
    /// item → current packed group. Items absent here are implicit
    /// singletons.
    item_map: HashMap<u32, CliqueRef>,
    /// Keys of `Clique(W)` — cliques whose last copy must be retained
    /// (Algorithm 6 line 2).
    current_keys: HashSet<u64>,
    /// Scratch: distinct cliques of the in-flight request
    /// `(ref, requested_count)`.
    scratch: Vec<(CliqueRef, u32)>,
}

impl PackedCacheCore {
    pub fn new(cost: CostModel, charge: ChargePolicy) -> Self {
        Self {
            cost,
            charge,
            ledger: CostLedger::default(),
            cache: CacheState::new(),
            item_map: HashMap::new(),
            current_keys: HashSet::new(),
            scratch: Vec::with_capacity(8),
        }
    }

    /// Replace the active clique set (window tick). Items not covered by
    /// any clique revert to singletons.
    pub fn set_cliques<'a>(&mut self, cliques: impl Iterator<Item = &'a [u32]>) {
        self.item_map.clear();
        self.current_keys.clear();
        for c in cliques {
            debug_assert!(!c.is_empty());
            let key = clique_key(c);
            let r = CliqueRef {
                key,
                size: c.len() as u32,
            };
            for &d in c {
                self.item_map.insert(d, r);
            }
            self.current_keys.insert(key);
        }
    }

    /// The packed group serving `item` (singleton fallback).
    #[inline]
    pub fn group_of(&self, item: u32) -> CliqueRef {
        self.item_map.get(&item).copied().unwrap_or(CliqueRef {
            key: clique_key(&[item]),
            size: 1,
        })
    }

    /// Units the caching charge applies to (DESIGN.md §6).
    #[inline]
    fn charge_units(&self, requested: u32, size: u32) -> u32 {
        match self.charge {
            ChargePolicy::RequestedItems => requested,
            ChargePolicy::CliqueItems => size,
        }
    }

    /// Advance expiry processing (Algorithm 6) to `now` without serving a
    /// request, charging retention rent exactly as a request arrival
    /// would. Used by the sharded coordinator's shutdown quiesce: a shard
    /// only sweeps at its *own* request times, so without a final sweep to
    /// the global end time its ledger would miss the retention rent a
    /// single leader charges when other servers' requests advance the
    /// clock (DESIGN.md §2.3). Idempotent: re-advancing to a past time
    /// processes nothing.
    pub fn advance_time(&mut self, now: f64) {
        let retained_before = self.cache.retained_units;
        self.cache
            .process_expirations(now, &self.current_keys, self.cost.delta_t);
        // Storage rent for Alg.-6 forced retentions since the last event
        // (uncharged in the paper's pseudocode; see DESIGN.md §6).
        self.ledger.c_p +=
            self.cost.mu * (self.cache.retained_units - retained_before);
    }

    /// Algorithm 5 for one request.
    pub fn handle_request(&mut self, r: &Request) {
        let now = r.time;
        self.advance_time(now);

        // Gather distinct cliques + per-clique requested counts
        // (|D_i| ≤ d_max, so linear dedup beats hashing).
        self.scratch.clear();
        for &d in &r.items {
            let g = self.group_of(d);
            if let Some(e) = self.scratch.iter_mut().find(|(x, _)| x.key == g.key) {
                e.1 += 1;
            } else {
                self.scratch.push((g, 1));
            }
        }

        let mut all_hit = true;
        let new_exp = now + self.cost.delta_t;
        // Take scratch to appease the borrow checker; put it back after.
        let mut scratch = std::mem::take(&mut self.scratch);
        for &(g, requested) in &scratch {
            let units = self.charge_units(requested, g.size);
            if self.cache.is_cached(g.key, r.server, now) {
                // Lines 5-6: extend expiry, charge the extension.
                let prev = self.cache.extend(g.key, r.server, new_exp);
                self.ledger.c_p += self.cost.caching(units, new_exp - prev);
            } else {
                // Lines 7-12: fetch the packed copy, cache it.
                all_hit = false;
                self.ledger.c_t += self.cost.transfer_packed(g.size);
                self.ledger.transfers += 1;
                self.cache.insert(g.key, g.size, r.server, new_exp);
                self.ledger.c_p += self.cost.caching(units, self.cost.delta_t);
            }
            self.ledger.items_delivered += g.size as u64;
            self.ledger.items_requested += requested as u64;
        }
        scratch.clear();
        self.scratch = scratch;

        self.ledger.requests += 1;
        if all_hit {
            self.ledger.full_hits += 1;
        } else {
            self.ledger.misses += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AkpcConfig, TransferModel};

    fn core(alpha: f64) -> PackedCacheCore {
        let cfg = AkpcConfig {
            alpha,
            ..Default::default()
        };
        PackedCacheCore::new(CostModel::from_config(&cfg), ChargePolicy::RequestedItems)
    }

    fn req(items: &[u32], server: u32, time: f64) -> Request {
        Request::new(items.to_vec(), server, time)
    }

    #[test]
    fn singleton_miss_costs_lambda_plus_mu_dt() {
        // Theorem 1 Case 1.1 with ω=1 (no packing): C = λ + μΔt = 2.
        let mut c = core(0.8);
        c.handle_request(&req(&[3], 0, 0.0));
        assert!((c.ledger.c_t - 1.0).abs() < 1e-12);
        assert!((c.ledger.c_p - 1.0).abs() < 1e-12);
        assert_eq!(c.ledger.misses, 1);
    }

    #[test]
    fn packed_miss_costs_discounted_transfer() {
        // Theorem 1 Case 1.1: clique of ω=5 fetched for one item:
        // C_T = (1 + 4·0.8)λ = 4.2, C_P = 1·μ·Δt = 1.
        let mut c = core(0.8);
        c.set_cliques([vec![1u32, 2, 3, 4, 5].as_slice()].into_iter());
        c.handle_request(&req(&[3], 0, 0.0));
        assert!((c.ledger.c_t - 4.2).abs() < 1e-12);
        assert!((c.ledger.c_p - 1.0).abs() < 1e-12);
        assert_eq!(c.ledger.items_delivered, 5);
        assert_eq!(c.ledger.items_requested, 1);
    }

    #[test]
    fn hit_within_dt_charges_only_extension() {
        // Fig. 2 scenario: access at t=0 caches to 1.0; re-access at 0.4
        // extends to 1.4, charging μ·0.4; no transfer.
        let mut c = core(0.8);
        c.handle_request(&req(&[3], 0, 0.0));
        let (t0, p0) = (c.ledger.c_t, c.ledger.c_p);
        c.handle_request(&req(&[3], 0, 0.4));
        assert_eq!(c.ledger.c_t, t0, "no new transfer on hit");
        assert!((c.ledger.c_p - p0 - 0.4).abs() < 1e-12);
        assert_eq!(c.ledger.full_hits, 1);
    }

    #[test]
    fn fig2_timeline_total_caching() {
        // Fig. 2: accesses at t, t+0.3, t+0.6, t+0.9 keep d cached until
        // t+1.9; total C_P = μ·1.9 (initial Δt + extensions).
        let mut c = core(0.8);
        for t in [0.0, 0.3, 0.6, 0.9] {
            c.handle_request(&req(&[1], 0, t));
        }
        assert!((c.ledger.c_p - 1.9).abs() < 1e-12, "{}", c.ledger.c_p);
        assert_eq!(c.ledger.transfers, 1);
        // Re-access after expiry at t' = 2.5 refetches.
        c.handle_request(&req(&[1], 0, 2.5));
        assert_eq!(c.ledger.transfers, 2);
    }

    #[test]
    fn expired_copy_refetched() {
        let mut c = core(0.8);
        c.handle_request(&req(&[3], 0, 0.0));
        c.handle_request(&req(&[3], 0, 5.0)); // far past Δt=1
        assert_eq!(c.ledger.transfers, 2);
        assert!((c.ledger.c_t - 2.0).abs() < 1e-12);
    }

    #[test]
    fn different_servers_cache_independently() {
        let mut c = core(0.8);
        c.handle_request(&req(&[3], 0, 0.0));
        c.handle_request(&req(&[3], 1, 0.1));
        assert_eq!(c.ledger.transfers, 2);
        assert_eq!(c.cache.copy_count(c.group_of(3).key), 2);
    }

    #[test]
    fn multi_item_request_one_clique_single_transfer() {
        let mut c = core(0.8);
        c.set_cliques([vec![1u32, 2, 3].as_slice()].into_iter());
        c.handle_request(&req(&[1, 2, 3], 0, 0.0));
        assert_eq!(c.ledger.transfers, 1);
        // C_T = (1+2·0.8)λ = 2.6; C_P = 3 requested · μΔt = 3.
        assert!((c.ledger.c_t - 2.6).abs() < 1e-12);
        assert!((c.ledger.c_p - 3.0).abs() < 1e-12);
    }

    #[test]
    fn multi_item_request_across_cliques() {
        // Theorem 1 Case 2.1: S=2 items in distinct cliques of size 2:
        // C_T = 2·(1+α)λ, C_P = 2·μΔt.
        let mut c = core(0.8);
        c.set_cliques([vec![1u32, 2].as_slice(), vec![3u32, 4].as_slice()].into_iter());
        c.handle_request(&req(&[1, 3], 0, 0.0));
        assert_eq!(c.ledger.transfers, 2);
        assert!((c.ledger.c_t - 2.0 * 1.8).abs() < 1e-12);
        assert!((c.ledger.c_p - 2.0).abs() < 1e-12);
    }

    #[test]
    fn clique_items_charge_policy_charges_full_size() {
        let cfg = AkpcConfig::default();
        let mut c =
            PackedCacheCore::new(CostModel::from_config(&cfg), ChargePolicy::CliqueItems);
        c.set_cliques([vec![1u32, 2, 3, 4, 5].as_slice()].into_iter());
        c.handle_request(&req(&[1], 0, 0.0));
        assert!((c.ledger.c_p - 5.0).abs() < 1e-12);
    }

    #[test]
    fn alg5_transfer_variant() {
        let cfg = AkpcConfig {
            transfer_model: TransferModel::Alg5Line12,
            ..Default::default()
        };
        let mut c =
            PackedCacheCore::new(CostModel::from_config(&cfg), ChargePolicy::RequestedItems);
        c.set_cliques([vec![1u32, 2, 3, 4, 5].as_slice()].into_iter());
        c.handle_request(&req(&[1], 0, 0.0));
        // α·μ·|c| = 0.8·5 = 4.0
        assert!((c.ledger.c_t - 4.0).abs() < 1e-12);
    }

    #[test]
    fn window_tick_replaces_groups() {
        let mut c = core(0.8);
        c.set_cliques([vec![1u32, 2].as_slice()].into_iter());
        assert_eq!(c.group_of(1).size, 2);
        c.set_cliques([vec![1u32, 2, 3].as_slice()].into_iter());
        assert_eq!(c.group_of(1).size, 3);
        c.set_cliques(std::iter::empty());
        assert_eq!(c.group_of(1).size, 1);
    }

    #[test]
    fn cached_copy_survives_window_tick_with_same_content() {
        let mut c = core(0.8);
        c.set_cliques([vec![1u32, 2].as_slice()].into_iter());
        c.handle_request(&req(&[1], 0, 0.0));
        // Regenerate identical cliques: key unchanged -> still a hit.
        c.set_cliques([vec![1u32, 2].as_slice()].into_iter());
        c.handle_request(&req(&[2], 0, 0.5));
        assert_eq!(c.ledger.transfers, 1);
    }
}
