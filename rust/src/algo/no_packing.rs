//! *No Packing* baseline (inspired by Wang et al. [6]): every item is
//! transferred and cached individually — the cost ceiling every packing
//! strategy is measured against (Fig. 5, and the α→1 limit of Fig. 6a).

use super::{CachePolicy, PackedCacheCore};
use crate::cache::{CostLedger, CostModel};
use crate::config::AkpcConfig;
use crate::trace::model::Request;

#[derive(Debug)]
pub struct NoPacking {
    core: PackedCacheCore,
}

impl NoPacking {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self {
            // No cliques are ever installed: every item is a singleton.
            core: PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy),
        }
    }
}

impl CachePolicy for NoPacking {
    fn name(&self) -> String {
        "NoPacking".into()
    }

    fn handle_request(&mut self, r: &Request) {
        self.core.handle_request(r);
    }

    fn ledger(&self) -> &CostLedger {
        &self.core.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_item_transferred_individually() {
        let cfg = AkpcConfig::default();
        let mut p = NoPacking::new(&cfg);
        p.handle_request(&Request::new(vec![1, 2, 3], 0, 0.0));
        // 3 singleton transfers at λ each + 3 μΔt caching.
        assert_eq!(p.ledger().transfers, 3);
        assert!((p.ledger().c_t - 3.0).abs() < 1e-12);
        assert!((p.ledger().c_p - 3.0).abs() < 1e-12);
    }

    #[test]
    fn repeat_access_hits() {
        let cfg = AkpcConfig::default();
        let mut p = NoPacking::new(&cfg);
        p.handle_request(&Request::new(vec![1], 0, 0.0));
        p.handle_request(&Request::new(vec![1], 0, 0.5));
        assert_eq!(p.ledger().transfers, 1);
        assert_eq!(p.ledger().full_hits, 1);
    }

    #[test]
    fn end_batch_is_noop() {
        let cfg = AkpcConfig::default();
        let mut p = NoPacking::new(&cfg);
        let r = Request::new(vec![1, 2], 0, 0.0);
        p.end_batch(&[r.clone()]);
        p.handle_request(&r);
        assert_eq!(p.ledger().transfers, 2); // still unpacked
    }
}
