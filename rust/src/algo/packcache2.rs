//! *PackCache* baseline — Wu et al. [2]: the online 2-packing
//! state-of-the-art the paper compares against.
//!
//! Wu et al. mine frequently co-accessed *pairs* with an FP-tree and cache
//! them as packed duos. We reproduce the decision behaviour with the same
//! windowed machinery AKPC uses, restricted to pairs: pair co-occurrence
//! counts are accumulated over time with exponential decay (the FP-tree's
//! long-lived frequency structure — a single window would churn the
//! pairing and invalidate cached packs every tick), pairs above a minimum
//! support are kept, and a maximum-weight disjoint matching is selected
//! greedily at each window tick. Request/expiry handling is the shared
//! Algorithm 5/6 core (their cost model — the one this paper adopts).

use std::collections::HashMap;

use super::{CachePolicy, PackedCacheCore};
use crate::cache::{CostLedger, CostModel};
use crate::config::AkpcConfig;
use crate::trace::model::Request;
use crate::util::Histogram;

/// Minimum (decayed) co-occurrence count for a pair to be packable
/// (FP-tree support threshold analogue).
const MIN_SUPPORT: f64 = 5.0;

/// Minimum confidence: co-count must be at least this fraction of the
/// rarer item's own count (FP-tree association-rule confidence).
const MIN_CONFIDENCE: f64 = 0.75;

/// Per-window decay of historical pair counts (EWMA).
const DECAY: f64 = 0.7;

#[derive(Debug)]
pub struct PackCache2 {
    core: PackedCacheCore,
    hist: Histogram,
    /// Decayed co-occurrence counts (the FP-tree stand-in).
    counts: HashMap<(u32, u32), f64>,
    /// Decayed per-item transaction counts (for confidence).
    item_counts: HashMap<u32, f64>,
    n_pairs: usize,
}

impl PackCache2 {
    pub fn new(cfg: &AkpcConfig) -> Self {
        Self {
            core: PackedCacheCore::new(CostModel::from_config(cfg), cfg.charge_policy),
            hist: Histogram::new(),
            counts: HashMap::new(),
            item_counts: HashMap::new(),
            n_pairs: 0,
        }
    }

    /// Fold one window into the decayed counts. Pair co-utilization is
    /// mined over sessionized transactions (same signal AKPC's CRM sees;
    /// Wu et al.'s FP-tree equally observes per-user access sequences).
    fn absorb_window(&mut self, window: &[Request]) {
        for v in self.counts.values_mut() {
            *v *= DECAY;
        }
        self.counts.retain(|_, v| *v > 0.05);
        for v in self.item_counts.values_mut() {
            *v *= DECAY;
        }
        self.item_counts.retain(|_, v| *v > 0.05);
        let transactions =
            crate::crm::sessionize(window, 0.05 * self.core.cost.delta_t);
        for r in &transactions {
            for i in 0..r.items.len() {
                *self.item_counts.entry(r.items[i]).or_default() += 1.0;
                for j in (i + 1)..r.items.len() {
                    *self
                        .counts
                        .entry((r.items[i], r.items[j]))
                        .or_default() += 1.0;
                }
            }
        }
    }

    /// Confidence of a pair: co-count relative to the rarer member.
    fn confidence(&self, a: u32, b: u32, co: f64) -> f64 {
        let ca = self.item_counts.get(&a).copied().unwrap_or(co);
        let cb = self.item_counts.get(&b).copied().unwrap_or(co);
        co / ca.min(cb).max(1e-9)
    }

    /// Greedy maximum-weight disjoint pair matching over count data.
    pub fn matching_from_counts(counts: &HashMap<(u32, u32), f64>) -> Vec<[u32; 2]> {
        let mut pairs: Vec<((u32, u32), f64)> = counts
            .iter()
            .filter(|&(_, &c)| c >= MIN_SUPPORT)
            .map(|(&k, &c)| (k, c))
            .collect();
        // Deterministic: by count desc (total order — L1), then pair asc.
        pairs.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));

        let mut used = std::collections::HashSet::new();
        let mut matching = Vec::new();
        for ((a, b), _) in pairs {
            if !used.contains(&a) && !used.contains(&b) {
                used.insert(a);
                used.insert(b);
                matching.push([a, b]);
            }
        }
        matching
    }

    /// One-shot mining from a single window (used by tests and DP_Greedy's
    /// per-window ablation).
    pub fn mine_pairs(window: &[Request]) -> Vec<[u32; 2]> {
        let mut counts: HashMap<(u32, u32), f64> = HashMap::new();
        for r in window {
            for i in 0..r.items.len() {
                for j in (i + 1)..r.items.len() {
                    *counts.entry((r.items[i], r.items[j])).or_default() += 1.0;
                }
            }
        }
        Self::matching_from_counts(&counts)
    }
}

impl CachePolicy for PackCache2 {
    fn name(&self) -> String {
        "PackCache".into()
    }

    fn handle_request(&mut self, r: &Request) {
        self.core.handle_request(r);
    }

    fn end_batch(&mut self, batch: &[Request]) {
        self.absorb_window(batch);
        let confident: HashMap<(u32, u32), f64> = self
            .counts
            .iter()
            .filter(|(&(a, b), &c)| self.confidence(a, b, c) >= MIN_CONFIDENCE)
            .map(|(&k, &v)| (k, v))
            .collect();
        let pairs = Self::matching_from_counts(&confident);
        self.n_pairs = pairs.len();
        for _ in &pairs {
            self.hist.record(2);
        }
        self.core.set_cliques(pairs.iter().map(|p| p.as_slice()));
    }

    fn ledger(&self) -> &CostLedger {
        &self.core.ledger
    }

    fn clique_sizes(&self) -> Option<Histogram> {
        Some(self.hist.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(items: &[u32], t: f64) -> Request {
        Request::new(items.to_vec(), 0, t)
    }

    #[test]
    fn mine_pairs_finds_frequent_disjoint_pairs() {
        let mut w = vec![];
        for _ in 0..5 {
            w.push(req(&[1, 2], 0.0));
            w.push(req(&[3, 4], 0.0));
        }
        w.push(req(&[1, 3], 0.0)); // below support
        let pairs = PackCache2::mine_pairs(&w);
        assert!(pairs.contains(&[1, 2]));
        assert!(pairs.contains(&[3, 4]));
        assert!(!pairs.contains(&[1, 3]));
    }

    #[test]
    fn mine_pairs_disjoint() {
        let mut w = vec![];
        for _ in 0..5 {
            w.push(req(&[1, 2], 0.0));
        }
        for _ in 0..4 {
            w.push(req(&[2, 3], 0.0));
        }
        let pairs = PackCache2::mine_pairs(&w);
        // (1,2) has higher count and wins; (2,3) conflicts on 2.
        assert_eq!(pairs, vec![[1, 2]]);
    }

    #[test]
    fn packs_apply_to_next_batch() {
        let cfg = AkpcConfig::default();
        let mut p = PackCache2::new(&cfg);
        // Eight separate transactions (spaced > Δt) establish support
        // above MIN_SUPPORT for the {1,2} pair.
        let batch: Vec<Request> = (0..8).map(|i| req(&[1, 2], i as f64 * 5.0)).collect();
        for r in &batch {
            p.handle_request(r);
        }
        p.end_batch(&batch);
        // Next request for item 1 fetches the {1,2} pack: (1+α)λ = 1.8.
        let before = p.ledger().c_t;
        p.handle_request(&req(&[1], 100.0));
        assert!((p.ledger().c_t - before - 1.8).abs() < 1e-12);
    }

    #[test]
    fn single_requests_never_pack() {
        let cfg = AkpcConfig::default();
        let mut p = PackCache2::new(&cfg);
        let batch: Vec<Request> = (0..10).map(|i| req(&[i % 3], i as f64)).collect();
        p.end_batch(&batch);
        assert_eq!(p.n_pairs, 0);
    }
}
