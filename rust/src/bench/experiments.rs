//! One function per paper table/figure (see DESIGN.md §4).
//!
//! Every function prints the same rows/series the paper reports and
//! returns them as structured data so integration tests can assert the
//! *shape* of each result (who wins, direction of trends, crossovers).

use crate::algo::Akpc;
use crate::config::AkpcConfig;
use crate::sim;
use crate::trace::generator::{netflix_like, spotify_like};
use crate::trace::model::Trace;

use super::sweep::{run_policy_set, EngineChoice, PolicyChoice, RelativeCosts};

/// Experiment-wide options.
#[derive(Debug, Clone, Copy)]
pub struct ExpOptions {
    /// Requests per trace (paper: 1M; quick runs use less).
    pub n_requests: usize,
    pub engine: EngineChoice,
    pub seed: u64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            n_requests: 200_000,
            engine: EngineChoice::Native,
            seed: 1,
        }
    }
}

/// The two evaluation datasets (paper §V-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    Netflix,
    Spotify,
}

impl Dataset {
    pub const BOTH: &'static [Dataset] = &[Dataset::Netflix, Dataset::Spotify];

    pub fn label(&self) -> &'static str {
        match self {
            Dataset::Netflix => "Netflix",
            Dataset::Spotify => "Spotify",
        }
    }

    pub fn trace(&self, cfg: &AkpcConfig, opts: &ExpOptions) -> Trace {
        match self {
            Dataset::Netflix => {
                netflix_like(cfg.n_items, cfg.n_servers, opts.n_requests, opts.seed)
            }
            Dataset::Spotify => {
                spotify_like(cfg.n_items, cfg.n_servers, opts.n_requests, opts.seed)
            }
        }
    }
}

/// A generic experiment result: one labelled series per dataset.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub id: String,
    pub param_name: String,
    pub params: Vec<f64>,
    /// `series[dataset][policy] = Vec<relative cost per param>`.
    pub series: Vec<(String, Vec<(String, Vec<f64>)>)>,
}

impl SweepResult {
    pub fn print(&self) {
        println!("== {} — relative total cost vs {} ==", self.id, self.param_name);
        for (ds, policies) in &self.series {
            println!("-- {ds} --");
            print!("{:<24}", self.param_name);
            for p in &self.params {
                print!("{p:>10.2}");
            }
            println!();
            for (name, vals) in policies {
                print!("{name:<24}");
                for v in vals {
                    print!("{v:>10.2}");
                }
                println!();
            }
        }
    }

    /// JSON export (for plotting tools).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("param", Json::Str(self.param_name.clone())),
            (
                "params",
                Json::Arr(self.params.iter().map(|&p| Json::Num(p)).collect()),
            ),
            (
                "series",
                Json::Arr(
                    self.series
                        .iter()
                        .map(|(ds, pol)| {
                            Json::obj(vec![
                                ("dataset", Json::Str(ds.clone())),
                                (
                                    "policies",
                                    Json::Arr(
                                        pol.iter()
                                            .map(|(name, vals)| {
                                                Json::obj(vec![
                                                    ("name", Json::Str(name.clone())),
                                                    (
                                                        "rel_cost",
                                                        Json::Arr(
                                                            vals.iter()
                                                                .map(|&v| Json::Num(v))
                                                                .collect(),
                                                        ),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn series_for(&self, dataset: &str, policy: &str) -> Option<&[f64]> {
        self.series
            .iter()
            .find(|(d, _)| d == dataset)?
            .1
            .iter()
            .find(|(p, _)| p == policy)
            .map(|(_, v)| v.as_slice())
    }
}

/// Generic sweep: vary one config parameter, run the policy set on both
/// datasets, normalize to OPT per point.
fn sweep_param(
    id: &str,
    param_name: &str,
    params: &[f64],
    opts: &ExpOptions,
    base: &AkpcConfig,
    policies: &[PolicyChoice],
    apply: impl Fn(&AkpcConfig, f64) -> AkpcConfig,
    regen_trace_per_point: bool,
) -> SweepResult {
    let mut series = Vec::new();
    for ds in Dataset::BOTH {
        let base_trace = if regen_trace_per_point {
            None
        } else {
            Some(ds.trace(base, opts))
        };
        let mut per_policy: Vec<(String, Vec<f64>)> = Vec::new();
        for &p in params {
            let cfg = apply(base, p);
            let trace = match &base_trace {
                Some(t) => t.clone(),
                None => ds.trace(&cfg, opts),
            };
            let reports = run_policy_set(&cfg, &trace, policies, opts.engine);
            let rel = RelativeCosts::from_reports(&reports);
            for (name, v, ..) in &rel.rows {
                match per_policy.iter_mut().find(|(n, _)| n == name) {
                    Some((_, vals)) => vals.push(*v),
                    None => per_policy.push((name.clone(), vec![*v])),
                }
            }
        }
        series.push((ds.label().to_string(), per_policy));
    }
    SweepResult {
        id: id.to_string(),
        param_name: param_name.to_string(),
        params: params.to_vec(),
        series,
    }
}

// ---------------------------------------------------------------- Table I

/// Table I — analytic transfer/caching costs by pack size. Pure cost-model
/// check (also unit-tested); printed for completeness.
pub fn table1(cfg: &AkpcConfig) {
    let m = crate::cache::CostModel::from_config(cfg);
    println!("== Table I — transfer & caching costs (λ={}, μ={}, Δt={}, α={}) ==",
        cfg.lambda, cfg.mu, cfg.delta_t(), cfg.alpha);
    println!("{:<10}{:<12}{:>14}{:>14}", "#packed", "type", "transfer", "caching");
    for k in [1u32, 2, 5] {
        println!(
            "{:<10}{:<12}{:>14.2}{:>14.2}",
            k, "unpacked", m.transfer_unpacked(k), m.caching(k, m.delta_t)
        );
        println!(
            "{:<10}{:<12}{:>14.2}{:>14.2}",
            k, "K-packed", m.transfer_packed(k), m.caching(k, m.delta_t)
        );
    }
}

// ---------------------------------------------------------------- Fig. 5

/// Fig. 5 result: stacked C_T/C_P per policy per dataset, relative to OPT.
#[derive(Debug, Clone)]
pub struct Fig5Result {
    /// `(dataset, rows)` where rows = `(policy, rel_total, rel_ct, rel_cp)`.
    pub datasets: Vec<(String, Vec<(String, f64, f64, f64)>)>,
}

impl Fig5Result {
    pub fn rel_total(&self, dataset: &str, policy: &str) -> Option<f64> {
        self.datasets
            .iter()
            .find(|(d, _)| d == dataset)?
            .1
            .iter()
            .find(|(p, ..)| p == policy)
            .map(|&(_, t, ..)| t)
    }

    /// JSON export.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::Arr(
            self.datasets
                .iter()
                .map(|(ds, rows)| {
                    Json::obj(vec![
                        ("dataset", Json::Str(ds.clone())),
                        (
                            "rows",
                            Json::Arr(
                                rows.iter()
                                    .map(|(name, t, ct, cp)| {
                                        Json::obj(vec![
                                            ("policy", Json::Str(name.clone())),
                                            ("total", Json::Num(*t)),
                                            ("c_t", Json::Num(*ct)),
                                            ("c_p", Json::Num(*cp)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }

    pub fn print(&self) {
        println!("== Fig. 5 — total cost vs SOTA (normalized, OPT = 1) ==");
        for (ds, rows) in &self.datasets {
            println!("-- {ds} --");
            println!(
                "{:<26}{:>10}{:>10}{:>10}",
                "policy", "total", "C_T", "C_P"
            );
            for (name, t, ct, cp) in rows {
                println!("{name:<26}{t:>10.2}{ct:>10.2}{cp:>10.2}");
            }
        }
    }
}

/// Fig. 5 — cost comparison across all packing strategies on both traces.
pub fn fig5(opts: &ExpOptions, base: &AkpcConfig) -> Fig5Result {
    let mut datasets = Vec::new();
    for ds in Dataset::BOTH {
        let trace = ds.trace(base, opts);
        let reports = run_policy_set(base, &trace, PolicyChoice::FIG5, opts.engine);
        let rel = RelativeCosts::from_reports(&reports);
        datasets.push((ds.label().to_string(), rel.rows));
    }
    Fig5Result { datasets }
}

// ------------------------------------------------------- Fig. 6 (α and ρ)

/// Fig. 6(a) — sensitivity to the discount factor α ∈ [0.6, 1.0].
pub fn fig6a(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 6(a)",
        "alpha",
        &[0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0],
        opts,
        base,
        PolicyChoice::SWEEP,
        |c, a| AkpcConfig { alpha: a, ..c.clone() },
        false,
    )
}

/// Fig. 6(b) — sensitivity to the cost ratio ρ = λ/μ ∈ [1, 10].
pub fn fig6b(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 6(b)",
        "rho",
        &[1.0, 2.0, 4.0, 6.0, 8.0, 10.0],
        opts,
        base,
        PolicyChoice::SWEEP,
        // The swept quantity is the λ/μ *price ratio* (the paper's Fig. 6b
        // x-axis). Δt is held at its base value by compensating ρ —
        // sweeping Δt together with λ (Alg. 6 line 1 taken literally)
        // would conflate the expiry horizon with the price ratio and
        // reverses the trend the paper reports (DESIGN.md §6).
        |c, r| AkpcConfig {
            lambda: r * c.mu,
            rho: 1.0 / r,
            ..c.clone()
        },
        false,
    )
}

// ----------------------------------------------------- Fig. 7 (θ, γ, ω)

/// Fig. 7(a) — CRM threshold θ sweep.
pub fn fig7a(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 7(a)",
        "theta",
        &[0.05, 0.1, 0.15, 0.2, 0.3, 0.4, 0.6, 0.8],
        opts,
        base,
        &[PolicyChoice::AkpcNoCsNoAcm, PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, t| AkpcConfig { theta: t as f32, ..c.clone() },
        false,
    )
}

/// Fig. 7(b) — clique approximation threshold γ sweep.
pub fn fig7b(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 7(b)",
        "gamma",
        &[0.5, 0.6, 0.7, 0.8, 0.85, 0.9, 0.95, 1.0],
        opts,
        base,
        &[PolicyChoice::AkpcNoCsNoAcm, PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, g| AkpcConfig { gamma_approx: g as f32, ..c.clone() },
        false,
    )
}

/// Fig. 7(c) — maximum clique size ω sweep.
pub fn fig7c(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 7(c)",
        "omega",
        &[2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0],
        opts,
        base,
        &[PolicyChoice::AkpcNoCsNoAcm, PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, w| AkpcConfig { omega: w as u32, ..c.clone() },
        false,
    )
}

// ------------------------------------------------ Fig. 8 (scalability)

/// Fig. 8(a) — number of servers sweep (trace regenerated per point).
pub fn fig8a(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 8(a)",
        "servers",
        &[30.0, 60.0, 150.0, 300.0, 600.0],
        opts,
        base,
        PolicyChoice::SWEEP,
        |c, m| AkpcConfig { n_servers: m as u32, ..c.clone() },
        true,
    )
}

/// Fig. 8(b) — number of data items sweep (trace regenerated per point).
pub fn fig8b(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 8(b)",
        "items",
        &[60.0, 240.0, 600.0, 1800.0, 3600.0],
        opts,
        base,
        PolicyChoice::SWEEP,
        |c, n| AkpcConfig { n_items: n as u32, ..c.clone() },
        true,
    )
}

/// Fig. 8(c) — batch size sweep.
pub fn fig8c(opts: &ExpOptions, base: &AkpcConfig) -> SweepResult {
    sweep_param(
        "Fig 8(c)",
        "batch",
        &[50.0, 100.0, 200.0, 350.0, 500.0],
        opts,
        base,
        PolicyChoice::SWEEP,
        |c, b| AkpcConfig { batch_size: b as usize, ..c.clone() },
        false,
    )
}

// ------------------------------------------------ Fig. 9 (cliques, time)

/// Fig. 9(a) — clique-size distribution across the three AKPC variants.
#[derive(Debug, Clone)]
pub struct Fig9aResult {
    /// `(dataset, variant, distribution)`.
    pub dists: Vec<(String, String, Vec<(u32, f64)>)>,
}

impl Fig9aResult {
    pub fn mean_size(&self, dataset: &str, variant: &str) -> Option<f64> {
        let (_, _, dist) = self
            .dists
            .iter()
            .find(|(d, v, _)| d == dataset && v == variant)?;
        let total: f64 = dist.iter().map(|(_, f)| f).sum();
        Some(
            dist.iter()
                .map(|&(s, f)| s as f64 * f)
                .sum::<f64>()
                / total.max(1e-12),
        )
    }

    pub fn print(&self) {
        println!("== Fig. 9(a) — clique size distribution ==");
        for (ds, variant, dist) in &self.dists {
            let mean = self.mean_size(ds, variant).unwrap_or(0.0);
            print!("{ds:<10} {variant:<24} mean={mean:.2}  ");
            for (s, f) in dist {
                print!("{s}:{:.0}% ", f * 100.0);
            }
            println!();
        }
    }
}

pub fn fig9a(opts: &ExpOptions, base: &AkpcConfig) -> Fig9aResult {
    let variants = [
        (PolicyChoice::AkpcNoCsNoAcm, "AKPC w/o CS, w/o ACM"),
        (PolicyChoice::AkpcNoAcm, "AKPC w/o ACM"),
        (PolicyChoice::Akpc, "AKPC (Proposed)"),
    ];
    let mut dists = Vec::new();
    for ds in Dataset::BOTH {
        let trace = ds.trace(base, opts);
        for (choice, label) in variants {
            let mut p = choice.build(base, opts.engine);
            let rep = sim::run(p.as_mut(), &trace, base.batch_size);
            dists.push((
                ds.label().to_string(),
                label.to_string(),
                // All Fig. 9a variants are AKPC-based and track cliques;
                // a None here would mean the variant stopped packing.
                rep.clique_hist.map(|h| h.distribution()).unwrap_or_default(),
            ));
        }
    }
    Fig9aResult { dists }
}

/// Fig. 9(b) — clique-generation execution time vs number of data items.
#[derive(Debug, Clone)]
pub struct Fig9bResult {
    /// `(n_items, seconds per clique-generation tick)`.
    pub rows: Vec<(u32, f64)>,
}

impl Fig9bResult {
    pub fn print(&self) {
        println!("== Fig. 9(b) — clique generation time vs data size ==");
        println!("{:<12}{:>16}", "n_items", "secs/tick");
        for (n, s) in &self.rows {
            println!("{n:<12}{s:>16.4}");
        }
    }
}

/// Measures the full Event-1 path (CRM build + diff + adjust/split/merge)
/// per tick, averaged over several windows.
pub fn fig9b(opts: &ExpOptions, base: &AkpcConfig) -> Fig9bResult {
    let sizes = [100u32, 500, 1_000, 2_000, 5_000, 10_000];
    let mut rows = Vec::new();
    for &n in &sizes {
        let cfg = AkpcConfig {
            n_items: n,
            ..base.clone()
        };
        // Enough requests for ~8 windows.
        let trace = netflix_like(n, cfg.n_servers, cfg.batch_size * 8, opts.seed);
        let engine = match opts.engine {
            EngineChoice::Native => crate::runtime::CrmEngine::Native,
            EngineChoice::Xla => crate::runtime::CrmEngine::Xla,
        };
        let mut akpc = Akpc::with_builder(&cfg, engine.builder(&cfg.artifacts_dir));
        for batch in trace.batches(cfg.batch_size) {
            akpc.end_batch(batch);
        }
        rows.push((n, akpc.clique_gen_secs / akpc.windows.max(1) as f64));
    }
    Fig9bResult { rows }
}

// ------------------------------------------------ Design-choice ablations

/// Ablations over the design choices DESIGN.md §6 documents — not paper
/// figures, but the evidence behind each resolution:
///
/// * `session_gap_frac` — co-utilization gap (must be ≪ Δt);
/// * `crm_window_batches` — correlation-window span (single-batch CRMs
///   fragment cliques);
/// * `charge_policy` — requested-items (paper Table I) vs physical
///   clique-items caching attribution;
/// * `transfer_model` — Eq. 3 vs the literal Alg.-5-line-12 formula.
pub fn ablations(opts: &ExpOptions, base: &AkpcConfig) -> Vec<SweepResult> {
    let mut out = Vec::new();
    out.push(sweep_param(
        "Ablation: session gap",
        "gap_frac",
        &[0.01, 0.05, 0.2, 0.5, 1.0],
        opts,
        base,
        &[PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, g| AkpcConfig {
            session_gap_frac: g,
            ..c.clone()
        },
        false,
    ));
    out.push(sweep_param(
        "Ablation: CRM window span",
        "batches",
        &[1.0, 2.0, 5.0, 10.0, 20.0],
        opts,
        base,
        &[PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, w| AkpcConfig {
            crm_window_batches: w as usize,
            ..c.clone()
        },
        false,
    ));
    out.push(sweep_param(
        "Ablation: caching-charge attribution",
        "policy(0=req,1=clique)",
        &[0.0, 1.0],
        opts,
        base,
        &[PolicyChoice::NoPacking, PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, p| AkpcConfig {
            charge_policy: if p < 0.5 {
                crate::config::ChargePolicy::RequestedItems
            } else {
                crate::config::ChargePolicy::CliqueItems
            },
            ..c.clone()
        },
        false,
    ));
    out.push(sweep_param(
        "Ablation: packed-transfer formula",
        "model(0=eq3,1=alg5)",
        &[0.0, 1.0],
        opts,
        base,
        &[PolicyChoice::NoPacking, PolicyChoice::Akpc, PolicyChoice::Opt],
        |c, m| AkpcConfig {
            transfer_model: if m < 0.5 {
                crate::config::TransferModel::Eq3
            } else {
                crate::config::TransferModel::Alg5Line12
            },
            ..c.clone()
        },
        false,
    ));
    out
}

// ------------------------------------------- Extended policy field table

/// The `akpc exp policies` field: every baseline the paper evaluates plus
/// the DESIGN.md §15 extension families, weakest-first so the table reads
/// as a ladder down to OPT. Resolved by registry *name* (not
/// [`PolicyChoice`]) precisely so extension policies are swept too.
pub const POLICY_FIELD: &[&str] = &[
    "no-packing",
    "packcache",
    "dp-greedy",
    "bundle-opt",
    "predictive",
    "akpc",
    "opt",
];

/// `akpc exp policies` — AKPC against a stronger baseline field than the
/// paper's (EXPERIMENTS.md §Policies).
#[derive(Debug, Clone)]
pub struct PoliciesResult {
    /// `(dataset, rows)` where rows = `(policy, total, rel_to_opt, c_t, c_p)`.
    pub datasets: Vec<(String, Vec<(String, f64, f64, f64, f64)>)>,
}

impl PoliciesResult {
    pub fn rel_total(&self, dataset: &str, policy: &str) -> Option<f64> {
        self.datasets
            .iter()
            .find(|(d, _)| d == dataset)?
            .1
            .iter()
            .find(|(p, ..)| p == policy)
            .map(|&(_, _, rel, ..)| rel)
    }

    pub fn print(&self) {
        println!("== exp policies — extended policy field (OPT = 1) ==");
        for (ds, rows) in &self.datasets {
            println!("-- {ds} --");
            println!(
                "{:<26}{:>14}{:>10}{:>14}{:>14}",
                "policy", "total", "rel", "C_T", "C_P"
            );
            for (name, total, rel, ct, cp) in rows {
                println!("{name:<26}{total:>14.1}{rel:>10.2}{ct:>14.1}{cp:>14.1}");
            }
        }
    }

    /// JSON export.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::Arr(
            self.datasets
                .iter()
                .map(|(ds, rows)| {
                    Json::obj(vec![
                        ("dataset", Json::Str(ds.clone())),
                        (
                            "rows",
                            Json::Arr(
                                rows.iter()
                                    .map(|(name, total, rel, ct, cp)| {
                                        Json::obj(vec![
                                            ("policy", Json::Str(name.clone())),
                                            ("total", Json::Num(*total)),
                                            ("rel", Json::Num(*rel)),
                                            ("c_t", Json::Num(*ct)),
                                            ("c_p", Json::Num(*cp)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

/// Run the full [`POLICY_FIELD`] on both datasets, normalized to OPT.
pub fn policies(opts: &ExpOptions, base: &AkpcConfig) -> anyhow::Result<PoliciesResult> {
    let registry = crate::run::PolicyRegistry::builtin();
    let mut datasets = Vec::new();
    for ds in Dataset::BOTH {
        let trace = ds.trace(base, opts);
        let mut raw = Vec::new();
        for &name in POLICY_FIELD {
            let mut p = registry.build(name, base, opts.engine)?;
            let rep = sim::run(p.as_mut(), &trace, base.batch_size);
            raw.push((rep.name.clone(), rep.ledger.total(), rep.ledger.c_t, rep.ledger.c_p));
        }
        let opt_total = raw
            .iter()
            .find(|(n, ..)| n == "OPT")
            .map(|&(_, t, ..)| t.max(1e-12))
            .ok_or_else(|| anyhow::anyhow!("POLICY_FIELD must include opt"))?;
        let rows = raw
            .into_iter()
            .map(|(n, t, ct, cp)| (n, t, t / opt_total, ct, cp))
            .collect();
        datasets.push((ds.label().to_string(), rows));
    }
    Ok(PoliciesResult { datasets })
}

// ------------------------------------------------ Theorems 1–2 harness

/// Adversarial competitive-ratio experiment (Theorem 2 construction):
/// phases of S fresh uncached items in distinct ω-cliques, never repeated.
/// Returns `(measured_ratio, derived_bound)`.
///
/// Note on the bound (DESIGN.md §6): the paper *states* the closed form
/// `(2 + (ω−1)·α·S) / (1 + (S−1)·α)`, but its own Case-2.1 derivation
/// computes `C_AKPC = S·(2 + (ω−1)α)λ` against `C_OPT = (1+(S−1)α)λ`,
/// whose ratio is `S·(2 + (ω−1)α) / (1 + (S−1)α)` — the `2` must scale
/// with S. The two agree only at S = 1. We report the derivation's value
/// as [`adversarial_bound_derived`] (what the algorithm actually attains)
/// and the paper's stated form as [`adversarial_bound_stated`].
pub fn adversarial_ratio(cfg: &AkpcConfig, s: u32, phases: u32) -> (f64, f64) {
    let cost = crate::cache::CostModel::from_config(cfg);

    // AKPC under adversary: each of the S items triggers a full ω-clique
    // transfer plus Δt caching of the requested item (Theorem 1 Case 2.1).
    let akpc_phase =
        s as f64 * (cost.transfer_packed(cfg.omega) + cfg.mu * cfg.delta_t());
    // OPT: one exactly-S packed transfer.
    let opt_phase = (1.0 + (s as f64 - 1.0) * cfg.alpha) * cfg.lambda;
    let measured = (phases as f64 * akpc_phase) / (phases as f64 * opt_phase);

    (measured, adversarial_bound_derived(cfg, s))
}

/// The bound the paper's Case-2.1 derivation actually yields:
/// `S·(2 + (ω−1)α) / (1 + (S−1)α)` (assumes ρ = 1, i.e. μΔt = λ).
pub fn adversarial_bound_derived(cfg: &AkpcConfig, s: u32) -> f64 {
    let s = s as f64;
    s * (2.0 + (cfg.omega as f64 - 1.0) * cfg.alpha) / (1.0 + (s - 1.0) * cfg.alpha)
}

/// The closed form as *stated* in Theorems 1-2:
/// `(2 + (ω−1)·α·S) / (1 + (S−1)·α)`.
pub fn adversarial_bound_stated(cfg: &AkpcConfig, s: u32) -> f64 {
    let s = s as f64;
    (2.0 + (cfg.omega as f64 - 1.0) * cfg.alpha * s) / (1.0 + (s - 1.0) * cfg.alpha)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> ExpOptions {
        ExpOptions {
            n_requests: 20_000,
            engine: EngineChoice::Native,
            seed: 3,
        }
    }

    fn quick_cfg() -> AkpcConfig {
        // Table-II shape (see sim::tests on density).
        AkpcConfig {
            crm_top_frac: 1.0,
            ..Default::default()
        }
    }

    #[test]
    fn fig5_shape_holds() {
        let r = fig5(&quick_opts(), &quick_cfg());
        for ds in ["Netflix", "Spotify"] {
            let akpc = r.rel_total(ds, "AKPC").unwrap();
            let pc = r.rel_total(ds, "PackCache").unwrap();
            let np = r.rel_total(ds, "NoPacking").unwrap();
            assert!(akpc < pc, "{ds}: AKPC {akpc} !< PackCache {pc}");
            assert!(pc <= np * 1.01, "{ds}: PackCache {pc} !<= NoPacking {np}");
            assert!(akpc >= 1.0);
        }
        r.print();
    }

    #[test]
    fn fig6a_converges_toward_no_packing_at_alpha_1() {
        let r = fig6a(&quick_opts(), &quick_cfg());
        let akpc = r.series_for("Netflix", "AKPC").unwrap();
        let np = r.series_for("Netflix", "NoPacking").unwrap();
        // Gap at α=0.6 must be much larger than gap at α=1.0.
        let gap_first = np[0] - akpc[0];
        let gap_last = np.last().unwrap() - akpc.last().unwrap();
        assert!(
            gap_last < gap_first,
            "gap did not shrink: {gap_first} -> {gap_last}"
        );
    }

    #[test]
    fn adversarial_matches_derived_bound_exactly() {
        let cfg = AkpcConfig::default();
        for s in 1..=5 {
            let (measured, bound) = adversarial_ratio(&cfg, s, 10);
            assert!(
                (measured - bound).abs() < 1e-9,
                "S={s}: {measured} vs {bound}"
            );
            // The paper's stated closed form agrees at S=1 and is smaller
            // (typo'd) for S>1 — see DESIGN.md §6.
            let stated = adversarial_bound_stated(&cfg, s);
            if s == 1 {
                assert!((stated - bound).abs() < 1e-9);
            } else {
                assert!(stated < bound);
            }
        }
    }

    #[test]
    fn policies_field_has_expected_ladder() {
        let r = policies(&quick_opts(), &quick_cfg()).unwrap();
        for ds in ["Netflix", "Spotify"] {
            let np = r.rel_total(ds, "NoPacking").unwrap();
            let bo = r.rel_total(ds, "BundleOpt").unwrap();
            let akpc = r.rel_total(ds, "AKPC").unwrap();
            let opt = r.rel_total(ds, "OPT").unwrap();
            // §15.2 pointwise dominance: BundleOpt never exceeds NoPacking.
            assert!(bo <= np + 1e-9, "{ds}: BundleOpt {bo} !<= NoPacking {np}");
            // Cross-request packing beats per-request bundles.
            assert!(akpc < bo, "{ds}: AKPC {akpc} !< BundleOpt {bo}");
            assert!((opt - 1.0).abs() < 1e-12);
            assert!(akpc >= 1.0);
            // Predictive must at least run and land in a sane band — the
            // forecast smooths the same CRM signal AKPC reacts to, so it
            // should sit well under a NoPacking blowup even when the
            // prediction is imperfect.
            let pred = r.rel_total(ds, "Predictive").unwrap();
            assert!(pred >= 1.0 && pred <= np * 1.25, "{ds}: Predictive {pred}");
        }
        r.print();
        crate::util::json::parse(&r.to_json().to_string()).unwrap();
    }

    #[test]
    fn fig9b_times_are_sane() {
        let mut o = quick_opts();
        o.n_requests = 2_000;
        let r = fig9b(&o, &quick_cfg());
        assert_eq!(r.rows.len(), 6);
        for (n, secs) in &r.rows {
            assert!(*secs >= 0.0 && *secs < 10.0, "n={n}: {secs}s");
        }
    }
}
