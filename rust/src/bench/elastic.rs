//! Elastic autoscale benchmark (EXPERIMENTS.md §Elastic): on each
//! autoscale scenario, run the elastic driver against its two static
//! baselines — always-min and always-max — over the *same* replay loop
//! and the *same* [`RentalModel`], with rental billed at actual
//! shard-seconds of trace time. The AKPC ledger is placement-invariant
//! (the handoff is exact), so the three cells differ only in rental and
//! overload: the elastic win is pure fleet-sizing.

use crate::config::AkpcConfig;
use crate::elastic::{
    drive_elastic, drive_static, ControllerConfig, ElasticOutcome, RentalModel,
};
use crate::run::cell_config;
use crate::scenario;
use crate::trace::model::Trace;
use crate::util::Json;

use super::sweep::EngineChoice;

/// The scenario-library entries built to stress the autoscaler: flash
/// crowd (scale-up), overnight trough (scale-down), hot-shard skew
/// (robustness — volume is flat, so a volume-tracking controller should
/// hold steady and match the static baseline).
pub const AUTOSCALE_SCENARIOS: [&str; 3] = [
    "autoscale-flash-crowd",
    "overnight-trough",
    "hot-shard-skew",
];

/// One (scenario, fleet policy) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ElasticCell {
    pub scenario: String,
    /// `elastic`, `static-<min>`, or `static-<max>`.
    pub label: String,
    pub outcome: ElasticOutcome,
}

/// The full sweep, cells in (scenario-major, elastic/min/max) order.
#[derive(Debug, Clone)]
pub struct ElasticSweep {
    pub cells: Vec<ElasticCell>,
}

impl ElasticSweep {
    /// Total billed cost of the cell labeled `label` under `scenario`.
    pub fn total(&self, scenario: &str, label: &str) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.label == label)
            .map(|c| c.outcome.cost.total())
    }

    pub fn print(&self) {
        println!("== Elastic autoscale — elastic vs static fleets ==");
        let mut last = "";
        for c in &self.cells {
            if c.scenario != last {
                println!("-- {} --", c.scenario);
                last = &c.scenario;
            }
            println!("  {}", c.outcome.summary(&c.label));
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.cells
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("scenario", Json::Str(c.scenario.clone())),
                        ("label", Json::Str(c.label.clone())),
                        ("outcome", c.outcome.to_json()),
                    ])
                })
                .collect(),
        )
    }
}

/// Derive a controller + rental model calibrated to `trace`'s mean
/// arrival rate: one shard comfortably carries the mean, so demand
/// swings (a 6x flash crowd, a 4x overnight stretch) map onto fleet
/// sizes inside `[min_shards, max_shards]`. Rental is priced at a tenth
/// of the per-shard capacity per shard-second, overload at 1 per excess
/// request — cheap enough that always-max is wasteful, dear enough that
/// always-min's spike overload dominates its rental savings.
pub fn calibrated(
    trace: &Trace,
    min_shards: usize,
    max_shards: usize,
) -> (ControllerConfig, RentalModel) {
    let span = (trace.requests.last().map(|r| r.time).unwrap_or(0.0)
        - trace.requests.first().map(|r| r.time).unwrap_or(0.0))
    .max(f64::MIN_POSITIVE);
    let mean_rate = trace.len() as f64 / span;
    let ctrl = ControllerConfig {
        min_shards,
        max_shards,
        shard_capacity_rps: mean_rate,
        shard_capacity_entries: 1e18,
        ewma_alpha: 0.6,
        scale_up_frac: 0.9,
        scale_down_frac: 0.6,
        cooldown_windows: 2,
    };
    let rental = RentalModel {
        rate_per_shard_time: 0.1 * mean_rate,
        shard_capacity_rps: mean_rate,
        overload_penalty: 1.0,
    };
    (ctrl, rental)
}

/// Sweep `names` (built-in scenarios) × {elastic, always-min,
/// always-max} at `scale`, fleet bounded by `[min_shards, max_shards]`.
pub fn elastic_suite(
    cfg: &AkpcConfig,
    names: &[&str],
    min_shards: usize,
    max_shards: usize,
    engine: EngineChoice,
    scale: f64,
) -> anyhow::Result<ElasticSweep> {
    anyhow::ensure!(
        min_shards >= 1 && min_shards <= max_shards,
        "need 1 <= min_shards <= max_shards (got {min_shards}..{max_shards})"
    );
    let mut cells = Vec::with_capacity(names.len() * 3);
    for &name in names {
        let spec = scenario::builtin(name)
            .ok_or_else(|| anyhow::anyhow!("unknown built-in scenario `{name}`"))?;
        let sc = spec.compile(scale)?;
        let cell_cfg = cell_config(cfg, sc.n_items, sc.n_servers);
        let trace = sc.concat_trace();
        let (ctrl, rental) = calibrated(trace, min_shards, max_shards);
        let runs = [
            (
                "elastic".to_string(),
                drive_elastic(&cell_cfg, engine.to_engine(), &trace.requests, ctrl, rental)?,
            ),
            (
                format!("static-{min_shards}"),
                drive_static(
                    &cell_cfg,
                    engine.to_engine(),
                    &trace.requests,
                    min_shards,
                    rental,
                )?,
            ),
            (
                format!("static-{max_shards}"),
                drive_static(
                    &cell_cfg,
                    engine.to_engine(),
                    &trace.requests,
                    max_shards,
                    rental,
                )?,
            ),
        ];
        for (label, outcome) in runs {
            cells.push(ElasticCell {
                scenario: name.to_string(),
                label,
                outcome,
            });
        }
    }
    Ok(ElasticSweep { cells })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_tracks_mean_rate() {
        let t = crate::trace::generator::netflix_like(20, 8, 500, 3);
        let (ctrl, rental) = calibrated(&t, 1, 4);
        assert_eq!(ctrl.min_shards, 1);
        assert_eq!(ctrl.max_shards, 4);
        assert!(ctrl.shard_capacity_rps > 0.0);
        assert!((rental.shard_capacity_rps - ctrl.shard_capacity_rps).abs() < 1e-12);
        assert!(rental.rate_per_shard_time > 0.0);
    }

    #[test]
    fn suite_runs_a_downscaled_flash_crowd() {
        let cfg = AkpcConfig {
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let sweep = elastic_suite(
            &cfg,
            &["autoscale-flash-crowd"],
            1,
            4,
            EngineChoice::Native,
            0.02,
        )
        .unwrap();
        assert_eq!(sweep.cells.len(), 3);
        assert!(sweep.total("autoscale-flash-crowd", "elastic").unwrap() > 0.0);
        assert!(sweep.total("autoscale-flash-crowd", "static-1").is_some());
        assert!(sweep.total("autoscale-flash-crowd", "static-4").is_some());
        crate::util::json::parse(&sweep.to_json().to_string()).unwrap();
        sweep.print();
    }

    #[test]
    fn suite_rejects_bad_bounds_and_names() {
        let cfg = AkpcConfig::default();
        assert!(elastic_suite(&cfg, &["smoke"], 4, 1, EngineChoice::Native, 1.0).is_err());
        assert!(elastic_suite(&cfg, &["nope"], 1, 4, EngineChoice::Native, 1.0).is_err());
    }
}
