//! Scenario suite runner: sweep the built-in scenario library × a policy
//! set through the phased single-leader driver and report a cost matrix
//! plus per-phase breakdowns (EXPERIMENTS.md §Scenarios). Sits alongside
//! the fig* experiments; `akpc scenario suite` and the CI smoke job call
//! into it.

use crate::config::AkpcConfig;
use crate::run::{cell_config, PolicyRegistry};
use crate::scenario::{self, run_phased, ScenarioRun};
use crate::util::Json;

use super::sweep::{EngineChoice, PolicyChoice};

/// Everything one suite sweep produced.
#[derive(Debug, Clone)]
pub struct ScenarioMatrix {
    /// Scenario names, column order.
    pub scenarios: Vec<String>,
    /// Policy display names, row order.
    pub policies: Vec<String>,
    /// All runs (scenario-major: `runs[s * policies.len() + p]`).
    pub runs: Vec<ScenarioRun>,
}

impl ScenarioMatrix {
    /// Total cost of `(policy row, scenario col)`.
    pub fn total(&self, policy: usize, scenario: usize) -> f64 {
        self.runs[scenario * self.policies.len() + policy].total_cost()
    }

    /// Render the policy × scenario total-cost matrix.
    pub fn print(&self) {
        println!("== Scenario suite — total cost (policy × scenario) ==");
        print!("{:<24}", "policy");
        for s in &self.scenarios {
            print!("{s:>18}");
        }
        println!();
        for (pi, p) in self.policies.iter().enumerate() {
            print!("{p:<24}");
            for si in 0..self.scenarios.len() {
                print!("{:>18.1}", self.total(pi, si));
            }
            println!();
        }
    }

    /// JSON export: the matrix plus every per-phase breakdown.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "scenarios",
                Json::Arr(
                    self.scenarios
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            ),
            (
                "policies",
                Json::Arr(self.policies.iter().map(|p| Json::Str(p.clone())).collect()),
            ),
            (
                "runs",
                Json::Arr(self.runs.iter().map(ScenarioRun::to_json).collect()),
            ),
        ])
    }
}

/// Run `policies` over each named built-in scenario at `scale` (phase
/// lengths multiplied; 1.0 = full size). Scenario state never leaks
/// between cells: every run builds a fresh policy and recompiles the
/// scenario.
pub fn scenario_suite(
    cfg: &AkpcConfig,
    names: &[&str],
    policies: &[PolicyChoice],
    engine: EngineChoice,
    scale: f64,
) -> anyhow::Result<ScenarioMatrix> {
    let policy_names: Vec<&str> = policies.iter().map(|p| p.cli_name()).collect();
    scenario_suite_names(cfg, names, &policy_names, engine, scale)
}

/// The registry-name flavor of [`scenario_suite`]: policies are resolved
/// by registered name, so extension families without a [`PolicyChoice`]
/// (`predictive`, `bundle-opt`, `akpc-adaptive-k`, …) sweep the same
/// matrix as the builtins. `akpc scenario suite` calls this.
pub fn scenario_suite_names(
    cfg: &AkpcConfig,
    names: &[&str],
    policies: &[&str],
    engine: EngineChoice,
    scale: f64,
) -> anyhow::Result<ScenarioMatrix> {
    let registry = PolicyRegistry::builtin();
    let mut runs = Vec::with_capacity(names.len() * policies.len());
    let mut policy_names = Vec::new();
    for &name in names {
        let spec = scenario::builtin(name)
            .ok_or_else(|| anyhow::anyhow!("unknown built-in scenario `{name}`"))?;
        let sc = spec.compile(scale)?;
        // The same effective-config derivation RunSpec::validate uses.
        let cell_cfg = cell_config(cfg, sc.n_items, sc.n_servers);
        for &p in policies {
            let mut policy = registry.build(p, &cell_cfg, engine)?;
            let run = run_phased(policy.as_mut(), &sc, cell_cfg.batch_size);
            if policy_names.len() < policies.len() {
                policy_names.push(run.policy.clone());
            }
            runs.push(run);
        }
    }
    Ok(ScenarioMatrix {
        scenarios: names.iter().map(|s| s.to_string()).collect(),
        policies: policy_names,
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_runs_smoke_matrix() {
        let cfg = AkpcConfig {
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let m = scenario_suite(
            &cfg,
            &["smoke"],
            &[PolicyChoice::NoPacking, PolicyChoice::Akpc],
            EngineChoice::Native,
            1.0,
        )
        .unwrap();
        assert_eq!(m.scenarios, vec!["smoke"]);
        assert_eq!(m.policies, vec!["NoPacking", "AKPC"]);
        assert_eq!(m.runs.len(), 2);
        assert!(m.total(0, 0) > 0.0 && m.total(1, 0) > 0.0);
        crate::util::json::parse(&m.to_json().to_string()).unwrap();
        m.print();
    }

    #[test]
    fn suite_by_name_includes_extension_policies() {
        // The names-based flavor sweeps registry extensions that have no
        // PolicyChoice — the DESIGN.md §15 families in particular.
        let cfg = AkpcConfig {
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let m = scenario_suite_names(
            &cfg,
            &["smoke"],
            &["no-packing", "bundle-opt", "predictive"],
            EngineChoice::Native,
            1.0,
        )
        .unwrap();
        assert_eq!(m.policies, vec!["NoPacking", "BundleOpt", "Predictive"]);
        assert_eq!(m.runs.len(), 3);
        // BundleOpt's packed fetches can only undercut NoPacking (§15.2
        // pointwise dominance) — pinned here on a real scenario too.
        assert!(m.total(1, 0) <= m.total(0, 0) + 1e-9);
    }

    #[test]
    fn suite_by_name_rejects_unknown_policy() {
        let cfg = AkpcConfig::default();
        let err = scenario_suite_names(
            &cfg,
            &["smoke"],
            &["bogus"],
            EngineChoice::Native,
            1.0,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown policy `bogus`"), "{err}");
    }

    #[test]
    fn suite_rejects_unknown_scenario() {
        let cfg = AkpcConfig::default();
        assert!(scenario_suite(
            &cfg,
            &["nope"],
            &[PolicyChoice::NoPacking],
            EngineChoice::Native,
            1.0
        )
        .is_err());
    }
}
