//! The paper's evaluation harness: one function per table/figure
//! (DESIGN.md §4 experiment index). Each regenerates the same rows/series
//! the paper reports, normalized to OPT = 1 where the paper does.

pub mod elastic;
pub mod experiments;
pub mod perf;
pub mod scenarios;
pub mod sweep;

pub use elastic::{elastic_suite, ElasticSweep, AUTOSCALE_SCENARIOS};
pub use experiments::*;
pub use perf::{run_perf, PerfOptions, PerfReport};
pub use scenarios::{scenario_suite, ScenarioMatrix};
pub use sweep::{run_policy_set, PolicyChoice, RelativeCosts};
