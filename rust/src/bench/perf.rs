//! Tracked hot-path performance baseline — the `akpc bench` subcommand.
//!
//! Replays the same hot paths `benches/hot_paths.rs` exercises, but as a
//! *reportable artifact*: one `BENCH_<PR>.json` per PR (EXPERIMENTS.md
//! §Perf documents the schema), so every future change lands against a
//! comparable baseline instead of an anecdote. Covered paths:
//!
//! * **request_path** — end-to-end policy replay (Algorithm 5 + window
//!   ticks) through the [`crate::run`] facade, req/s;
//! * **crm_build** — sparse CSR CRM construction per window at several
//!   `n_items` points × window lengths (the measured edge density is the
//!   sparsity coordinate);
//! * **clique_generate** — one incremental Algorithm-3 pipeline tick
//!   (adjust → form → split → merge) per window;
//! * **diff_windows** — the streaming ΔE merge between two windows;
//! * **memory** — resident-bytes of a materialized `Vec<Request>` vs the
//!   streaming replay path's bounded buffers at the same workload size,
//!   plus the OS-reported process peak RSS (DESIGN.md §10.6 / schema
//!   version 2 in EXPERIMENTS.md §Perf).
//!
//! `scale` shrinks the workloads proportionally (CI smoke uses 0.01); the
//! checked-in baselines are produced at scale 1.

use std::time::Instant;

use crate::clique::CliqueSet;
use crate::config::AkpcConfig;
use crate::crm::{build_native, diff_windows, CrmWindow};
use crate::run::{generated_source, PolicyRegistry, RunSpec, Workload};
use crate::trace::generator::{netflix_like, TraceKind};
use crate::trace::model::Request;
use crate::trace::stream::{TraceSource, DEFAULT_CHUNK_LEN};
use crate::util::json::Json;

/// Knobs for one baseline run.
#[derive(Debug, Clone)]
pub struct PerfOptions {
    /// Workload multiplier (1.0 = the full checked-in baseline).
    pub scale: f64,
    /// Generator seed (folded into every workload).
    pub seed: u64,
    /// Item-universe sizes for the per-window benchmarks.
    pub n_items_points: Vec<u32>,
}

impl Default for PerfOptions {
    fn default() -> Self {
        Self {
            scale: 1.0,
            seed: 1,
            n_items_points: vec![64, 256, 1024],
        }
    }
}

/// One end-to-end policy replay measurement.
#[derive(Debug, Clone)]
pub struct RequestPathRow {
    pub policy: String,
    pub n_requests: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub total_cost: f64,
}

/// One per-window CRM construction measurement.
#[derive(Debug, Clone)]
pub struct CrmBuildRow {
    pub n_items: u32,
    pub window_len: usize,
    /// Kept items k of the built window.
    pub k: usize,
    /// Binary edges E of the built window.
    pub edges: usize,
    /// Measured sparsity coordinate: `E / (k·(k−1)/2)`.
    pub density: f64,
    pub ms_per_window: f64,
}

/// One incremental clique-generation tick measurement.
#[derive(Debug, Clone)]
pub struct CliqueGenRow {
    pub n_items: u32,
    pub ms_per_window: f64,
    pub cliques: usize,
    pub delta_edges: usize,
}

/// One window-diff measurement.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub n_items: u32,
    pub us_per_diff: f64,
    pub delta_edges: usize,
}

/// One bounded-memory measurement (schema v2, EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct MemoryRow {
    /// Requests in the measured workload.
    pub n_requests: usize,
    /// Resident-bytes estimate of the materialized `Vec<Request>` form
    /// (request struct + item heap), summed over the *actual* generated
    /// stream — what the pre-streaming replay paths held.
    pub materialized_bytes: u64,
    /// Peak resident request-buffer bytes of the streaming replay path:
    /// the largest source chunk plus one clique-generation window.
    pub streamed_peak_bytes: u64,
    /// `materialized_bytes / streamed_peak_bytes` — the headline
    /// bounded-memory factor (grows linearly with workload size).
    pub reduction: f64,
    /// OS-reported process peak RSS (`VmHWM`, Linux `/proc`), sampled
    /// after the streamed pass; `None` off-Linux. Whole-process, so it
    /// bounds (not equals) the replay buffers.
    pub peak_rss_kb: Option<u64>,
}

/// The full baseline report (`BENCH_*.json` payload).
#[derive(Debug, Clone, Default)]
pub struct PerfReport {
    pub scale: f64,
    pub seed: u64,
    pub request_path: Vec<RequestPathRow>,
    pub crm_build: Vec<CrmBuildRow>,
    pub clique_generate: Vec<CliqueGenRow>,
    pub diff_windows: Vec<DiffRow>,
    pub memory: Vec<MemoryRow>,
}

/// Resident footprint of one request in the materialized vector form:
/// the inline struct plus its item heap allocation.
fn request_footprint_bytes(r: &Request) -> u64 {
    (std::mem::size_of::<Request>() + r.items.len() * std::mem::size_of::<u32>()) as u64
}

/// The process peak RSS in KiB from `/proc/self/status` (`VmHWM`);
/// `None` when procfs is unavailable (non-Linux hosts).
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Median wall-clock seconds of `iters` runs of `f`.
fn time_median<T>(iters: usize, mut f: impl FnMut() -> T) -> f64 {
    let mut samples = Vec::with_capacity(iters);
    std::hint::black_box(f()); // warm-up
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_unstable_by(crate::util::order::total_f64);
    samples[samples.len() / 2]
}

/// Run the baseline suite. Every end-to-end replay goes through the
/// [`RunSpec`] facade so the measured path is the one `akpc run` serves.
pub fn run_perf(opts: &PerfOptions) -> anyhow::Result<PerfReport> {
    let registry = PolicyRegistry::builtin();
    let iters = ((6.0 * opts.scale).ceil() as usize).clamp(3, 6);
    let mut report = PerfReport {
        scale: opts.scale,
        seed: opts.seed,
        ..Default::default()
    };

    // -- request_path: end-to-end replay via the run facade.
    let n_requests = ((100_000.0 * opts.scale).round() as usize).max(2_000);
    for policy in ["akpc", "no-packing"] {
        let cfg = AkpcConfig {
            n_servers: 100,
            seed: opts.seed,
            ..Default::default()
        };
        let outcome = RunSpec::new()
            .config(cfg)
            .policy(policy)
            .workload(Workload::Generated {
                kind: TraceKind::Netflix,
                n_requests,
            })
            .execute(&registry)?;
        report.request_path.push(RequestPathRow {
            policy: policy.to_string(),
            n_requests: outcome.n_requests,
            wall_secs: outcome.wall_secs,
            requests_per_sec: outcome.requests_per_sec,
            total_cost: outcome.total(),
        });
    }

    // -- per-window paths at each n_items point.
    for &n in &opts.n_items_points {
        let t1 = netflix_like(n, 10, 1_024, opts.seed);
        let t2 = netflix_like(n, 10, 1_024, opts.seed + 1);

        // CRM build at two window lengths (density varies with both the
        // catalog size and the window length — the sparsity axis).
        for window_len in [256usize, 1_024] {
            let reqs = &t1.requests[..window_len.min(t1.len())];
            let secs = time_median(iters, || build_native(reqs, n, 0.2, 1.0));
            let w = build_native(reqs, n, 0.2, 1.0);
            let k = w.k();
            let max_pairs = (k * k.saturating_sub(1) / 2).max(1);
            report.crm_build.push(CrmBuildRow {
                n_items: n,
                window_len: reqs.len(),
                k,
                edges: w.edge_count(),
                density: w.edge_count() as f64 / max_pairs as f64,
                ms_per_window: secs * 1e3,
            });
        }

        // Incremental clique generation (the Algorithm-3 tick) and the
        // streaming window diff, both over consecutive windows.
        let w1 = build_native(&t1.requests[..256.min(t1.len())], n, 0.2, 1.0);
        let w2 = build_native(&t2.requests[..256.min(t2.len())], n, 0.2, 1.0);
        let prev = CliqueSet::generate(
            &CliqueSet::new(),
            &w1,
            &diff_windows(&CrmWindow::default(), &w1),
            5,
            0.85,
            true,
            true,
        );
        let delta = diff_windows(&w1, &w2);
        let secs = time_median(iters, || {
            CliqueSet::generate(&prev, &w2, &delta, 5, 0.85, true, true)
        });
        let set = CliqueSet::generate(&prev, &w2, &delta, 5, 0.85, true, true);
        report.clique_generate.push(CliqueGenRow {
            n_items: n,
            ms_per_window: secs * 1e3,
            cliques: set.len(),
            delta_edges: delta.len(),
        });

        let secs = time_median(iters, || diff_windows(&w1, &w2));
        report.diff_windows.push(DiffRow {
            n_items: n,
            us_per_diff: secs * 1e6,
            delta_edges: delta.len(),
        });
    }

    // -- memory: one streamed pass over a large generated workload,
    // accumulating the materialized-footprint sum *without ever
    // materializing it* (the streaming engine measuring itself).
    let n_mem = ((1_000_000.0 * opts.scale).round() as usize).max(10_000);
    let mem_cfg = AkpcConfig {
        n_servers: 100,
        seed: opts.seed,
        ..Default::default()
    };
    let mut src = generated_source(TraceKind::Netflix, &mem_cfg, n_mem, DEFAULT_CHUNK_LEN)?;
    let mut buf = Vec::new();
    let (mut total_bytes, mut peak_chunk_bytes, mut served) = (0u64, 0u64, 0usize);
    while src.next_chunk(&mut buf)? {
        let chunk_bytes: u64 = buf.iter().map(request_footprint_bytes).sum();
        peak_chunk_bytes = peak_chunk_bytes.max(chunk_bytes);
        total_bytes += chunk_bytes;
        served += buf.len();
    }
    let avg_bytes = total_bytes as f64 / served.max(1) as f64;
    let window_bytes = (mem_cfg.batch_size as f64 * avg_bytes).ceil() as u64;
    let streamed_peak = peak_chunk_bytes + window_bytes;
    report.memory.push(MemoryRow {
        n_requests: served,
        materialized_bytes: total_bytes,
        streamed_peak_bytes: streamed_peak,
        reduction: total_bytes as f64 / streamed_peak.max(1) as f64,
        peak_rss_kb: peak_rss_kb(),
    });

    Ok(report)
}

impl PerfReport {
    /// Human-readable summary table.
    pub fn print(&self) {
        println!("== akpc bench (scale {}, seed {}) ==", self.scale, self.seed);
        println!("-- request_path (end-to-end via RunSpec)");
        for r in &self.request_path {
            println!(
                "  {:<12} {:>9} reqs  {:>12.0} req/s  total={:.1}",
                r.policy, r.n_requests, r.requests_per_sec, r.total_cost
            );
        }
        println!("-- crm_build (sparse CSR per window)");
        for r in &self.crm_build {
            println!(
                "  n={:<6} |W|={:<5} k={:<5} E={:<7} density={:.4}  {:>9.3} ms/window",
                r.n_items, r.window_len, r.k, r.edges, r.density, r.ms_per_window
            );
        }
        println!("-- clique_generate (incremental Algorithm-3 tick)");
        for r in &self.clique_generate {
            println!(
                "  n={:<6} cliques={:<5} dE={:<6} {:>9.3} ms/window",
                r.n_items, r.cliques, r.delta_edges, r.ms_per_window
            );
        }
        println!("-- diff_windows (streaming edge diff)");
        for r in &self.diff_windows {
            println!(
                "  n={:<6} dE={:<6} {:>9.1} us/diff",
                r.n_items, r.delta_edges, r.us_per_diff
            );
        }
        println!("-- memory (materialized Vec<Request> vs streamed buffers)");
        for r in &self.memory {
            let rss = r
                .peak_rss_kb
                .map(|k| format!("{k} KiB"))
                .unwrap_or_else(|| "n/a".to_string());
            println!(
                "  {:>9} reqs  materialized={:>12}B  streamed-peak={:>9}B  \
                 x{:<8.0} peak-rss={rss}",
                r.n_requests, r.materialized_bytes, r.streamed_peak_bytes, r.reduction
            );
        }
    }

    /// The `BENCH_*.json` payload (schema: EXPERIMENTS.md §Perf).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("akpc-hot-paths".into())),
            ("schema_version", Json::Num(2.0)),
            ("scale", Json::Num(self.scale)),
            ("seed", Json::Num(self.seed as f64)),
            (
                "request_path",
                Json::Arr(
                    self.request_path
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("policy", Json::Str(r.policy.clone())),
                                ("n_requests", Json::Num(r.n_requests as f64)),
                                ("wall_secs", Json::Num(r.wall_secs)),
                                ("requests_per_sec", Json::Num(r.requests_per_sec)),
                                ("total_cost", Json::Num(r.total_cost)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "crm_build",
                Json::Arr(
                    self.crm_build
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("n_items", Json::Num(r.n_items as f64)),
                                ("window_len", Json::Num(r.window_len as f64)),
                                ("k", Json::Num(r.k as f64)),
                                ("edges", Json::Num(r.edges as f64)),
                                ("density", Json::Num(r.density)),
                                ("ms_per_window", Json::Num(r.ms_per_window)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "clique_generate",
                Json::Arr(
                    self.clique_generate
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("n_items", Json::Num(r.n_items as f64)),
                                ("ms_per_window", Json::Num(r.ms_per_window)),
                                ("cliques", Json::Num(r.cliques as f64)),
                                ("delta_edges", Json::Num(r.delta_edges as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "diff_windows",
                Json::Arr(
                    self.diff_windows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("n_items", Json::Num(r.n_items as f64)),
                                ("us_per_diff", Json::Num(r.us_per_diff)),
                                ("delta_edges", Json::Num(r.delta_edges as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "memory",
                Json::Arr(
                    self.memory
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("n_requests", Json::Num(r.n_requests as f64)),
                                (
                                    "materialized_bytes",
                                    Json::Num(r.materialized_bytes as f64),
                                ),
                                (
                                    "streamed_peak_bytes",
                                    Json::Num(r.streamed_peak_bytes as f64),
                                ),
                                ("reduction", Json::Num(r.reduction)),
                                (
                                    "peak_rss_kb",
                                    match r.peak_rss_kb {
                                        Some(k) => Json::Num(k as f64),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_scale_baseline_runs() {
        let opts = PerfOptions {
            scale: 0.002,
            seed: 3,
            n_items_points: vec![32, 64],
        };
        let rep = run_perf(&opts).unwrap();
        assert_eq!(rep.request_path.len(), 2);
        assert_eq!(rep.crm_build.len(), 4);
        assert_eq!(rep.clique_generate.len(), 2);
        assert_eq!(rep.diff_windows.len(), 2);
        for r in &rep.request_path {
            assert!(r.requests_per_sec > 0.0, "{}", r.policy);
        }
        for r in &rep.crm_build {
            assert!(r.ms_per_window >= 0.0);
            assert!((0.0..=1.0).contains(&r.density), "{}", r.density);
        }
        // Memory row: the streamed path must be a large constant-factor
        // win even at the 10k floor, and the analytic sums must be
        // self-consistent.
        assert_eq!(rep.memory.len(), 1);
        let m = &rep.memory[0];
        assert_eq!(m.n_requests, 10_000);
        assert!(m.materialized_bytes > m.streamed_peak_bytes);
        assert!(m.reduction > 1.0, "reduction {}", m.reduction);
        // JSON payload parses back.
        let j = rep.to_json();
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("bench").and_then(|b| b.as_str()),
            Some("akpc-hot-paths")
        );
        assert_eq!(
            parsed.get("crm_build").and_then(|a| a.as_arr()).map(|a| a.len()),
            Some(4)
        );
        assert_eq!(
            parsed.get("memory").and_then(|a| a.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }
}
