//! Shared sweep machinery: instantiate policy sets, run them over a trace,
//! normalize to OPT.

use crate::algo::CachePolicy;
use crate::config::AkpcConfig;
use crate::runtime::CrmEngine;
use crate::sim::{self, SimReport};
use crate::trace::model::Trace;

/// Which CRM engine AKPC variants use in experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    Native,
    Xla,
}

impl EngineChoice {
    pub fn to_engine(self) -> CrmEngine {
        match self {
            EngineChoice::Native => CrmEngine::Native,
            EngineChoice::Xla => CrmEngine::Xla,
        }
    }
}

/// The policies of Fig. 5 (superset used by all sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyChoice {
    NoPacking,
    DpGreedy,
    PackCache,
    AkpcNoCsNoAcm,
    AkpcNoAcm,
    Akpc,
    Opt,
}

impl PolicyChoice {
    pub const FIG5: &'static [PolicyChoice] = &[
        PolicyChoice::NoPacking,
        PolicyChoice::DpGreedy,
        PolicyChoice::PackCache,
        PolicyChoice::AkpcNoCsNoAcm,
        PolicyChoice::Akpc,
        PolicyChoice::Opt,
    ];

    pub const SWEEP: &'static [PolicyChoice] = &[
        PolicyChoice::NoPacking,
        PolicyChoice::PackCache,
        PolicyChoice::Akpc,
        PolicyChoice::Opt,
    ];

    /// The registry/CLI name of this choice — the bijection between the
    /// sweep enum and [`crate::run::PolicyRegistry`] names lives here
    /// and nowhere else.
    pub fn cli_name(self) -> &'static str {
        match self {
            PolicyChoice::NoPacking => "no-packing",
            PolicyChoice::DpGreedy => "dp-greedy",
            PolicyChoice::PackCache => "packcache",
            PolicyChoice::AkpcNoCsNoAcm => "akpc-no-cs-no-acm",
            PolicyChoice::AkpcNoAcm => "akpc-no-acm",
            PolicyChoice::Akpc => "akpc",
            PolicyChoice::Opt => "opt",
        }
    }

    /// Instantiate via the policy registry — construction logic lives in
    /// [`crate::run::PolicyRegistry::builtin`], not here.
    pub fn build(
        self,
        cfg: &AkpcConfig,
        engine: EngineChoice,
    ) -> Box<dyn CachePolicy> {
        crate::run::PolicyRegistry::builtin().build_choice(self, cfg, engine)
    }
}

/// Run a set of policies over one trace; returns reports in input order.
pub fn run_policy_set(
    cfg: &AkpcConfig,
    trace: &Trace,
    policies: &[PolicyChoice],
    engine: EngineChoice,
) -> Vec<SimReport> {
    policies
        .iter()
        .map(|&p| {
            let mut policy = p.build(cfg, engine);
            sim::run(policy.as_mut(), trace, cfg.batch_size)
        })
        .collect()
}

/// Costs normalized to the OPT entry (paper's "relative total cost").
#[derive(Debug, Clone)]
pub struct RelativeCosts {
    /// `(policy name, relative total, relative C_T, relative C_P)`.
    pub rows: Vec<(String, f64, f64, f64)>,
    pub opt_total: f64,
}

impl RelativeCosts {
    /// Normalize a report set by its OPT member (falls back to the
    /// minimum total if OPT was not in the set).
    pub fn from_reports(reports: &[SimReport]) -> Self {
        let opt_total = reports
            .iter()
            .find(|r| r.name == "OPT")
            .map(|r| r.total())
            .unwrap_or_else(|| {
                reports
                    .iter()
                    .map(|r| r.total())
                    .fold(f64::INFINITY, f64::min)
            })
            .max(1e-12);
        let rows = reports
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    r.total() / opt_total,
                    r.ledger.c_t / opt_total,
                    r.ledger.c_p / opt_total,
                )
            })
            .collect();
        Self { rows, opt_total }
    }

    pub fn of(&self, name: &str) -> Option<f64> {
        self.rows
            .iter()
            .find(|(n, ..)| n == name)
            .map(|&(_, t, ..)| t)
    }
}

/// One row of the serving-path shard-scaling sweep.
#[derive(Debug, Clone)]
pub struct ShardScalingRow {
    pub n_shards: usize,
    pub requests_per_sec: f64,
    pub total_cost: f64,
    pub p99_latency_us: u32,
}

/// Replay `trace` through the sharded coordinator at each shard count
/// (parallel clients, async ticks — the throughput configuration) and
/// report req/s + cost per configuration. Used by `benches/hot_paths.rs`
/// and `akpc exp shards` to exercise 1/2/4/8-shard setups.
pub fn shard_scaling(
    cfg: &AkpcConfig,
    trace: &Trace,
    shard_counts: &[usize],
    engine: EngineChoice,
) -> anyhow::Result<Vec<ShardScalingRow>> {
    let mut rows = Vec::with_capacity(shard_counts.len());
    for &n in shard_counts {
        let rep = sim::replay_sharded(
            cfg,
            engine.to_engine(),
            trace,
            n,
            sim::ReplayMode::Parallel,
        )?;
        rows.push(ShardScalingRow {
            n_shards: rep.n_shards,
            requests_per_sec: rep.requests_per_sec,
            total_cost: rep.metrics.ledger.total(),
            p99_latency_us: rep.metrics.latency_us.quantile(0.99),
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::netflix_like;

    #[test]
    fn shard_scaling_reports_all_counts() {
        let cfg = AkpcConfig {
            n_items: 30,
            n_servers: 16,
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let trace = netflix_like(30, 16, 2_000, 2);
        let rows = shard_scaling(&cfg, &trace, &[1, 2], EngineChoice::Native).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].n_shards, 1);
        assert_eq!(rows[1].n_shards, 2);
        for r in &rows {
            assert!(r.requests_per_sec > 0.0);
            assert!(r.total_cost > 0.0);
        }
    }

    #[test]
    fn policy_set_runs_and_normalizes() {
        let cfg = AkpcConfig {
            n_items: 40,
            n_servers: 300,
            crm_top_frac: 1.0,
            ..Default::default()
        };
        let trace = netflix_like(40, 300, 5_000, 1);
        let reports =
            run_policy_set(&cfg, &trace, PolicyChoice::FIG5, EngineChoice::Native);
        assert_eq!(reports.len(), PolicyChoice::FIG5.len());
        let rel = RelativeCosts::from_reports(&reports);
        assert!((rel.of("OPT").unwrap() - 1.0).abs() < 1e-9);
        assert!(rel.of("NoPacking").unwrap() >= 1.0);
        assert!(rel.of("AKPC").unwrap() >= 1.0);
    }
}
